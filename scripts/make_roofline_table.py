"""Render the §Roofline markdown table from results/*.json."""

import glob
import json
import sys

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(tag="baseline", d="results"):
    rows = []
    for fn in sorted(glob.glob(f"{d}/*__{tag}.json")):
        rows.append(json.load(open(fn)))
    return rows


def main(tag="baseline", d="results"):
    rows = load(tag, d)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r["mesh"]))
    print("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
          "| dominant | 6ND/HLO | roofline frac | GB/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                  f"| — | — | — | skip: {r['reason'][:48]} |")
            continue
        t = r["roofline"]
        step = max(t.values())
        frac = t["compute_s"] * r["useful_flops_ratio"] / step if step else 0
        note = ""
        if r["memory"]["peak_bytes_per_device"] > 16e9:
            note = f"over 16GB HBM"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
              f"| {t['collective_s']:.3f} | {r['dominant'].replace('_s','')} "
              f"| {r['useful_flops_ratio']:.3f} | {frac:.4f} "
              f"| {r['memory']['peak_bytes_per_device']/1e9:.1f} | {note} |")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
