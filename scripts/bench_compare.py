#!/usr/bin/env python
"""Diff two benchmark JSON dumps and fail on regressions (ISSUE 3 satellite).

Usage:
    python scripts/bench_compare.py BASE.json NEW.json \
        --key meta/lookup_hold/penalty/holds=4 \
        --key-up meta/proposals/speedup \
        --key-min agent/commit_tput/speedup=2.0 \
        --key-max gc/churn/amplification_post=1.2 \
        [--max-regress 0.25]

``--key``     names a lower-is-better value (latencies, penalty ratios):
              regression when new > base * (1 + max_regress).
``--key-up``  names a higher-is-better value (speedups):
              regression when new < base * (1 - max_regress).
``--key-min`` names an ABSOLUTE acceptance floor ``KEY=VALUE`` checked
              against NEW alone (BASE not consulted): fails when
              new < value. This is how a paper-style acceptance criterion
              ("session commit throughput >= 2x hand-rolled", ISSUE 4) stays
              enforced even if the committed baseline itself drifts.
``--key-max`` the ceiling counterpart: fails when new > value (e.g.
              "post-churn storage amplification <= 1.2x", ISSUE 5).

Keys may be given multiple times. A key missing from NEW fails (a renamed or
dropped benchmark must update the CI wiring deliberately); a key missing from
BASE is reported and skipped (first run after adding a benchmark). Exit code
is 1 iff any named key regressed by more than ``--max-regress`` (default 25%)
or undershot its ``--key-min`` floor.

Ratio-style keys are the ones worth wiring into CI: they are dimensionless,
so they stay comparable across machines, unlike absolute microseconds.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("base", help="baseline JSON ({row_name: value})")
    ap.add_argument("new", help="candidate JSON")
    ap.add_argument("--key", action="append", default=[],
                    help="lower-is-better key to check (repeatable)")
    ap.add_argument("--key-up", action="append", default=[],
                    help="higher-is-better key to check (repeatable)")
    ap.add_argument("--key-min", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="absolute acceptance floor for a key in NEW "
                         "(repeatable); fails when new < value")
    ap.add_argument("--key-max", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="absolute acceptance ceiling for a key in NEW "
                         "(repeatable); fails when new > value")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    args = ap.parse_args()
    if not (args.key or args.key_up or args.key_min or args.key_max):
        print("bench_compare: no keys named, nothing to check")
        return 0

    def parse_bounds(specs, flag):
        out = []
        for spec in specs:
            key, sep, value = spec.rpartition("=")
            try:
                out.append((key, float(value)))
            except ValueError:
                sep = ""
            if not sep or not key:
                ap.error(f"{flag} expects KEY=VALUE, got {spec!r}")
        return out

    floors = parse_bounds(args.key_min, "--key-min")
    ceilings = parse_bounds(args.key_max, "--key-max")

    with open(args.base) as f:
        base = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    failed = []
    checked = 0
    for key, higher_better in ([(k, False) for k in args.key]
                               + [(k, True) for k in args.key_up]):
        if key not in new:
            print(f"FAIL  {key}: missing from {args.new}")
            failed.append(key)
            checked += 1
            continue
        if key not in base:
            print(f"skip  {key}: not in baseline (new benchmark)")
            continue
        b, n = float(base[key]), float(new[key])
        checked += 1
        if higher_better:
            bad = n < b * (1.0 - args.max_regress)
            change = (b - n) / b if b else 0.0
        else:
            bad = n > b * (1.0 + args.max_regress)
            change = (n - b) / b if b else 0.0
        status = "FAIL" if bad else "ok  "
        arrow = "down" if higher_better else "up"
        print(f"{status}  {key}: base={b:.3f} new={n:.3f} "
              f"({change * 100:+.1f}% {arrow}-is-worse)")
        if bad:
            failed.append(key)

    for bounds, word, worse in ((floors, "floor", lambda n, b: n < b),
                                (ceilings, "ceiling", lambda n, b: n > b)):
        for key, bound in bounds:
            checked += 1
            if key not in new:
                print(f"FAIL  {key}: missing from {args.new}")
                failed.append(key)
                continue
            n = float(new[key])
            bad = worse(n, bound)
            print(f"{'FAIL' if bad else 'ok  '}  {key}: new={n:.3f} "
                  f"(acceptance {word} {bound:.3f})")
            if bad:
                failed.append(key)

    if failed:
        print(f"bench_compare: {len(failed)} of {checked} checked keys "
              f"regressed >{args.max_regress * 100:.0f}% or missed an "
              "acceptance floor/ceiling: " + ", ".join(failed))
        return 1
    print(f"bench_compare: {checked} keys within {args.max_regress * 100:.0f}% "
          "and inside all acceptance bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
