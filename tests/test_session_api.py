"""Agent-session client API (DESIGN.md §12): unified append receipts,
speculative fork transactions, and tailing subscriptions.

The hypothesis suite at the bottom is the acceptance property set for
``Speculation.commit()`` auto-rebase: the speculative suffix is replayed
exactly once (zero-copy), parent records are never lost, and exhausting the
bounded retry budget raises ``ConflictError`` carrying the metadata layer's
fork-point diagnostics.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AppendReceipt, BoltSystem, ConflictError, ForkBlocked,
                        GroupCommitConfig, InvalidOperation, Speculation,
                        UnknownLog)
from repro.core.sim import OpTally

REC = lambda tag, i: f"{tag}{i}".encode()  # noqa: E731


# ----------------------------------------------------------- unified receipts
def test_per_call_receipt_is_resolved_immediately():
    system = BoltSystem(n_brokers=2)
    log = system.create_log("x")
    r = log.append(b"a")
    assert isinstance(r, AppendReceipt)
    assert r.done and r.count == 1
    assert r.position() == 0 and r.positions() == [0]
    assert not r.withheld
    rb = log.append_batch([b"b", b"c"])
    assert rb.done and rb.count == 2 and rb.positions() == [1, 2]


def test_group_commit_receipt_resolves_at_flush():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=100))
    log = system.create_log("x")
    r = log.append(b"a")
    assert isinstance(r, AppendReceipt) and not r.done
    system.flush()
    assert r.done and r.positions() == [0]
    r2 = log.append(b"b")
    assert r2.wait() is r2          # wait() forces the flush itself
    assert r2.position() == 1


def test_receipt_withheld_state_per_call_and_grouped():
    for kwargs in ({}, {"group_commit": 4}):
        system = BoltSystem(n_brokers=2, **kwargs)
        root = system.create_log("root")
        root.append(b"base").wait()
        child = root.cfork(promotable=True)
        r = root.append(b"hidden")
        system.flush()
        assert r.withheld and r.positions() is None and r.position() is None
        child.promote()
        assert root.read(0, 2) == [b"base", b"hidden"]


def test_receipt_legacy_shim_warns_but_works():
    system = BoltSystem(n_brokers=2)
    log = system.create_log("x")
    r = log.append_batch([b"a", b"b"])
    with pytest.warns(DeprecationWarning):
        assert r.result() == [0, 1]
    with pytest.warns(DeprecationWarning):
        assert r == [0, 1]
    with pytest.warns(DeprecationWarning):
        assert log.append(b"c") == 2
    with pytest.warns(DeprecationWarning):
        assert r[1] == 1
    with pytest.warns(DeprecationWarning):
        assert list(r) == [0, 1]
    # receipt-to-receipt comparison is identity, NOT deprecated
    assert r == r and not (r == log.append(b"d"))


# -------------------------------------------- AgileLog.flush (satellite fix)
def test_log_flush_is_scoped_to_this_logs_staged_records():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=100))
    a = system.create_log("a")
    b = system.create_log("b")            # same broker 0 as `a`
    ra = a.append(b"a0")
    b.flush()                             # b has nothing staged: must NOT flush a
    assert not ra.done
    a.flush()                             # a's staged record commits now
    assert ra.done and ra.positions() == [0]
    rb = b.append(b"b0")
    system.flush()                        # global flush still commits everything
    assert rb.done and rb.positions() == [0]


def test_dead_broker_set_initialized_in_constructor():
    system = BoltSystem(n_brokers=3)
    assert system._dead == set()          # no lazy getattr fallbacks (satellite)
    system.fail_broker(1)
    assert system._dead == {1}
    assert system.live_broker(system.brokers[1]) is not system.brokers[1]


# ----------------------------------------------------- tailing subscriptions
def test_subscription_drains_in_batches_and_tracks_cursor():
    system = BoltSystem(n_brokers=2)
    log = system.create_log("x")
    log.append_batch([REC("r", i) for i in range(10)])
    sub = log.subscribe(from_pos=2, batch=3, follow=False)
    batches = list(sub)
    assert batches == [[REC("r", 2), REC("r", 3), REC("r", 4)],
                       [REC("r", 5), REC("r", 6), REC("r", 7)],
                       [REC("r", 8), REC("r", 9)]]
    assert sub.position == 10 and sub.delivered == 8
    assert sub.poll() == []               # caught up
    log.append(b"late")
    assert sub.poll() == [b"late"]        # cursor resumes exactly


def test_subscription_follow_mode_backoff_and_max_idle():
    system = BoltSystem(n_brokers=2)
    log = system.create_log("x")
    waits = []

    def cooperative(idle):                # a producer racing the subscriber
        waits.append(idle)
        if len(waits) == 1:
            log.append(b"pushed")

    sub = log.subscribe(follow=True, max_idle=3, backoff=cooperative)
    assert next(sub) == [b"pushed"]       # idle once, then delivery
    with pytest.raises(StopIteration):    # nothing more: max_idle stops it
        next(sub)
    assert waits == [1, 1, 2]             # max_idle reached before 3rd backoff


def test_subscription_resumed_round_gets_a_fresh_idle_budget():
    """Regression: the idle counter must reset between iteration rounds —
    a resumed follow-mode round (the cursor is a resume token) polls
    max_idle times again instead of stopping instantly."""
    system = BoltSystem(n_brokers=2)
    log = system.create_log("x")
    waits = []
    sub = log.subscribe(follow=True, max_idle=2, backoff=waits.append)
    assert list(sub) == [] and waits == [1]
    log.append(b"r0")
    assert list(sub) == [[b"r0"]] and waits == [1, 1]   # resumed, re-polled
    assert next(iter(sub), None) is None and waits == [1, 1, 1]


def test_subscription_respects_withheld_visibility():
    system = BoltSystem(n_brokers=2)
    root = system.create_log("root")
    root.append_batch([b"a", b"b"])
    sub = root.subscribe(batch=16)
    assert sub.poll() == [b"a", b"b"]
    child = root.cfork(promotable=True)
    root.append(b"hidden")                # §4.1: beyond the fork point
    assert sub.poll() == []               # not visible while the hold is active
    child.promote()
    assert sub.poll() == [b"hidden"]      # delivered after the hold resolves


def test_subscription_validation_errors():
    system = BoltSystem(n_brokers=2)
    log = system.create_log("x")
    with pytest.raises(InvalidOperation):
        log.subscribe(batch=0)
    with pytest.raises(InvalidOperation):
        log.subscribe(from_pos=-1)


def test_consumer_is_built_on_subscription():
    from repro.streams import Consumer, Producer, Topic
    system = BoltSystem(n_brokers=2)
    topic = Topic.create(system, "t")
    prod = Producer(topic, linger_records=8)
    for i in range(20):
        prod.produce({"i": i})
    receipt = prod.flush()
    assert receipt is None or receipt.done
    cons = Consumer(topic)
    got = [r["i"] for batch in cons.stream(follow=False) for r in batch]
    assert got == list(range(20))
    cons.commit()
    assert Consumer.restore(topic).offset == 20


# ------------------------------------------------------- speculation sessions
def test_speculation_commit_without_conflict():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append_batch([b"p0", b"p1"])
    with root.speculate() as s:
        s.append(b"s0")
        s.append_batch([b"s1", b"s2"])
        assert s.suffix_len == 3 and s.fork_point == 2
        assert s.parent_advanced == 0
        res = s.commit()
    assert res.attempts == 1 and res.rebases == 0 and res.replayed == 0
    assert list(res.positions) == [2, 3, 4] and res.log_id == root.log_id
    assert root.read(0, 5) == [b"p0", b"p1", b"s0", b"s1", b"s2"]
    assert system.metadata.state.live_log_ids() == [root.log_id]


def test_speculation_auto_rebase_replays_suffix_zero_copy():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append(b"p0")
    deltas = []
    with root.speculate(on_rebase=lambda s, lo, hi: deltas.append(s.read(lo, hi))
                        or True) as s:
        s.append_batch([b"s0", b"s1"])
        root.append_batch([b"c0", b"c1"])     # producer races the commit
        before = OpTally.capture(system)
        res = s.commit()
        tally = OpTally.capture(system).delta(before)
    assert res.rebases == 1 and res.replayed == 2 and res.attempts == 2
    # the rebase touched NO payload bytes: metadata-only re-appends, no PUTs
    assert tally.puts == 0 and tally.replays == 1
    assert tally.spec_rebases == 1 and tally.spec_replayed == 2
    # the on_rebase hook saw exactly the parent's delta, already inherited
    assert deltas == [[b"c0", b"c1"]]
    # final linearization: suffix lands after every parent record, exactly once
    assert root.read(0, 5) == [b"p0", b"c0", b"c1", b"s0", b"s1"]
    assert system.metadata.check_convergence()


def test_speculation_conflict_error_carries_diagnostics():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append(b"p0")

    def adversary(s, lo, hi):             # keeps the parent ahead forever
        root.append(b"a")
        return True

    with pytest.raises(ConflictError) as ei:
        with root.speculate(max_rebases=2, on_rebase=adversary) as s:
            s.append(b"s0")
            root.append(b"c0")
            s.commit()
    e = ei.value
    assert e.attempts == 3                # 1 + max_rebases
    assert e.log_id == root.log_id and e.advanced >= 1
    assert e.parent_tail is not None and e.parent_tail > e.expected
    assert e.fork_point is not None and e.holds_epoch is not None
    # nothing lost, nothing leaked: parent kept every producer record, the
    # speculative suffix is gone, and the fork was squashed
    assert root.read(0, root.tail) == [b"p0", b"c0", b"a", b"a"]
    assert system.metadata.state.live_log_ids() == [root.log_id]
    assert system.metadata.check_convergence()


def test_speculation_on_rebase_veto_aborts():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append(b"p0")
    with pytest.raises(ConflictError):
        with root.speculate(on_rebase=lambda s, lo, hi: False) as s:
            s.append(b"s0")
            root.append(b"c0")
            s.commit()
    assert root.read(0, 2) == [b"p0", b"c0"]
    assert system.metadata.state.live_log_ids() == [root.log_id]


def test_speculation_losing_a_promote_race_rebases_onto_the_merge():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append(b"p0")
    a = root.speculate()
    b = root.speculate()                  # same fork point: both allowed
    a.append(b"A")
    b.append(b"B")
    assert a.commit().rebases == 0
    res = b.commit()                      # fork squashed by a's win -> rebase
    assert res.rebases == 1
    assert root.read(0, 3) == [b"p0", b"A", b"B"]
    assert system.spec_stats.commits == 2 and system.spec_stats.conflicts == 1


def test_speculation_abort_paths():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append(b"p0")
    # explicit abort
    with root.speculate() as s:
        s.append(b"junk")
        s.abort()
    assert root.tail == 1
    # implicit abort on exception
    with pytest.raises(RuntimeError):
        with root.speculate() as s:
            s.append(b"junk")
            raise RuntimeError("agent crashed")
    assert root.tail == 1
    # implicit abort on clean exit without commit (must release the hold)
    with root.speculate() as s:
        s.append(b"junk")
    assert root.tail == 1
    assert root.read(0, 1) == [b"p0"]     # no hold left: read succeeds
    assert system.metadata.state.live_log_ids() == [root.log_id]
    # a closed session rejects further use
    with pytest.raises(InvalidOperation):
        s.commit()
    with pytest.raises(InvalidOperation):
        s.append(b"late")
    s.abort()                             # idempotent once closed


def test_non_promotable_speculation_is_a_sandbox():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append(b"p0")
    with root.speculate(promotable=False) as s:
        s.append(b"what-if")
        root.append(b"p1")                # no hold: positions assigned
        assert root.read(0, 2) == [b"p0", b"p1"]
        assert s.read(0, 3) == [b"p0", b"what-if", b"p1"]
        with pytest.raises(InvalidOperation):
            s.commit()
    assert system.metadata.state.live_log_ids() == [root.log_id]
    with pytest.raises(InvalidOperation):
        root.speculate(promotable=False, on_rebase=lambda s, lo, hi: True)


def test_speculation_under_group_commit_flushes_suffix_at_commit():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=1000))
    root = system.create_log("root")
    root.append(b"p0")
    with root.speculate() as s:
        r = s.append(b"s0")
        assert not r.done                 # staged, not yet sequenced
        root.append(b"c0")                # staged on the parent's broker
        res = s.commit()                  # waits the suffix, then promotes
    assert r.done
    assert res.count == 1 and res.rebases == 0
    # pinned semantics: a STAGED parent append is not sequenced until its
    # broker flushes (DESIGN.md §9) — the commit linearizes before it, so it
    # conflicts with nothing and lands after the promoted suffix at flush
    assert root.read(0, root.tail) == [b"p0", b"s0", b"c0"]
    assert system.metadata.check_convergence()


def test_promote_if_outcomes_are_deterministic_and_replayable():
    system = BoltSystem(n_brokers=2, n_meta_replicas=3, snapshot_every=4)
    root = system.create_log("root")
    root.append(b"p0")
    # drive conflicts + rebases across snapshot boundaries, then crash/recover
    for i in range(3):
        with root.speculate() as s:
            s.append(REC("s", i))
            root.append(REC("c", i))
            assert s.commit().rebases == 1
    victim = next(r.rid for r in system.metadata.replicas
                  if r.rid != system.metadata.leader_id)
    system.metadata.fail_replica(victim)
    with root.speculate() as s:
        s.append(b"post-crash")
        s.commit()
    system.metadata.recover_replica(victim)
    assert system.metadata.check_convergence()
    want = root.read(0, root.tail)
    system.metadata.fail_replica(system.metadata.leader_id)
    assert root.read(0, root.tail) == want


# ------------------------------------------------ acceptance property suite
@given(prefill=st.integers(0, 3),
       suffix_batches=st.lists(st.integers(1, 3), min_size=1, max_size=3),
       pre_commit_appends=st.integers(0, 2),
       adversary=st.lists(st.integers(0, 2), min_size=0, max_size=4),
       max_rebases=st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_speculation_commit_rebase_properties(prefill, suffix_batches,
                                              pre_commit_appends, adversary,
                                              max_rebases):
    """Acceptance properties for auto-rebase (ISSUE 4):

    * the speculative suffix appears in the committed parent EXACTLY once,
      contiguously at the tail, in append order;
    * parent records are never lost (every producer append survives, in
      order, below the suffix), commit or abort alike;
    * when the adversary outruns ``max_rebases``, commit raises
      ``ConflictError`` with fork-point/tail diagnostics and the metadata
      forest is left clean (fork squashed, replicas converged).
    """
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    produced = []

    def produce(k):
        for _ in range(k):
            rec = REC("c", len(produced))
            produced.append(rec)
            root.append(rec)

    produce(prefill)
    schedule = list(adversary)

    def on_rebase(s, lo, hi):
        # the delta the rebase skipped over is exactly what the producer
        # appended since the previous fork point
        assert s.read(lo, hi) == produced[lo:hi]
        if schedule:
            produce(schedule.pop(0))
        return True

    suffix = []
    spec = root.speculate(max_rebases=max_rebases, on_rebase=on_rebase)
    for j, k in enumerate(suffix_batches):
        batch = [REC(f"s{j}_", i) for i in range(k)]
        suffix.extend(batch)
        spec.append_batch(batch)
    produce(pre_commit_appends)

    try:
        res = spec.commit()
    except ConflictError as e:
        assert e.attempts == max_rebases + 1
        assert e.parent_tail is None or e.parent_tail >= e.expected
        committed = False
    else:
        committed = True
        assert res.count == len(suffix)
        assert res.rebases <= max_rebases
        assert res.replayed == res.rebases * len(suffix)

    content = root.read(0, root.tail)
    if committed:
        # suffix exactly once, contiguous, at the tail; producers below it
        assert content == produced + suffix
        assert list(res.positions) == list(range(len(produced),
                                                 len(produced) + len(suffix)))
    else:
        assert content == produced        # suffix fully squashed, nothing lost
    assert system.metadata.state.live_log_ids() == [root.log_id]
    assert system.metadata.check_convergence()
