"""Slow-lane smoke for the end-to-end examples: each one must run to
completion as a real subprocess (its own interpreter, PYTHONPATH=src), the
way CI and a new user invoke it. The examples assert their own invariants
(exact speculative decode, durable serve cursor, crash/resume), so a zero
exit code is the contract."""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_example(name, *extra):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / name), *extra],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"{name} failed:\n--- stdout ---\n{proc.stdout[-2000:]}"
        f"\n--- stderr ---\n{proc.stderr[-2000:]}")
    return proc.stdout


@pytest.mark.slow
def test_serve_example_smoke():
    out = _run_example("serve.py")
    assert "engine served 4 requests" in out
    assert "byte-identical to sequential" in out


@pytest.mark.slow
def test_train_e2e_example_smoke():
    out = _run_example("train_e2e.py", "--steps", "60")
    assert "resumed from step" in out
    assert "phase2 final" in out
