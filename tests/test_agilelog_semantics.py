"""Property tests: Bolt (all variants) vs the brute-force oracle model.

Random operation traces (append / cFork / sFork / read / promote / squash) are
replayed on both systems; every observable — returned positions, read contents,
tails, and *which operations error* — must match. This is the linearizable-
interleaving guarantee of §4.1 plus the blocking rules of §5.6, end to end.
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BoltSystem
from repro.core.errors import AgileLogError
from repro.core.oracle import OracleModel


class TraceRunner:
    def __init__(self, seed: int, **bolt_kwargs):
        self.rng = random.Random(seed)
        self.bolt = BoltSystem(n_brokers=3, **bolt_kwargs)
        self.oracle = OracleModel()
        root = self.bolt.create_log("root")
        oroot = self.oracle.create_root("root")
        self.handles = {oroot: root}      # oracle id -> AgileLog handle
        self.live = [oroot]
        self.rec_counter = 0

    def _both(self, bolt_fn, oracle_fn):
        b_res = b_err = o_res = o_err = None
        try:
            b_res = bolt_fn()
        except AgileLogError as e:
            b_err = type(e).__name__
        try:
            o_res = oracle_fn()
        except AgileLogError as e:
            o_err = type(e).__name__
        assert (b_err is None) == (o_err is None), \
            f"error mismatch: bolt={b_err or b_res!r} oracle={o_err or o_res!r}"
        return b_res, o_res, b_err

    def step(self):
        rng = self.rng
        lid = rng.choice(self.live)
        h = self.handles[lid]
        op = rng.random()
        if op < 0.35:
            k = rng.randint(1, 3)
            recs = [f"r{self.rec_counter + i}".encode() for i in range(k)]
            self.rec_counter += k
            b, o, err = self._both(lambda: h.append_batch(recs).positions(),
                                   lambda: self.oracle.append(lid, recs))
            if err is None:
                assert b == o, f"append positions mismatch: {b} vs {o}"
        elif op < 0.5:
            promotable = rng.random() < 0.4
            b, o, err = self._both(lambda: h.cfork(promotable=promotable),
                                   lambda: self.oracle.cfork(lid, promotable))
            if err is None:
                self.handles[o] = b
                self.live.append(o)
        elif op < 0.6:
            past = None
            if rng.random() < 0.4 and self.oracle.tail(lid) > 0:
                past = rng.randrange(self.oracle.tail(lid))
            b, o, err = self._both(lambda: h.sfork(past=past),
                                   lambda: self.oracle.sfork(lid, past))
            if err is None:
                self.handles[o] = b
                self.live.append(o)
        elif op < 0.85:
            tail = self.oracle.tail(lid)
            lo = rng.randint(0, max(0, tail))
            hi = rng.randint(lo, max(lo, tail))
            b, o, err = self._both(lambda: h.read(lo, hi),
                                   lambda: self.oracle.read(lid, lo, hi))
            if err is None:
                assert b == o, f"read mismatch on log {lid} [{lo},{hi})"
        elif op < 0.93:
            mode = rng.choice(["copy", "splice"])
            b, o, err = self._both(lambda: h.promote(mode=mode),
                                   lambda: self.oracle.promote(lid))
            if err is None:
                self._drop_dead()
        else:
            b, o, err = self._both(lambda: h.squash(),
                                   lambda: self.oracle.squash(lid))
            if err is None:
                self._drop_dead()
        self._check_tails()

    def _drop_dead(self):
        self.live = [l for l in self.live if l in self.oracle.logs]
        for l in list(self.handles):
            if l not in self.oracle.logs:
                del self.handles[l]

    def _check_tails(self):
        for l in self.live:
            bt = self.handles[l].tail
            ot = self.oracle.tail(l)
            assert bt == ot, f"tail mismatch on log {l}: bolt={bt} oracle={ot}"
            assert self.handles[l].visible_tail == self.oracle.visible_tail(l)

    def final_check(self):
        for l in self.live:
            vt = self.oracle.visible_tail(l)
            try:
                b = self.handles[l].read(0, vt)
                o = self.oracle.read(l, 0, vt)
                assert b == o, f"final read mismatch on log {l}"
            except AgileLogError:
                pass  # capped by an ancestor hold: both rejected (checked in step)


VARIANTS = [
    dict(cf_mode="ltt", fork_mode="zerocopy", promote_mode="copy"),
    dict(cf_mode="ltt", fork_mode="zerocopy", promote_mode="splice"),
    dict(cf_mode="eager", fork_mode="zerocopy", promote_mode="copy"),
]


@pytest.mark.parametrize("variant", VARIANTS,
                         ids=["bolt-copy", "bolt-splice", "eager-tails"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=25, deadline=None)
def test_bolt_matches_oracle(variant, seed):
    runner = TraceRunner(seed, **variant)
    for _ in range(60):
        runner.step()
    runner.final_check()


def test_bolt_long_trace():
    runner = TraceRunner(7, cf_mode="ltt", promote_mode="splice")
    for _ in range(800):
        runner.step()
    runner.final_check()


def test_naive_cf_variant_short_trace():
    """BoltNaiveCF duplicates entries; promote unsupported there (ablation-only),
    so replay traces without promote/squash-sensitive ops."""
    rng = random.Random(3)
    bolt = BoltSystem(n_brokers=3, cf_mode="naive")
    oracle = OracleModel()
    root = bolt.create_log("root")
    oroot = oracle.create_root("root")
    handles = {oroot: root}
    live = [oroot]
    for i in range(200):
        lid = rng.choice(live)
        h = handles[lid]
        r = rng.random()
        if r < 0.5:
            recs = [f"n{i}".encode()]
            assert h.append_batch(recs).positions() == oracle.append(lid, recs)
        elif r < 0.7:
            b = h.cfork()
            o = oracle.cfork(lid, False)
            handles[o] = b
            live.append(o)
        else:
            t = oracle.tail(lid)
            lo = rng.randint(0, t)
            hi = rng.randint(lo, t)
            assert h.read(lo, hi) == oracle.read(lid, lo, hi)
    for l in live:
        assert handles[l].tail == oracle.tail(l)
        assert handles[l].read(0, oracle.tail(l)) == oracle.read(l, 0, oracle.tail(l))
