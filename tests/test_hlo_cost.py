"""Validate the trip-count-aware HLO cost model on hand-computable cases."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze

pytestmark = pytest.mark.slow  # JAX tracing/compilation; fast lane: -m 'not slow'


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(txt)


W = jax.ShapeDtypeStruct((512, 512), jnp.float32)
X = jax.ShapeDtypeStruct((256, 512), jnp.float32)
MM_FLOPS = 2 * 256 * 512 * 512


def test_single_matmul():
    c = _cost(lambda w, x: x @ w, W, X)
    assert c.flops == pytest.approx(MM_FLOPS, rel=0.02)


def test_scan_multiplies_by_trip_count():
    def fn(w, x):
        def body(cr, _):
            return jnp.tanh(cr @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(out)
    c = _cost(fn, W, X)
    assert c.flops == pytest.approx(7 * MM_FLOPS, rel=0.02)


def test_nested_scan():
    def fn(w, x):
        def outer(cr, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, cr, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return jnp.sum(out)
    c = _cost(fn, W, X)
    assert c.flops == pytest.approx(15 * MM_FLOPS, rel=0.02)


def test_grad_counts_fwd_and_bwd():
    def fn(w, x):
        return jnp.sum(jnp.tanh(x @ w))
    c = _cost(jax.grad(fn, argnums=(0, 1)), W, X)
    # fwd + dW + dX = 3 matmuls
    assert c.flops == pytest.approx(3 * MM_FLOPS, rel=0.02)


def test_grad_of_scan():
    def fn(w, x):
        def body(cr, _):
            return jnp.tanh(cr @ w), None
        out, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(out)
    c = _cost(jax.grad(fn), W, X)
    # per step: fwd dot + dcarry dot + dW dot = 3; total 12 matmuls
    assert c.flops == pytest.approx(12 * MM_FLOPS, rel=0.05)


def test_collectives_counted_with_trips():
    from repro.launch.mesh import activate_mesh, make_mesh
    mesh = make_mesh((len(jax.devices()),), ("model",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = len(jax.devices())
    xs = jax.ShapeDtypeStruct((8, 64 * n), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, "model")))

    def fn(x):
        def body(cr, _):
            return cr + jnp.sum(x, axis=1, keepdims=True), None  # all-reduce
        out, _ = jax.lax.scan(body, jnp.zeros((8, 1)), None, length=5)
        return out
    with activate_mesh(mesh):
        txt = jax.jit(fn).lower(xs).compile().as_text()
    c = analyze(txt)
    if n > 1:
        assert "all-reduce" in c.coll
        assert c.coll["all-reduce"][0] >= 5  # counted per trip
