"""Compaction-epoch equivalence harness + cold-tiering safety (DESIGN.md §14).

The §13 harness proves GC never deletes a reachable byte; this file proves the
stronger §14 contract: a *compaction epoch* — candidate selection, live-span
ranged reads, the compacted-object PUT, the consensus ``compact`` swap, the
source reap, and any tier demotion/promotion around it — is **byte-invisible**
to every reader. Concretely:

* **Epoch equivalence** — under arbitrary fork/append/promote/squash/
  speculate/gc/compact interleavings (group-commit multi-log segments and
  mid-scan readers included), every live log reads byte-identically across
  every epoch boundary, and the byte-granular manifests always equal a
  from-scratch recount.
* **Byte liveness** — after churn quiesces, GC drains, and compaction drains,
  resident data bytes exceed the live-byte union by at most the configured
  residual (1/max_live_ratio); the §13 object-level predicate cannot see this
  leak at all (``test_oracle_byte_bound_catches_the_seed_leak``).
* **Fault injection** — a compactor crash between the PUT and the swap
  (orphan swept by resync), between the swap and the reap (sources reclaimed
  by any later quantum), a stale swap (liveness moved underneath the
  compactor), leader failover and snapshot install with compaction state in
  flight — replicas must converge on identical byte manifests and cold sets.
* **Tiering** — demoted objects read byte-identically through the slow store
  class, scans promote cold ranges back, point reads do not, and the DES
  tally splits hot from cold traffic.
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BoltSystem, CompactionConfig, FaultConfig,
                        GroupCommitConfig, TieredObjectStore, TieringConfig)
from repro.core.errors import AgileLogError, StoreFault
from repro.core.objectstore import MemoryObjectStore
from repro.core.oracle import (check_manifest_audit, check_storage_liveness,
                               check_storage_safety, live_byte_union,
                               recount_object_ref_bytes)
from repro.core.sim import OpTally

from test_gc_safety import GCTraceRunner

#: residual amplification ceiling once compaction drains at the default 0.85
#: live-ratio threshold: every surviving object is individually > 85% live
RESIDUAL_AMP = 1.0 / CompactionConfig().max_live_ratio + 1e-9


def _data_objects(system):
    return [k for k in system.store.list()
            if k.startswith(("obj-", "seg-", "cmp-"))]


def _churn_multi_log(system, root, rounds=3, losers=2):
    """Group-commit churn that leaves shared segments partially live: each
    round stages one surviving speculation and ``losers`` aborted ones into
    the SAME segment, so every segment keeps a live slice after the abort."""
    for rnd in range(rounds):
        winner = root.speculate()
        for i in range(8):
            winner.append(f"w{rnd}-{i}".encode() * 16)
        dead = [root.speculate() for _ in range(losers)]
        for j, spec in enumerate(dead):
            for i in range(8):
                spec.append(f"l{rnd}-{j}-{i}".encode() * 16)
        system.flush()
        winner.commit()
        for spec in dead:
            spec.abort()
    system.flush()
    system.gc()


# ---------------------------------------------------------------------------
# the tentpole, directed: swap correctness + amplification drop
# ---------------------------------------------------------------------------

def test_compact_swap_is_byte_invisible_and_bounds_amplification():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        gc=True)
    root = system.create_log("r")
    for i in range(20):
        root.append(f"base-{i:03d}".encode() * 8)
    _churn_multi_log(system, root)
    state = system.metadata.state
    live = sum(live_byte_union(state).values())
    assert system.store.total_bytes / live > 1.2   # the leak is real pre-swap
    before = root.read(0, root.tail)
    stats = system.compact()
    assert stats.compacted_objects >= 1 and stats.sources_retired >= 1
    assert stats.bytes_written < stats.bytes_written + 1  # counters populated
    system.gc()
    assert root.read(0, root.tail) == before       # epoch equivalence
    check_manifest_audit(state)
    check_storage_safety(system)
    check_storage_liveness(system, max_byte_amplification=1.2)
    assert system.metadata.check_convergence()
    # the compacted object is fully live: not a candidate for re-compaction
    assert system.compact_stats.candidates == 0


def test_compact_preserves_frozen_chains_and_sforks():
    """The swap rewrites every referencing index — frozen stand-ins and
    sfork prefix copies included — in one atomic command; a frozen snapshot
    must keep reading identical bytes through the compacted object."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        gc=True)
    root = system.create_log("r")
    root.append(b"r0").wait()
    keeper = root.cfork()                          # siblings co-locate (§5.7):
    goner = root.cfork()                           # their appends share segments
    for i in range(8):
        keeper.append(f"k{i}".encode() * 16)
    goner.append(b"dead-weight" * 24)
    system.flush()                                 # ONE segment, both forks
    snap = keeper.sfork(past=4)                    # prefix copy of the segment
    keeper.squash()                                # freezes: snap depends on it
    goner.squash()                                 # its slice is dead weight
    system.gc()
    before_root, before_snap = root.read(0, root.tail), snap.read(0, snap.tail)
    assert system.compact().sources_retired >= 1
    system.gc()
    assert root.read(0, root.tail) == before_root
    assert snap.read(0, snap.tail) == before_snap  # via the frozen stand-in
    check_manifest_audit(system.metadata.state)
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)


def test_compact_rewrites_naive_index_entries_too():
    system = BoltSystem(cf_mode="naive", gc=True,
                        group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    root.append(b"n0").wait()
    keeper = root.cfork()                          # naive mode copies eagerly
    goner = root.cfork()                           # co-located sibling
    for i in range(8):
        keeper.append(f"n{i}".encode() * 8)
    goner.append(b"dead-weight" * 16)
    system.flush()                                 # ONE segment, both forks
    goner.squash()
    system.gc()
    before = keeper.read(0, keeper.tail)
    assert system.compact().compacted_objects >= 1
    system.gc()
    assert keeper.read(0, keeper.tail) == before
    check_manifest_audit(system.metadata.state)
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)


def test_mid_scan_reader_survives_a_full_epoch():
    """A scan paused mid-way re-resolves its remaining batches after the
    sources it started on were compacted away, reaped, and the compacted
    object demoted cold — and still yields the original bytes."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        gc=True, tiering=TieringConfig(min_age=1))
    root = system.create_log("r")
    want = [f"rec-{i:04d}".encode() * 4 for i in range(60)]
    for rec in want:
        root.append(rec)
    _churn_multi_log(system, root, rounds=2, losers=2)
    want = root.read(0, root.tail)
    it = root.scan(batch=7)
    got = [next(it) for _ in range(25)]            # cursor parked mid-segment
    assert system.compact().sources_retired >= 1   # epoch under the scan
    system.gc()
    system.demote()
    got.extend(it)                                 # remaining batches re-resolve
    assert got == want
    check_storage_safety(system)


def test_compactor_excludes_open_session_receipt_segments():
    """A rebase replays receipt (object, offsets) tuples verbatim, so the
    compactor must skip segments an open speculation's receipts reference —
    and pick them up once the session closes."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    root.append(b"base").wait()
    spec = root.speculate()
    spec.append(b"suffix-kept" * 8)
    loser = root.cfork()
    loser.append(b"loser-bytes" * 24)
    system.flush()                                 # ONE shared segment
    loser.squash()                                 # segment now partially live
    system.gc()
    seg = {s[0] for r in spec._suffix
           if (s := r._pending.segment) is not None}
    assert seg and seg <= set(system._session_segments())
    assert not (seg & set(system.compactor.candidates()))
    assert system.compact_quantum() == []          # nothing eligible
    root.append(b"conflict").wait()                # force a rebase on commit
    res = spec.commit()
    assert res.rebases == 1
    assert root.read(0, root.tail)[-1] == b"suffix-kept" * 8
    system.gc()
    # session closed: the (re-indexed) segments are fair game again
    before = root.read(0, root.tail)
    system.compact()
    system.gc()
    assert root.read(0, root.tail) == before
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)


def test_compaction_candidates_honor_reaper_pins():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    root.append(b"base").wait()
    keeper = root.cfork()
    goner = root.cfork()                           # co-located siblings
    keeper.append(b"live-bytes" * 16)
    goner.append(b"pinned-dead-weight" * 16)
    system.flush()                                 # shared segment
    goner.squash()
    system.gc()
    cands = system.compactor.candidates()
    assert cands
    system.collector.pin(cands)
    try:
        assert not set(cands) & set(system.compactor.candidates())
    finally:
        system.collector.unpin(cands)
    assert set(cands) <= set(system.compactor.candidates())


# ---------------------------------------------------------------------------
# oracle regression (satellite): the byte bound catches the seed leak
# ---------------------------------------------------------------------------

def test_oracle_byte_bound_catches_the_seed_leak():
    """Pre-compaction, group-commit churn leaves the store ~2x over the
    live-byte union while the §13 object-level liveness predicate passes —
    the regression the live-BYTE bound exists to catch."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        gc=True)
    root = system.create_log("r")
    for i in range(20):
        root.append(f"b{i}".encode() * 8)
    _churn_multi_log(system, root)
    check_storage_liveness(system)                 # object-level: blind to it
    with pytest.raises(AssertionError, match="amplification"):
        check_storage_liveness(system, max_byte_amplification=1.2)
    system.compact()
    system.gc()
    check_storage_liveness(system, max_byte_amplification=1.2)


def test_byte_manifest_recount_matches_incremental_accounting():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    for i in range(12):
        root.append(f"x{i}".encode() * (1 + i % 4))
    fork = root.cfork()
    fork.append(b"fork" * 8)
    system.flush()
    snap = root.sfork(past=5)
    state = system.metadata.state
    want = recount_object_ref_bytes(state)
    got = {k: v for k, v in state.object_ref_bytes.items() if v > 0}
    assert got == want
    fork.squash()
    snap.squash()
    system.gc()
    check_manifest_audit(state)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_crash_after_put_before_swap_orphan_swept_by_resync():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    root.append(b"base").wait()
    _churn_multi_log(system, root, rounds=2)
    plan = system.compactor._plan()
    assert plan is not None
    new_object_id, payload, _mapping, _n_gets = plan
    system.store.put(new_object_id, payload)       # ...and the compactor dies
    state = system.metadata.state
    assert new_object_id not in state.object_refs  # consensus never saw it
    swept = system.compactor.resync()
    assert swept == [new_object_id]
    assert not system.store.exists(new_object_id)
    before = root.read(0, root.tail)
    system.compact()                               # restarted compactor works
    system.gc()
    assert root.read(0, root.tail) == before
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)
    assert system.compact_stats.orphans_swept == 1


def test_injected_torn_cmp_put_swept_by_compactor_resync():
    """§15 x §14: the compactor's cmp-* PUT tears (injected prefix write +
    StoreFault) before the swap proposal — the carcass key is unknown to
    consensus, reads stay on the sources, and after healing the compactor's
    resync sweeps it and a re-run compacts cleanly."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        faults=FaultConfig(seed=29))
    root = system.create_log("r")
    root.append(b"base").wait()
    _churn_multi_log(system, root, rounds=2)
    before = root.read(0, root.tail)
    system.faults.config.store_put_torn = 1.0   # arm ONLY the cmp-* PUT
    with pytest.raises(StoreFault):
        system.compact_quantum()
    system.faults.config.store_put_torn = 0.0
    carcasses = [k for k in system.store.list("cmp-")
                 if k not in system.metadata.state.object_refs]
    assert carcasses                            # the torn prefix landed
    assert root.read(0, root.tail) == before    # reads never left the sources
    check_storage_safety(system)
    system.faults.heal()
    swept = system.compactor.resync()
    assert sorted(swept) == sorted(carcasses)
    system.compact()                            # restarted compactor works
    system.gc()
    assert root.read(0, root.tail) == before
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)
    assert system.metadata.check_convergence()


def test_crash_after_swap_before_reap_sources_reclaimed_on_next_quantum():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        compaction=CompactionConfig(reap=False))
    root = system.create_log("r")
    root.append(b"base").wait()
    _churn_multi_log(system, root, rounds=2)
    before = root.read(0, root.tail)
    retired = system.compact_quantum()             # swap commits; reap=False
    assert retired                                 # ...and the compactor dies
    assert all(system.store.exists(o) for o in retired)   # not yet reaped
    assert root.read(0, root.tail) == before       # reads already on cmp-*
    check_storage_safety(system)
    system.gc()                                    # ANY later quantum finishes
    assert all(not system.store.exists(o) for o in retired)
    system.compact()
    system.gc()
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)
    assert system.metadata.check_convergence()


def test_stale_swap_mutates_nothing_and_orphans_the_new_object():
    """Liveness moved between the plan and the proposal: the swap must
    reject wholesale, leave every index untouched, and enqueue the
    just-PUT compacted object on the §13 zero-ref orphan path."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    root.append(b"base").wait()
    keeper = root.cfork()
    goner = root.cfork()                           # co-located siblings
    keeper.append(b"kept" * 8)
    goner.append(b"doomed" * 32)
    system.flush()                                 # shared segment
    goner.squash()
    system.gc()
    plan = system.compactor._plan()
    assert plan is not None
    new_object_id, payload, mapping, _ = plan
    system.store.put(new_object_id, payload)
    # the race: a RIVAL compactor quantum retires the same sources first —
    # by the time this proposal lands, they are no longer compactable
    sources = [src for src, _ranges in mapping]
    winner = system.compactor._plan(sources=sources)
    w_id, w_payload, w_mapping, _ = winner
    system.store.put(w_id, w_payload)
    assert system.metadata.propose(
        ("compact", w_id, len(w_payload), w_mapping))[0] == "ok"
    outcome = system.metadata.propose(
        ("compact", new_object_id, len(payload), mapping))
    assert outcome[0] == "stale"
    state = system.metadata.state
    assert state.object_refs.get(new_object_id) == 0   # orphan, queued
    before = root.read(0, root.tail)
    system.gc()
    assert not system.store.exists(new_object_id)
    assert root.read(0, root.tail) == before
    check_manifest_audit(state)
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)


def test_leader_failover_with_compaction_in_flight_converges():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        n_meta_replicas=3, gc=True,
                        tiering=TieringConfig(min_age=1))
    root = system.create_log("r")
    root.append(b"keep").wait()
    _churn_multi_log(system, root, rounds=2)
    before = root.read(0, root.tail)
    assert system.compact_quantum()                # one swap committed...
    system.metadata.fail_replica(system.metadata.leader_id)   # ...then failover
    assert root.read(0, root.tail) == before
    _churn_multi_log(system, root, rounds=1)
    before = root.read(0, root.tail)
    system.compact()
    system.gc()
    system.demote()
    assert root.read(0, root.tail) == before
    assert system.metadata.check_convergence()
    check_manifest_audit(system.metadata.state)
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)


def test_snapshot_install_with_compaction_state_converges():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        n_meta_replicas=3, snapshot_every=8, gc=True,
                        tiering=TieringConfig(min_age=1))
    root = system.create_log("r")
    root.append(b"keep").wait()
    _churn_multi_log(system, root, rounds=1)
    victim = (system.metadata.leader_id + 1) % 3
    system.metadata.fail_replica(victim)
    # compaction + demotion while the replica is down
    system.compact()
    system.gc()
    system.demote()
    _churn_multi_log(system, root, rounds=1)
    system.compact()
    system.gc()
    system.metadata.recover_replica(victim)        # snapshot + suffix replay
    r = system.metadata.replicas[victim]
    leader = system.metadata.state
    assert r.state.object_ref_bytes == leader.object_ref_bytes
    assert r.state.object_bytes == leader.object_bytes
    assert r.state.cold_objects == leader.cold_objects
    assert r.state.compact_epoch == leader.compact_epoch
    assert system.metadata.check_convergence()
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)


def test_convergence_digest_covers_compaction_and_tiering_state():
    system = BoltSystem(n_brokers=2)
    root = system.create_log("r")
    root.append(b"a")
    assert system.metadata.check_convergence()
    follower = next(r for r in system.metadata.replicas
                    if r.rid != system.metadata.leader_id)
    follower.apply_pending()
    obj = next(iter(follower.state.object_ref_bytes))
    follower.state.object_ref_bytes[obj] += 1      # byte-manifest drift only
    assert not system.metadata.check_convergence()
    follower.state.object_ref_bytes[obj] -= 1
    assert system.metadata.check_convergence()
    follower.state.cold_objects.add(obj)           # placement drift only
    assert not system.metadata.check_convergence()


# ---------------------------------------------------------------------------
# cold tiering (satellite)
# ---------------------------------------------------------------------------

def _tiered_with_cold_object():
    """Churned system with one compacted object demoted cold; returns
    (system, root, cold_object_id, pre-demotion bytes of the whole log)."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        gc=True, tiering=TieringConfig(min_age=1,
                                                       promote_scan_records=4))
    root = system.create_log("r")
    for i in range(10):
        root.append(f"hot-{i}".encode() * 8)
    _churn_multi_log(system, root, rounds=2)
    system.compact()
    system.gc()
    want = root.read(0, root.tail)
    demoted = system.demote_quantum()
    assert demoted
    # the pre-demotion read warmed the broker page cache; drop those pages so
    # the next read genuinely exercises the cold store class
    for b in system.brokers:
        b.cache.invalidate_object(demoted[0])
    return system, root, demoted[0], want


def test_demoted_object_reads_byte_identical_through_the_cold_class():
    system, root, cold_obj, want = _tiered_with_cold_object()
    store = system.store
    assert store.is_cold(cold_obj)
    assert store.cold_stored_bytes < store.cold_logical_bytes  # compressed
    got = root.read(0, root.tail)
    assert got == want                             # byte-identical via zlib
    assert store.cold_gets > 0                     # served by the slow class


def test_scan_over_cold_range_promotes_back_to_hot():
    system, root, cold_obj, want = _tiered_with_cold_object()
    store = system.store
    assert root.read(0, root.tail) == want         # scan-shaped (>= 4 records)
    assert not store.is_cold(cold_obj)             # physically promoted
    assert cold_obj not in system.metadata.state.cold_objects   # and by consensus
    assert system.tier_stats.rehydrations >= 1
    assert root.read(0, root.tail) == want         # now hot, still identical
    check_storage_safety(system)


def test_point_read_does_not_promote():
    system, root, cold_obj, want = _tiered_with_cold_object()
    store = system.store
    # a position inside the compacted (now cold) object: the churn suffix
    pos = root.tail - 1
    assert root.read(pos, pos + 1) == want[pos:pos + 1]
    assert store.is_cold(cold_obj)                 # 1 record < scan threshold
    assert cold_obj in system.metadata.state.cold_objects
    assert system.tier_stats.rehydrations == 0


def test_tally_splits_hot_and_cold_traffic():
    system, root, cold_obj, want = _tiered_with_cold_object()
    t0 = OpTally.capture(system)
    assert root.read(0, root.tail) == want
    d = OpTally.capture(system).delta(t0)
    assert d.cold_gets > 0 and d.bytes_get_cold > 0
    assert d.gets >= d.cold_gets                   # cold is a subset of GETs
    assert d.bytes_get >= d.bytes_get_cold
    full = OpTally.capture(system)
    assert full.cold_demotions >= 1 and full.bytes_demoted > 0


def test_tier_resync_converges_placement_to_consensus():
    system, root, cold_obj, want = _tiered_with_cold_object()
    store = system.store
    # drift A: physically promote without consensus (crash mid-promotion)
    store.rehydrate(cold_obj)
    store.drop_cold(cold_obj)
    assert not store.is_cold(cold_obj)
    assert cold_obj in system.metadata.state.cold_objects
    fixed = system.tiers.resync()
    assert fixed == 1 and store.is_cold(cold_obj)
    # drift B: consensus promoted but the physical move never happened
    system.metadata.propose(("promote_hot", (cold_obj,)))
    assert store.is_cold(cold_obj)
    fixed = system.tiers.resync()
    assert fixed == 1 and not store.is_cold(cold_obj)
    assert root.read(0, root.tail) == want         # correct at every point
    check_storage_safety(system)


def test_reaped_cold_object_releases_both_tiers():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=10_000),
                        gc=True, tiering=TieringConfig(min_age=1))
    root = system.create_log("r")
    root.append(b"keep").wait()
    _churn_multi_log(system, root, rounds=1)
    system.compact()
    system.gc()
    demoted = system.demote_quantum()
    assert demoted
    # kill the lineage holding the compacted object: squash + promote churn
    # until its refs die, then gc must clear the cold copy and the consensus
    # placement record together
    state = system.metadata.state
    snap = root.sfork()                            # keeps only a prefix alive?
    snap.squash()
    # directly retire via a second compaction of the cold object's spans
    before = root.read(0, root.tail)
    system.metadata.propose(("promote_hot", tuple(demoted)))
    system.tiers.resync()
    plan = system.compactor._plan(sources=demoted)
    if plan is not None:
        new_id, payload, mapping, _ = plan
        system.store.put(new_id, payload)
        assert system.metadata.propose(
            ("compact", new_id, len(payload), mapping))[0] == "ok"
    system.gc()
    for obj in demoted:
        assert not system.store.exists(obj)
        assert obj not in state.cold_objects
    assert root.read(0, root.tail) == before
    check_manifest_audit(state)
    check_storage_safety(system)


def test_tiering_parameter_validation():
    assert isinstance(BoltSystem(tiering=True).store, TieredObjectStore)
    assert isinstance(BoltSystem(tiering=TieringConfig()).store,
                      TieredObjectStore)
    assert isinstance(BoltSystem().store, MemoryObjectStore)
    with pytest.raises(TypeError, match="TieredObjectStore"):
        BoltSystem(store=MemoryObjectStore(), tiering=True)
    with pytest.raises(ValueError):
        BoltSystem(tiering=-3)
    with pytest.raises(TypeError):
        BoltSystem(compaction="yes")
    with pytest.raises(ValueError):
        BoltSystem(compaction=0)


# ---------------------------------------------------------------------------
# property suite: epoch equivalence under random interleavings
# ---------------------------------------------------------------------------

class CompactionTraceRunner(GCTraceRunner):
    """The §13 trace runner with three §14 extensions to the op mix:
    speculation sessions (abort- and commit-shaped, mirrored in the oracle
    as cfork+squash / cfork+append+promote), compaction quanta, and —
    around every compact — an epoch-equivalence assertion: the full
    readable prefix of every live slot, byte-compared before and after."""

    def _slot_reads(self):
        out = {}
        for slot in sorted(self.slots):
            log, oid = self.slots[slot]
            hi = self.oracle.visible_tail(oid)
            try:
                out[slot] = log.read(0, hi)
            except AgileLogError as e:   # capped by an ancestor's hold
                out[slot] = type(e).__name__
        return out

    def _epoch(self):
        before = self._slot_reads()
        self.system.compact_quantum()
        assert self._slot_reads() == before, "compaction epoch changed bytes"

    def _speculate(self):
        slot = self._pick()
        log, oid = self.slots[slot]
        recs = [f"sp{self._rec + i}".encode() * self.rng.randint(1, 6)
                for i in range(self.rng.randint(1, 3))]
        self._rec += len(recs)
        commit = self.rng.random() < 0.5

        def sys_fn():
            with log.speculate() as s:
                s.append_batch(recs)
                if commit:
                    s.commit()
            return True

        def ora_fn():
            cid = self.oracle.cfork(oid, True)
            if commit:
                self.oracle.append(cid, recs)
                self.oracle.promote(cid)
            else:
                self.oracle.squash(cid)
            return True

        self._both(sys_fn, ora_fn)

    def step(self):
        r = self.rng.random()
        if r < 0.12:
            self._epoch()
            check_manifest_audit(self.system.metadata.state)
        elif r < 0.24:
            self._speculate()
            self._prune()
            check_manifest_audit(self.system.metadata.state)
        else:
            super().step()

    def finish(self):
        super().finish()                           # quiesce + gc + §13 checks
        before = self._slot_reads()
        self.system.compact()                      # drain the epoch fully
        self.system.gc()
        assert self._slot_reads() == before
        check_manifest_audit(self.system.metadata.state)
        check_storage_safety(self.system)
        check_storage_liveness(self.system,
                               max_byte_amplification=RESIDUAL_AMP)


@pytest.mark.parametrize("promote_mode", ["copy", "splice"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=10, deadline=None)
def test_epoch_equivalence_under_random_interleavings(promote_mode, seed):
    runner = CompactionTraceRunner(seed, promote_mode)
    for _ in range(40):
        runner.step()
    runner.finish()


@given(seed=st.integers(min_value=0, max_value=100_000),
       flush_every=st.integers(min_value=2, max_value=6))
@settings(max_examples=8, deadline=None)
def test_epoch_equivalence_under_group_commit_churn(seed, flush_every):
    """Multi-log segments (§9) under fork churn with compaction, demotion,
    and promotion interleaved: the root and every surviving fork must read
    byte-identically across every epoch, and the final amplification must
    land under the residual bound."""
    rng = random.Random(seed)
    system = BoltSystem(n_brokers=3,
                        group_commit=GroupCommitConfig(max_records=10_000),
                        tiering=TieringConfig(min_age=1))
    root = system.create_log("r")
    root.append(b"base").wait()
    live = [root.cfork() for _ in range(3)]
    state = system.metadata.state

    def reads():
        return [root.read(0, root.tail)] + [f.read(0, f.tail) for f in live]

    for i in range(36):
        op = rng.random()
        if op < 0.45 and live:
            rng.choice(live).append(f"x{i}".encode() * rng.randint(1, 6))
        elif op < 0.60:
            live.append(root.cfork())
        elif op < 0.72 and live:
            victim = live.pop(rng.randrange(len(live)))
            victim.squash()
        elif op < 0.82:
            system.gc_quantum(limit=rng.randint(1, 3))
        elif op < 0.92:
            before = reads()
            system.compact_quantum()
            assert reads() == before, "epoch changed bytes mid-churn"
        else:
            before = reads()
            system.demote_quantum()
            assert reads() == before, "demotion changed bytes mid-churn"
        if i % flush_every == 0:
            system.flush()
        check_manifest_audit(state)
    system.flush()
    before_root = root.read(0, root.tail)
    for f in live:
        f.squash()
    system.gc()
    system.compact()
    system.gc()
    system.demote()
    assert root.read(0, root.tail) == before_root
    check_storage_safety(system)
    check_storage_liveness(system, max_byte_amplification=RESIDUAL_AMP)
    assert system.metadata.check_convergence()
