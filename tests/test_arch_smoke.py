"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU, asserting output shapes and finiteness (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import decode_step, forward, init_caches, init_params, loss_fn

pytestmark = pytest.mark.slow  # JAX tracing/compilation; fast lane: -m 'not slow'


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.vlm is not None:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encdec.enc_len, cfg.d_model)), jnp.bfloat16)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits, aux, _, n_prefix = forward(cfg, params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S + n_prefix, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(metrics["nll"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, B=2, S=16)

    @jax.jit
    def step(p):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(p)
        return loss, grads

    loss, grads = step(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jax.tree.reduce(
        lambda a, l: a + jnp.sum(jnp.square(l.astype(jnp.float32))), grads, 0.0)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(2))
    B, max_len = 2, 16
    caches = init_caches(cfg, B, max_len)
    tokens = jnp.ones((B, 1), jnp.int32)
    logits, caches2 = decode_step(cfg, params, caches, tokens,
                                  jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # a second step at the next position must also be well-formed
    logits2, _ = decode_step(cfg, params, caches2, tokens,
                             jnp.asarray(1, jnp.int32))
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


def test_decode_matches_forward_smollm():
    """Teacher-forced decode == full forward (KV-cache correctness)."""
    cfg = get_smoke_config("smollm-135m")
    params = init_params(cfg, jax.random.key(3))
    B, S = 2, 8
    batch = _batch(cfg, B=B, S=S, key=9)
    full_logits, _, _, _ = forward(cfg, params, batch)
    caches = init_caches(cfg, B, S)
    outs = []
    for t in range(S):
        lg, caches = decode_step(cfg, params, caches,
                                 batch["tokens"][:, t:t + 1],
                                 jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(dec_logits, np.float32),
                               rtol=0.15, atol=0.15)


def test_xlstm_chunked_matches_recurrent():
    """mLSTM chunkwise form == step-by-step recurrence."""
    from repro.models.xlstm import mlstm_sequence
    rng = np.random.default_rng(0)
    B, H, S, Dh = 2, 3, 32, 8
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, Dh)), jnp.float32)
               for _ in range(3))
    li = jnp.asarray(rng.normal(size=(B, H, S)), jnp.float32)
    lf = jnp.asarray(rng.normal(size=(B, H, S)), jnp.float32)
    h_chunk, st_chunk = mlstm_sequence(q, k, v, li, lf, chunk=8)
    h_rec, st_rec = mlstm_sequence(q, k, v, li, lf, chunk=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk["C"]), np.asarray(st_rec["C"]),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_match_assignment():
    """Full-config parameter counts land near the advertised sizes."""
    from repro.configs import get_config
    expect = {
        "smollm-135m": (0.13e9, 0.18e9),
        "deepseek-67b": (60e9, 70e9),
        "starcoder2-15b": (14e9, 17e9),
        "qwen3-8b": (7e9, 9.5e9),
        "llava-next-34b": (30e9, 38e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
    }
    for arch, (lo, hi) in expect.items():
        total, _ = get_config(arch).count_params()
        assert lo <= total <= hi, f"{arch}: {total/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
