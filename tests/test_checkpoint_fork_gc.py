"""Checkpoint-as-fork (DESIGN.md §17): checkpoints are log forks, so every
checkpoint byte is visible to the §13 refcount manifests and reclaimed by the
same reaper that GCs stream segments.

Covers: save/restore roundtrip (incl. bf16 leaves), keep-prune through
chain-GC, fork-per-experiment (merge = promote, abandon = squash + GC),
crash-orphan recovery, §4.1 hold interplay between trunk and experiment
catalogs, and a churn property bounding byte amplification at 1.2x under
random save/prune/experiment/recover interleavings.
"""

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BoltSystem
from repro.core.errors import AgileLogError
from repro.core.oracle import (check_manifest_audit, check_storage_liveness,
                               check_storage_safety)
from repro.train.checkpoint import CheckpointManager


def _params(seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)
                             .astype(dtype)),
            "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}


def _opt(seed):
    return {"m": jnp.zeros((8, 8)), "v": jnp.full((8,), float(seed))}


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _drain(system):
    system.flush()
    for _ in range(32):
        if not system.gc_quantum():
            break


def _dead(system, log_id):
    meta = system.metadata.state.logs.get(log_id)
    return meta is None or not meta.alive


# ---------------------------------------------------------------------------
# roundtrip + atomicity
# ---------------------------------------------------------------------------

def test_save_restore_roundtrip():
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, keep=3)
    p, o = _params(0), _opt(0)
    ckpt.save(10, p, o, extra={"cursor": [10, 0]})
    step, p2, o2, extra = ckpt.restore()
    assert step == 10 and extra["cursor"] == [10, 0]
    _assert_trees_equal(p, p2)
    _assert_trees_equal(o, o2)
    check_manifest_audit(system.metadata.state)


def test_bf16_leaves_roundtrip():
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system)
    p = _params(1, dtype=ml_dtypes.bfloat16)
    ckpt.save(5, p, _opt(1))
    _, p2, _, _ = ckpt.restore(5)
    _assert_trees_equal(p, p2)


def test_chunked_leaves_roundtrip():
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, chunk_bytes=64)   # force many chunks
    p, o = _params(2), _opt(2)
    ckpt.save(1, p, o)
    rec = ckpt._replay()[1]
    assert max(hi - lo for lo, hi in rec["spans"]) > 1
    _, p2, o2, _ = ckpt.restore()
    _assert_trees_equal(p, p2)
    _assert_trees_equal(o, o2)


def test_seed_signature_fails_loudly():
    system = BoltSystem(n_brokers=2)
    with pytest.raises(TypeError):
        CheckpointManager(system.store)


def test_reattach_sees_existing_checkpoints():
    """Checkpoint lineage lives in the log, so a fresh manager (new client
    process, same shared-log service) finds everything by name."""
    system = BoltSystem(n_brokers=2)
    p, o = _params(3), _opt(3)
    CheckpointManager(system).save(7, p, o)
    again = CheckpointManager(system)
    assert again.steps() == [7]
    _, p2, _, _ = again.restore()
    _assert_trees_equal(p, p2)


# ---------------------------------------------------------------------------
# prune == squash == chain-GC (the seed's leak, fixed)
# ---------------------------------------------------------------------------

def test_prune_hands_bytes_to_reaper():
    system = BoltSystem(n_brokers=2, gc=True)
    ckpt = CheckpointManager(system, keep=2)
    forks = {s: ckpt.save(s, _params(s), _opt(s)) for s in (10, 20, 30)}
    assert ckpt.steps() == [20, 30]               # 10 pruned
    assert _dead(system, forks[10])               # its data fork is squashed
    assert not _dead(system, forks[20]) and not _dead(system, forks[30])
    _drain(system)
    # every byte the store still holds is referenced by a live manifest
    check_manifest_audit(system.metadata.state)
    check_storage_safety(system)
    check_storage_liveness(system)
    # restorable checkpoints actually restore after the reaper ran
    _, p2, _, _ = ckpt.restore(20)
    _assert_trees_equal(_params(20), p2)


def test_prune_is_recorded_in_catalog():
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, keep=1)
    for s in (1, 2, 3):
        ckpt.save(s, _params(s), _opt(s))
    # a second manager replays the same catalog to the same index
    assert CheckpointManager(system, keep=1).steps() == [3]


# ---------------------------------------------------------------------------
# crash orphans: the §13 reaper path replaces the seed's leak
# ---------------------------------------------------------------------------

def _crashed_save(ckpt, nbytes=4096):
    """Simulate a save that died between the data-fork flush and the catalog
    append: a live fork full of bytes that no manifest references."""
    fork = ckpt.data_root.cfork(promotable=False)
    fork.append_batch([b"x" * 512 for _ in range(nbytes // 512)]).wait()
    fork.flush()
    return fork.log_id


def test_recover_squashes_crash_orphans():
    system = BoltSystem(n_brokers=2, gc=True)
    ckpt = CheckpointManager(system, keep=3)
    ckpt.save(1, _params(1), _opt(1))
    orphan = _crashed_save(ckpt)
    assert not _dead(system, orphan)
    recovered = ckpt.recover()
    assert recovered == [orphan]
    assert _dead(system, orphan)
    _drain(system)
    check_storage_liveness(system)
    assert ckpt.steps() == [1]                    # real checkpoint untouched
    assert ckpt.recover() == []                   # idempotent


def test_recover_keeps_experiment_referenced_forks():
    """A fork referenced only by a live experiment catalog is NOT an orphan:
    recover() must scan experiment forks too, or a concurrent experiment's
    checkpoint gets destroyed."""
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, keep=3)
    exp = ckpt.experiment("sweep")
    fid = exp.save(100, _params(9), _opt(9))
    assert ckpt.recover() == []                   # trunk can't see the save,
    assert not _dead(system, fid)                 # but must not reap it
    exp.merge()
    assert ckpt.steps() == [100]


# ---------------------------------------------------------------------------
# fork-per-experiment: merge = promote, abandon = squash + chain-GC
# ---------------------------------------------------------------------------

def test_experiment_merge_lands_saves_atomically():
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, keep=5)
    ckpt.save(10, _params(0), _opt(0))
    with ckpt.experiment("lr-sweep") as exp:
        assert exp.steps() == [10]                # trunk state visible (ltt)
        exp.save(20, _params(1), _opt(1))
        exp.save(30, _params(2), _opt(2))
        assert ckpt.steps() == [10]               # withheld from trunk (§4.1)
    assert ckpt.steps() == [10, 20, 30]           # squash-on-merge landed
    _, p2, _, _ = ckpt.restore(30)
    _assert_trees_equal(_params(2), p2)
    check_manifest_audit(system.metadata.state)


def test_experiment_abandon_reclaims_every_byte():
    system = BoltSystem(n_brokers=2, gc=True)
    ckpt = CheckpointManager(system, keep=5)
    ckpt.save(10, _params(0), _opt(0))
    exp = ckpt.experiment("doomed")
    fid = exp.save(20, _params(1), _opt(1))
    exp.abandon()
    assert ckpt.steps() == [10]                   # trunk untouched
    assert _dead(system, fid)
    _drain(system)
    check_storage_safety(system)
    check_storage_liveness(system)
    _, p2, _, _ = ckpt.restore(10)
    _assert_trees_equal(_params(0), p2)


def test_experiment_abandons_on_exception():
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, keep=5)
    with pytest.raises(RuntimeError):
        with ckpt.experiment("boom") as exp:
            exp.save(1, _params(0), _opt(0))
            raise RuntimeError("training diverged")
    assert ckpt.steps() == []
    with pytest.raises(AgileLogError):
        exp.save(2, _params(1), _opt(1))          # closed experiments refuse


def test_trunk_saves_during_experiment_are_withheld_not_lost():
    """§4.1: an open (promotable) experiment holds the trunk catalog — a
    trunk save during the experiment is sequenced but withheld, and becomes
    visible once the experiment resolves."""
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system, keep=5)
    exp = ckpt.experiment("hold")
    ckpt.save(10, _params(0), _opt(0))            # sequenced-but-withheld
    assert ckpt.steps() == []                     # trunk reader capped
    exp.abandon()
    assert ckpt.steps() == [10]                   # released by the resolve
    _, p2, _, _ = ckpt.restore(10)
    _assert_trees_equal(_params(0), p2)


# ---------------------------------------------------------------------------
# the real training loop: crash/resume trace audits clean
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_checkpoint_restore_trace_audits_clean():
    """Drive the actual launch driver through a crash/resume cycle on ONE
    shared-log service: checkpoint bytes must appear in (and audit against)
    the §13 refcount manifests over the whole trace, and resume must pick up
    the training step where the crashed client stopped."""
    from repro.launch.train import run

    system = BoltSystem(n_brokers=2, gc=True)
    run(steps=20, d_model=32, n_layers=2, batch=2, seq=32, vocab=256,
        system=system, ckpt_every=10, log_every=10)
    check_manifest_audit(system.metadata.state)
    losses, _, _ = run(steps=30, d_model=32, n_layers=2, batch=2, seq=32,
                       vocab=256, system=system, ckpt_every=10, log_every=10,
                       resume=True)
    assert len(losses) == 10                      # resumed at step 20
    ckpt = CheckpointManager(system)
    assert ckpt.latest_step() == 30
    _drain(system)
    check_manifest_audit(system.metadata.state)
    check_storage_safety(system)
    check_storage_liveness(system, max_byte_amplification=1.2)


# ---------------------------------------------------------------------------
# churn property: byte amplification stays bounded
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.booleans()),
                min_size=4, max_size=14))
def test_checkpoint_churn_bounds_amplification(ops):
    """Random save / crashed-save / experiment(merge|abandon) / recover
    churn, then drain GC: the §13 manifests must audit clean and the store
    must hold at most 1.2x the live checkpoint bytes (the seed's leaked
    orphans and pruned leaves would fail this immediately)."""
    system = BoltSystem(n_brokers=2, gc=True)
    ckpt = CheckpointManager(system, keep=2)
    step = 0
    for op, flag in ops:
        step += 1
        if op == 0:
            ckpt.save(step, _params(step), _opt(step))
        elif op == 1:
            _crashed_save(ckpt)
        elif op == 2:
            exp = ckpt.experiment(f"e{step}")
            exp.save(step * 1000, _params(step), _opt(step))
            if flag:
                exp.merge()
            else:
                exp.abandon()
        else:
            ckpt.recover()
    ckpt.recover()
    _drain(system)
    check_manifest_audit(system.metadata.state)
    check_storage_safety(system)
    check_storage_liveness(system, max_byte_amplification=1.2)
    for s in ckpt.steps():                        # survivors all restore
        ckpt.restore(s)
