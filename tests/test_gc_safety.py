"""Storage-safety harness for lineage-aware segment GC (DESIGN.md §13).

Two property suites plus directed units and fault injection:

* **Safety** — GC never deletes a reachable byte: under arbitrary
  fork/append/promote/squash/speculate/gc interleavings (including mid-scan
  and under promotable holds), every position readable through any live log
  resolves to bytes present in shared storage, and the metadata layer's
  incremental manifests always equal a from-scratch recount
  (``oracle.check_manifest_audit``).
* **Liveness** — after churn quiesces and GC drains, unreachable bytes are
  reclaimed and reclaimed == dead: the store holds exactly the objects some
  log (live or frozen) still references (``oracle.check_storage_liveness``).

Fault injection reuses the replicated-metadata machinery of
``test_raft_fault_tolerance.py``: a reaper crash mid-reap, leader failover
and snapshot install with GC events pending — replicas must converge on the
identical reclaimed set (``check_convergence`` digests cover the manifests).
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BoltSystem, FaultConfig, ForkBlocked, GCConfig,
                        GroupCommitConfig, InvalidOperation)
from repro.core.errors import AgileLogError, StoreFault
from repro.core.oracle import (OracleModel, check_manifest_audit,
                               check_storage_liveness, check_storage_safety,
                               recount_object_refs)


def _data_objects(system):
    return [k for k in system.store.list()
            if k.startswith(("obj-", "seg-"))]


# ---------------------------------------------------------------------------
# manifests: directed units
# ---------------------------------------------------------------------------

def test_append_registers_manifest_and_squash_hands_segments_to_gc():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    root.append(b"keep")
    fork = root.cfork()
    fork.append(b"fork-private")
    state = system.metadata.state
    check_manifest_audit(state)
    assert state.gc_tracked() == 2 and state.gc_pending() == 0
    fork.squash()
    assert state.gc_pending() == 1            # dead-lineage event enqueued
    dead = system.gc_quantum()
    assert len(dead) == 1 and not system.store.exists(dead[0])
    assert root.read(0, 1) == [b"keep"]
    check_storage_liveness(system)


def test_group_commit_segment_lives_until_every_log_in_it_dies():
    """Group commit makes objects multi-log (§9): one segment holds records
    of several logs, so liveness is a refcount, not ownership."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=100))
    root = system.create_log("r")
    root.append(b"base").wait()
    a = root.cfork()          # forks of one parent co-locate on one broker
    b = root.cfork()
    before = set(_data_objects(system))
    a.append(b"aaaa")
    b.append(b"bbbb")
    system.flush()            # ONE segment object carries both forks' records
    segs = sorted(set(_data_objects(system)) - before)
    assert len(segs) == 1
    a.squash()
    system.gc()
    assert system.store.exists(segs[0])       # b still references the segment
    assert b.read(1, 2) == [b"bbbb"]
    b.squash()
    system.gc()
    assert not system.store.exists(segs[0])   # last reference died
    check_storage_liveness(system)


def test_failed_append_orphan_put_is_reclaimed():
    """A deterministically-failed append already PUT its object — zero
    manifest references from birth, reclaimed on the next quantum."""
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    root.append(b"base")
    sib = root.cfork()                        # non-promotable, created first
    hold = root.cfork(promotable=True)        # now sib is capped (§4.1)
    before = set(_data_objects(system))
    with pytest.raises(ForkBlocked):
        sib.append(b"doomed")
    orphan = set(_data_objects(system)) - before
    assert len(orphan) == 1                   # the PUT survived the failure
    state = system.metadata.state
    check_manifest_audit(state)
    assert state.gc_pending() == 1
    dead = system.gc_quantum()
    assert set(dead) == orphan
    hold.squash()
    system.gc()
    check_storage_liveness(system)


@pytest.mark.parametrize("mode", ["copy", "splice"])
def test_promote_keeps_winner_segments_and_reclaims_the_squashed_rival(mode):
    system = BoltSystem(n_brokers=3, promote_mode=mode)
    root = system.create_log("r")
    root.append(b"p0")
    win = root.cfork(promotable=True)
    lose = root.cfork(promotable=True)        # same fork point: both allowed
    win.append(b"winner")
    lose.append(b"loser")
    win.promote()                             # first promote squashes `lose`
    state = system.metadata.state
    check_manifest_audit(state)
    dead = system.gc()
    assert dead.objects_reclaimed == 1        # the rival's private segment
    assert root.read(0, 2) == [b"p0", b"winner"]
    check_storage_safety(system)
    check_storage_liveness(system)


def test_frozen_chain_gc_releases_segments_only_at_the_last_dependent():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    root.append(b"p0")
    fork = root.cfork()
    fork.append(b"frozen-payload")
    snap = fork.sfork()                       # positional dependent of `fork`
    fork.squash()                             # fork must FREEZE, not die
    state = system.metadata.state
    check_manifest_audit(state)
    system.gc()
    assert snap.read(0, 2) == [b"p0", b"frozen-payload"]   # safety via chain
    check_storage_safety(system)
    snap.squash()                             # chain GC releases the segment
    assert state.gc_pending() >= 1
    system.gc()
    check_storage_liveness(system)
    assert root.read(0, 1) == [b"p0"]


def test_naive_variant_manifests_count_copies():
    system = BoltSystem(n_brokers=2, cf_mode="naive")
    root = system.create_log("r")
    root.append(b"a")
    fork = root.cfork()                       # copies propagate eagerly
    root.append(b"b")
    state = system.metadata.state
    check_manifest_audit(state)
    fork.squash()
    check_manifest_audit(state)
    system.gc()
    assert root.read(0, 2) == [b"a", b"b"]
    check_storage_liveness(system)


def test_collect_drains_beyond_the_quantum_batch():
    """Regression: ``system.gc()`` must be an UNBOUNDED drain — the
    configured batch paces incremental quanta only, never a drain."""
    system = BoltSystem(n_brokers=3, gc=GCConfig(batch=4))
    root = system.create_log("r")
    root.append(b"keep")
    _churn(root, 30)                          # 30 dead objects >> batch=4
    assert len(system.gc_quantum()) == 4      # quantum honors the batch
    stats = system.gc()
    assert stats.objects_reclaimed == 30 and stats.pending == 0
    check_storage_liveness(system)


def test_candidate_queue_stays_proportional_to_dead_objects():
    """Regression: successful appends must not enqueue stale candidates —
    the queue (scanned by gc_pending/auto nudges) tracks dead objects only."""
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    for i in range(50):
        root.append(f"r{i}".encode())
    state = system.metadata.state
    assert len(state._reclaimable) == 0       # 50 live appends, empty queue
    f = root.cfork()
    f.append(b"dies")
    f.squash()
    assert len(state._reclaimable) == 1
    system.gc()
    assert len(state._reclaimable) == 0
    check_storage_liveness(system)


def test_gc_preserves_withheld_suffix_under_promotable_hold():
    """Positions withheld by a hold (§4.1) are unreadable *now* but become
    readable at promote — their segments must survive any GC in between."""
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    root.append(b"base")
    child = root.cfork(promotable=True)
    r = root.append(b"hidden-1")
    root.append(b"hidden-2")
    assert r.withheld
    check_storage_safety(system)              # resolves the withheld suffix too
    assert system.gc().objects_reclaimed == 0
    child.promote()
    assert root.read(0, 3) == [b"base", b"hidden-1", b"hidden-2"]
    check_storage_liveness(system)


# ---------------------------------------------------------------------------
# session hand-off (satellites): eager abort, close(), rebase pinning
# ---------------------------------------------------------------------------

def test_aborted_session_exclusive_bytes_reclaimed_on_next_quantum():
    system = BoltSystem(n_brokers=3)          # manual reaper
    root = system.create_log("r")
    root.append(b"keep")
    with root.speculate() as s:
        s.append(b"private-1")
        s.append(b"private-2")
        s.abort()                             # hands the suffix to GC eagerly
    state = system.metadata.state
    assert state.gc_pending() == 2
    dead = system.gc_quantum()
    assert len(dead) == 2
    assert all(not system.store.exists(o) for o in dead)
    assert root.read(0, 1) == [b"keep"]
    check_storage_liveness(system)


def test_auto_gc_reclaims_abort_suffix_without_explicit_drain():
    system = BoltSystem(n_brokers=3, gc=True)
    root = system.create_log("r")
    root.append(b"keep")
    with root.speculate() as s:
        s.append(b"junk")                     # implicit abort at block exit
    assert system.metadata.state.gc_pending() == 0   # nudge already reclaimed
    assert len(_data_objects(system)) == 1
    check_storage_liveness(system)


def test_close_hands_fork_suffix_to_gc_and_spares_roots():
    system = BoltSystem(n_brokers=3, gc=True)
    root = system.create_log("r")
    root.append(b"keep")
    fork = root.cfork()
    fork.append(b"fork-private")
    fork.close()
    assert len(_data_objects(system)) == 1    # suffix reclaimed by the nudge
    fork.close()                              # idempotent: fork already gone
    root.close()                              # roots only flush, never squash
    assert root.read(0, 1) == [b"keep"]
    check_storage_liveness(system)


def test_auto_gc_inside_rebase_window_spares_pinned_suffix():
    """The squash->replay window (§12): with auto GC, the squash's own nudge
    runs a quantum while the suffix segments have ZERO manifest references —
    only the session's pins (carried in the gc command) keep them alive for
    the zero-copy replay."""
    system = BoltSystem(n_brokers=3, gc=True)
    root = system.create_log("r")
    root.append(b"p0")
    with root.speculate() as s:
        s.append(b"s0")
        root.append(b"c0")                    # forces a conflict + rebase
        res = s.commit()
    assert res.rebases == 1 and res.replayed == 1
    assert root.read(0, 3) == [b"p0", b"c0", b"s0"]
    system.gc()
    check_storage_safety(system)
    check_storage_liveness(system)
    assert system.gc_stats.pinned == 0        # pins released after the replay


def test_gc_mid_scan_keeps_remaining_batches_intact():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    want = [f"r{i}".encode() for i in range(100)]
    root.append_batch(want)
    it = root.scan(batch=10)
    got = [next(it) for _ in range(35)]       # mid-scan cursor at 35
    for i in range(4):                        # churn + reclaim under the scan
        f = root.cfork()
        f.append(b"junk" * 50)
        f.squash()
    assert system.gc().objects_reclaimed == 4
    got.extend(it)                            # remaining batches re-resolve
    assert got == want
    check_storage_liveness(system)


# ---------------------------------------------------------------------------
# property suite: random interleavings vs the oracle
# ---------------------------------------------------------------------------

class GCTraceRunner:
    """Drive one BoltSystem and the brute-force OracleModel through the same
    random trace (appends, forks, promote, squash, reads, incremental GC
    quanta), requiring identical observable behavior AND the §13 storage
    invariants at every step. Slot i maps system handle <-> oracle id (the
    raw ids drift: splice promotes mint frozen stand-in ids)."""

    def __init__(self, seed: int, promote_mode: str):
        self.rng = random.Random(seed)
        self.system = BoltSystem(n_brokers=3, promote_mode=promote_mode)
        self.oracle = OracleModel()
        root = self.system.create_log("r")
        oid = self.oracle.create_root("r")
        self.slots = {0: (root, oid)}
        self._next_slot = 1
        self._rec = 0

    def _pick(self):
        return self.rng.choice(sorted(self.slots))

    def _both(self, sys_fn, ora_fn):
        """Run both sides; error types must match; returns (sys, ora) results."""
        res = []
        errs = []
        for fn in (sys_fn, ora_fn):
            try:
                res.append(fn())
                errs.append(None)
            except AgileLogError as e:
                res.append(None)
                errs.append(type(e).__name__)
        assert errs[0] == errs[1], f"error mismatch: {errs}"
        return res[0], res[1]

    def _prune(self):
        """Drop slots whose log died (squash subtree / promote); the live
        slot sets must agree between system and oracle."""
        state = self.system.metadata.state
        live_sys = {s for s, (log, _o) in self.slots.items()
                    if log.log_id in state.logs and state.logs[log.log_id].alive}
        live_ora = {s for s, (_l, oid) in self.slots.items()
                    if oid in self.oracle.logs}
        assert live_sys == live_ora, f"liveness drift: {live_sys} != {live_ora}"
        self.slots = {s: v for s, v in self.slots.items() if s in live_sys}

    def step(self):
        rng = self.rng
        slot = self._pick()
        log, oid = self.slots[slot]
        op = rng.random()
        if op < 0.40:
            recs = [f"x{self._rec + i}".encode() * rng.randint(1, 8)
                    for i in range(rng.randint(1, 3))]
            self._rec += len(recs)
            r_sys, r_ora = self._both(
                lambda: log.append_batch(recs).positions(),
                lambda: self.oracle.append(oid, recs))
            assert r_sys == r_ora          # positions, or None when withheld
        elif op < 0.58:
            promotable = rng.random() < 0.4
            f_sys, f_ora = self._both(
                lambda: log.cfork(promotable=promotable),
                lambda: self.oracle.cfork(oid, promotable))
            if f_sys is not None:
                self.slots[self._next_slot] = (f_sys, f_ora)
                self._next_slot += 1
        elif op < 0.68:
            past = None
            tail = self.oracle.tail(oid)
            if tail > 0 and rng.random() < 0.5:
                past = rng.randrange(tail)
            f_sys, f_ora = self._both(
                lambda: log.sfork(past=past),
                lambda: self.oracle.sfork(oid, past))
            if f_sys is not None:
                self.slots[self._next_slot] = (f_sys, f_ora)
                self._next_slot += 1
        elif op < 0.76:
            self._both(lambda: log.promote(), lambda: self.oracle.promote(oid))
        elif op < 0.84:
            self._both(lambda: log.squash(), lambda: self.oracle.squash(oid))
        elif op < 0.95:
            tail = self.oracle.tail(oid)
            lo = rng.randint(0, tail)
            hi = rng.randint(lo, tail)
            r_sys, r_ora = self._both(lambda: log.read(lo, hi),
                                      lambda: self.oracle.read(oid, lo, hi))
            assert r_sys == r_ora, f"content mismatch on slot {slot} [{lo},{hi})"
        else:
            self.system.gc_quantum(limit=rng.randint(1, 4))
        self._prune()
        check_manifest_audit(self.system.metadata.state)

    def finish(self):
        for slot in sorted(self.slots):
            log, oid = self.slots[slot]
            assert log.tail == self.oracle.tail(oid)
            assert log.visible_tail == self.oracle.visible_tail(oid)
        check_storage_safety(self.system)
        # quiesce: release every hold so liveness is decidable, then drain
        state = self.system.metadata.state
        for slot in sorted(self.slots, reverse=True):
            log, oid = self.slots[slot]
            meta = state.logs.get(log.log_id)
            if meta is not None and meta.alive and meta.promotable:
                try:
                    log.squash()
                    self.oracle.squash(oid)
                except AgileLogError:
                    pass
        self._prune()
        self.system.gc()
        check_manifest_audit(state)
        check_storage_safety(self.system)
        check_storage_liveness(self.system)
        for slot in sorted(self.slots):      # reclaim deleted nothing readable
            log, oid = self.slots[slot]
            hi = self.oracle.visible_tail(oid)
            assert log.read(0, hi) == self.oracle.read(oid, 0, hi)


@pytest.mark.parametrize("promote_mode", ["copy", "splice"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=12, deadline=None)
def test_gc_safety_under_random_interleavings(promote_mode, seed):
    runner = GCTraceRunner(seed, promote_mode)
    for _ in range(45):
        runner.step()
    runner.finish()


@given(seed=st.integers(min_value=0, max_value=100_000),
       flush_every=st.integers(min_value=2, max_value=6))
@settings(max_examples=10, deadline=None)
def test_gc_safety_under_group_commit_churn(seed, flush_every):
    """Multi-log segments (§9) under fork churn: staged appends across many
    logs share segment objects; squashes must only free a segment once its
    LAST referencing log dies. Content equivalence for group commit is
    test_group_commit.py's job — here we pin the storage invariants."""
    rng = random.Random(seed)
    system = BoltSystem(n_brokers=3,
                        group_commit=GroupCommitConfig(max_records=10_000))
    root = system.create_log("r")
    root.append(b"base").wait()
    live = [root.cfork() for _ in range(3)]
    state = system.metadata.state
    for i in range(40):
        op = rng.random()
        if op < 0.55 and live:
            rng.choice(live).append(f"x{i}".encode() * rng.randint(1, 6))
        elif op < 0.70:
            live.append(root.cfork())
        elif op < 0.85 and live:
            victim = live.pop(rng.randrange(len(live)))
            victim.squash()                    # flushes its staged records first
        else:
            system.gc_quantum(limit=rng.randint(1, 3))
        if i % flush_every == 0:
            system.flush()
        check_manifest_audit(state)
    system.flush()
    for f in live:
        f.squash()
    system.gc()
    check_storage_safety(system)
    check_storage_liveness(system)
    assert root.read(0, 1) == [b"base"]


# ---------------------------------------------------------------------------
# fault injection (reuses the test_raft_fault_tolerance machinery)
# ---------------------------------------------------------------------------

def _churn(root, n=4):
    """n speculation sessions that all abort: n dead private segments."""
    for i in range(n):
        with root.speculate() as s:
            s.append(f"churn-{i}".encode() * 8)
            s.abort()


def test_reaper_crash_mid_reap_resync_converges_store():
    system = BoltSystem(n_brokers=3)
    root = system.create_log("r")
    root.append(b"keep")
    _churn(root, 6)
    state = system.metadata.state
    assert state.gc_pending() == 6
    # consensus decides the full reclaimed set; the reaper dies after
    # applying only two of the deletes
    dead = system.metadata.propose(("gc", None, ()))
    assert len(dead) == 6
    for obj in dead[:2]:
        system.store.delete(obj)
    lingering = [o for o in dead if system.store.exists(o)]
    assert len(lingering) == 4
    check_storage_safety(system)              # safety never depended on reaping
    # a restarted reaper replays reclaimed ∩ store (deletes are idempotent)
    recovered = system.collector.resync()
    assert sorted(recovered) == sorted(lingering)
    check_storage_liveness(system)
    assert system.metadata.check_convergence()


def test_injected_delete_fault_mid_reap_heals_via_resync():
    """§15 x §13: a reaper whose store DELETEs fail mid-reap (injected, not
    hand-rolled) leaves already-reclaimed objects behind; after the plane
    heals, resync() replays reclaimed ∩ store and the store converges."""
    system = BoltSystem(n_brokers=3,
                        faults=FaultConfig(seed=41, store_delete_error=1.0))
    root = system.create_log("r")
    root.append(b"keep")
    _churn(root, 6)
    with pytest.raises(StoreFault):
        system.gc()                           # consensus committed, reap died
    state = system.metadata.state
    lingering = [o for o in state.reclaimed if system.store.exists(o)]
    assert lingering                          # the reaper really did die early
    check_storage_safety(system)              # fault plane never risks safety
    system.faults.heal()
    recovered = system.collector.resync()
    assert sorted(recovered) == sorted(lingering)
    check_storage_liveness(system)
    assert system.metadata.check_convergence()
    assert root.read(0, 1) == [b"keep"]


def test_injected_torn_put_carcass_swept_by_resync():
    """§15 x §13: a torn segment PUT (prefix durably written, error raised)
    retries under a FRESH object id; the carcass key — never registered by
    consensus — is noted by the broker and swept by the reaper's resync."""
    system = BoltSystem(n_brokers=2,
                        faults=FaultConfig(seed=13, store_put_torn=0.25))
    root = system.create_log("r")
    for i in range(40):
        root.append(b"r%d" % i)
    assert system.faults.counters.get("store_put_torn", 0) > 0
    assert root.read(0, 40) == [b"r%d" % i for i in range(40)]
    swept = system.collector.resync()
    assert swept                              # carcasses existed and are gone
    for key in swept:
        assert not system.store.exists(key)
    check_storage_liveness(system)            # no amplification left behind
    assert system.metadata.check_convergence()


def test_leader_failover_with_pending_gc_reclaims_identically():
    system = BoltSystem(n_brokers=3, n_meta_replicas=3)
    root = system.create_log("r")
    root.append(b"keep")
    _churn(root, 5)
    state = system.metadata.state
    assert state.gc_pending() == 5            # events pending at failover
    system.metadata.fail_replica(system.metadata.leader_id)
    dead = system.gc_quantum(limit=3)         # partial quantum post-failover
    assert len(dead) == 3
    _churn(root, 2)
    system.gc()
    assert system.metadata.check_convergence()
    check_storage_liveness(system)
    assert root.read(0, 1) == [b"keep"]


def test_snapshot_install_with_gc_state_converges():
    system = BoltSystem(n_brokers=3, n_meta_replicas=3, snapshot_every=6)
    root = system.create_log("r")
    root.append(b"keep")
    _churn(root, 3)
    victim = (system.metadata.leader_id + 1) % 3
    system.metadata.fail_replica(victim)
    system.gc_quantum(limit=2)                # reclaim while the replica is down
    _churn(root, 3)
    system.gc_quantum(limit=2)
    system.metadata.recover_replica(victim)   # snapshot install + suffix replay
    r = system.metadata.replicas[victim]
    assert r.state.reclaimed == system.metadata.state.reclaimed
    assert r.state.object_refs == system.metadata.state.object_refs
    assert system.metadata.check_convergence()
    system.gc()
    check_storage_liveness(system)


def test_convergence_digest_covers_gc_state():
    """A replica diverging ONLY in its reclaimed set (same log forest) must
    fail the convergence check — the §13 digest extension."""
    system = BoltSystem(n_brokers=2)
    root = system.create_log("r")
    root.append(b"a")
    assert system.metadata.check_convergence()
    follower = next(r for r in system.metadata.replicas
                    if r.rid != system.metadata.leader_id)
    follower.state.reclaimed.add("phantom-object")
    assert not system.metadata.check_convergence()


def test_gc_is_deterministic_across_replicas_and_restart():
    """The reclaimed sets on every replica are identical after quanta issued
    around failures, and a from-snapshot replica replays to the same set."""
    system = BoltSystem(n_brokers=3, n_meta_replicas=3, snapshot_every=4)
    root = system.create_log("r")
    root.append(b"keep")
    for round_ in range(3):
        _churn(root, 2)
        system.gc_quantum(limit=3)
    sets = {frozenset(r.state.reclaimed)
            for r in system.metadata.replicas if r.alive
            if (r.apply_pending() or True)}
    assert len(sets) == 1
    want = recount_object_refs(system.metadata.state)
    for r in system.metadata.replicas:
        assert recount_object_refs(r.state) == want
