"""Standalone trace debugger: replay a seed with op-by-op logging.

Usage: PYTHONPATH=src python tests/_trace_debug.py SEED [cf_mode] [promote_mode]
"""
import sys

sys.path.insert(0, "tests")
from test_agilelog_semantics import TraceRunner  # noqa: E402


def run(seed, cf_mode="ltt", promote_mode="copy", n=60, verbose=True):
    runner = TraceRunner(seed, cf_mode=cf_mode, fork_mode="zerocopy",
                         promote_mode=promote_mode)
    rng = runner.rng
    for i in range(n):
        lid = rng.choice(runner.live)
        h = runner.handles[lid]
        op = rng.random()
        desc = "?"
        try:
            if op < 0.35:
                k = rng.randint(1, 3)
                recs = [f"r{runner.rec_counter + j}".encode() for j in range(k)]
                runner.rec_counter += k
                desc = f"append({lid},k={k})"
                b, o, err = runner._both(lambda: h.append_batch(recs).positions(),
                                         lambda: runner.oracle.append(lid, recs))
                if err is None:
                    assert b == o, f"positions {b} vs {o}"
            elif op < 0.5:
                promotable = rng.random() < 0.4
                desc = f"cfork({lid},prom={promotable})"
                b, o, err = runner._both(lambda: h.cfork(promotable=promotable),
                                         lambda: runner.oracle.cfork(lid, promotable))
                if err is None:
                    runner.handles[o] = b
                    runner.live.append(o)
                    desc += f" -> {o}"
            elif op < 0.6:
                past = None
                if rng.random() < 0.4 and runner.oracle.tail(lid) > 0:
                    past = rng.randrange(runner.oracle.tail(lid))
                desc = f"sfork({lid},past={past})"
                b, o, err = runner._both(lambda: h.sfork(past=past),
                                         lambda: runner.oracle.sfork(lid, past))
                if err is None:
                    runner.handles[o] = b
                    runner.live.append(o)
                    desc += f" -> {o}"
            elif op < 0.85:
                tail = runner.oracle.tail(lid)
                lo = rng.randint(0, max(0, tail))
                hi = rng.randint(lo, max(lo, tail))
                desc = f"read({lid},[{lo},{hi}))"
                b, o, err = runner._both(lambda: h.read(lo, hi),
                                         lambda: runner.oracle.read(lid, lo, hi))
                if err is None:
                    assert b == o, f"read mismatch {b} vs {o}"
            elif op < 0.93:
                mode = rng.choice(["copy", "splice"])
                desc = f"promote({lid},{mode})"
                b, o, err = runner._both(lambda: h.promote(mode=mode),
                                         lambda: runner.oracle.promote(lid))
                if err is None:
                    runner._drop_dead()
            else:
                desc = f"squash({lid})"
                b, o, err = runner._both(lambda: h.squash(),
                                         lambda: runner.oracle.squash(lid))
                if err is None:
                    runner._drop_dead()
            if verbose:
                print(i, desc, "->", err or "ok")
            runner._check_tails()
        except AssertionError as e:
            print("MISMATCH at", i, desc, ":", str(e)[:300])
            dump(runner)
            return runner
        except Exception as e:
            print("DIED at", i, desc, ":", type(e).__name__, str(e)[:300])
            dump(runner)
            return runner
    runner.final_check()
    print("trace OK")
    return runner


def dump(runner):
    o = runner.oracle
    st = runner.bolt.metadata.state
    for l in runner.live:
        ol = o.logs.get(l)
        blid = runner.handles[l].log_id
        m = st.logs.get(blid)
        if ol and m:
            runs = ([(r.start, r.n, r.lcum_start) for r in m.index.runs()]
                    if hasattr(m.index, "runs") else "naive")
            t = st.tails.get(blid) if st.tails.contains(blid) else "gone"
            print(f"  o{l}/b{blid}: o(kind={ol.kind},parent={ol.parent},len={len(ol.records)})"
                  f" b(kind={m.kind},parent={m.parent},pforks={m.promotable_forks},"
                  f"ltt={t},runs={runs})")
    print("  oracle holds:", [(h.parent, h.child, h.fp, h.caps) for h in o.holds])
    frozen = {k: (v.parent, v.stands_for, sorted(v.hli_children))
              for k, v in st.logs.items() if v.kind == "frozen"}
    print("  bolt frozen:", frozen)


if __name__ == "__main__":
    seed = int(sys.argv[1])
    cf = sys.argv[2] if len(sys.argv) > 2 else "ltt"
    pm = sys.argv[3] if len(sys.argv) > 3 else "copy"
    run(seed, cf, pm)
