"""Property tests for the HLI RunIndex and the parameter-sharding rules."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.core.index import RunIndex
from repro.distributed.sharding import param_shardings, zero_extend
from repro.launch.mesh import make_mesh


# ------------------------------------------------------------------ RunIndex
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(1, 20)),
                min_size=1, max_size=40))
@settings(max_examples=80, deadline=None)
def test_runindex_matches_dict_model(spec):
    """Runs with random gaps: segments()/local_count_before agree with a
    naive per-record dict model."""
    idx = RunIndex()
    model = {}           # pos -> (obj, off, len)
    lcum = []            # local positions in order
    pos = 0
    for i, (gap, n) in enumerate(spec):
        pos += gap
        offs = np.arange(n) * 10
        lens = np.full(n, 10)
        idx.append_run(pos, f"o{i}", offs, lens)
        for j in range(n):
            model[pos + j] = (f"o{i}", j * 10, 10)
            lcum.append(pos + j)
        pos += n
    tail = pos
    # local_count_before agrees with the sorted-list model
    for q in range(0, tail + 1, max(1, tail // 17)):
        expect = sum(1 for x in lcum if x < q)
        assert idx.local_count_before(q) == expect
    # segments() reconstruct exactly the dict model
    seen = {}
    for seg in idx.segments(0, tail):
        if seg[0] == "local":
            _, a, b, run = seg
            for p_, span in zip(range(a, b), run.record_spans(a - run.start,
                                                              b - run.start)):
                seen[p_] = span
        else:
            _, a, b, lcount = seg
            for p_ in range(a, b):
                assert p_ not in model
            assert lcount == sum(1 for x in lcum if x < a)
    assert seen == model


def test_runindex_snapshot_shares_runs():
    idx = RunIndex()
    idx.append_run(0, "a", np.arange(4) * 8, np.full(4, 8))
    snap = idx.snapshot()
    idx.append_run(10, "b", np.arange(2) * 8, np.full(2, 8))
    assert snap.num_runs == 1 and idx.num_runs == 2
    assert snap.runs()[0] is idx.runs()[0]  # zero-copy sharing


# ------------------------------------------------------------- sharding rules
@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    return make_mesh((1, 1, n), ("pod", "data", "model"))


def test_param_rules_divisibility_fallback(mesh):
    shapes = {
        "wq": jax.ShapeDtypeStruct((4, 64, 16, 8), np.float32),   # H=16 % n
        "w_in": jax.ShapeDtypeStruct((4, 64, 33), np.float32),    # 33 odd
        "embed": jax.ShapeDtypeStruct((256, 64), np.float32),
        "ln1": jax.ShapeDtypeStruct((64,), np.float32),
    }
    sh = param_shardings(shapes, mesh)
    n = mesh.shape["model"]
    if 16 % n == 0:
        assert sh["wq"].spec == P(None, None, "model", None)
    if n > 1:  # 33 is never divisible by a >1 axis: replicate fallback
        assert sh["w_in"].spec == P(None, None, None)
    assert sh["ln1"].spec == P()


def test_zero_extend_prefers_largest_free_dim(mesh):
    spec = zero_extend(P(None, "model"), (8, 64), mesh, axes=("data", "pod"))
    # data/pod are size 1 here: nothing added, never crashes
    assert len(spec) == 2


def test_zero_extend_on_wide_mesh():
    devs = len(jax.devices())
    if devs < 2:
        pytest.skip("needs >1 device")
    m = make_mesh((devs, 1), ("data", "model"))
    spec = zero_extend(P(None, None), (devs * 4, 8), m)
    assert spec[0] == "data"
