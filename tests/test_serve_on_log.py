"""Serving ON the log (DESIGN.md §17): speculative decoding as speculation
sessions, exactness against sequential greedy decode, no-trace aborts,
re-anchoring over a moving response tail, and the subscription-fed engine.

The synthetic target/draft pair mirrors ``benchmarks/bench_serve.py``: the
target's greedy token is a hash of the prefix, the draft agrees except where
a second hash says otherwise — fully deterministic, no JAX on the equivalence
path. The JAX adapters (``ModelTarget`` / ``ModelDraft``) get their own
slow-lane test driving real ``decode_step`` weights through the same driver.
"""

import hashlib

import pytest

from repro.core import BoltSystem
from repro.core.oracle import check_manifest_audit, check_storage_liveness
from repro.serve.speculative import (SpeculativeDecoder, decode_response,
                                     encode_eos, encode_token,
                                     sequential_decode,
                                     sequential_decode_on_log)
from repro.streams.records import decode_record

VOCAB = 211


def _next_token(prefix):
    h = hashlib.blake2b(b"".join(t.to_bytes(2, "big") for t in prefix[-16:]),
                        digest_size=4).digest()
    return int.from_bytes(h[:2], "big") % VOCAB


class SynthTarget:
    def verify(self, prefix, draft):
        out, p = [], list(prefix)
        for i in range(len(draft) + 1):
            out.append(_next_token(p))
            if i < len(draft):
                p.append(draft[i])
        return out


class SynthDraft:
    """Disagrees with the target on ~1/8 of positions (prefix-hash salted)."""

    def __init__(self, salt=b"d", mod=8):
        self.salt, self.mod = salt, mod

    def propose(self, prefix, k):
        out, p = [], list(prefix)
        for _ in range(k):
            t = _next_token(p)
            h = hashlib.blake2b(self.salt + len(p).to_bytes(4, "big")
                                + t.to_bytes(2, "big"), digest_size=2).digest()
            if h[0] % self.mod == 0:
                t = (t + 1) % VOCAB
            out.append(t)
            p.append(t)
        return out


class WrongDraft:
    """Always disagrees at position 0: every rollout aborts."""

    def propose(self, prefix, k):
        out, p = [], list(prefix)
        for _ in range(k):
            t = (_next_token(p) + 1) % VOCAB
            out.append(t)
            p.append(t)
        return out


def _decode(system, draft, prompt, max_new, k=4, name="resp"):
    root = system.create_log(name)
    dec = SpeculativeDecoder(SynthTarget(), draft, k=k,
                             stats=system.serve_stats)
    res = dec.decode_request(root, "r0", prompt, max_new)
    return root, res


# ---------------------------------------------------------------------------
# exactness: speculative == sequential greedy, record for record
# ---------------------------------------------------------------------------

def test_speculative_decode_is_exact():
    prompt = [3, 7, 11, 19]
    max_new = 24
    ref = sequential_decode(SynthTarget(), prompt, max_new)
    system = BoltSystem(n_brokers=2)
    root, res = _decode(system, SynthDraft(), prompt, max_new)
    assert res.tokens == ref                      # declared output matches
    view = decode_response(root.read(0, root.visible_tail))
    assert view == {"r0": ref}                    # the STREAM matches too
    # exactly max_new token records + one EOS — aborted rollouts left nothing
    assert root.visible_tail == max_new + 1
    eos = decode_record(root.read(max_new, max_new + 1)[0])
    assert eos == {"id": "r0", "eos": True, "n": max_new}
    # some rollouts were rejected, or the draft-mixing is vacuous
    assert any(r.rejected for r in res.rollouts)
    assert 0.0 < res.acceptance < 1.0


def test_speculative_never_overshoots_max_new():
    for max_new in (1, 2, 4, 5, 9):
        system = BoltSystem(n_brokers=2)
        ref = sequential_decode(SynthTarget(), [1, 2], max_new)
        root, res = _decode(system, SynthDraft(), [1, 2], max_new)
        assert res.tokens == ref and len(res.tokens) == max_new


def test_sequential_on_log_matches_reference():
    system = BoltSystem(n_brokers=2)
    root = system.create_log("resp")
    ref = sequential_decode(SynthTarget(), [5, 6], 12)
    out = sequential_decode_on_log(SynthTarget(), root, "r0", [5, 6], 12)
    assert out == ref
    assert decode_response(root.read(0, root.visible_tail)) == {"r0": ref}
    assert root.visible_tail == 13                # 12 tokens + EOS


# ---------------------------------------------------------------------------
# no trace: rejected rollouts are squashed sessions
# ---------------------------------------------------------------------------

def test_rejected_rollouts_leave_no_trace():
    system = BoltSystem(n_brokers=2, gc=True)
    ref = sequential_decode(SynthTarget(), [9], 8)
    root, res = _decode(system, WrongDraft(), [9], 8)
    assert all(r.rejected for r in res.rollouts if r.drafted)
    assert res.acceptance == 0.0
    assert res.tokens == ref                      # corrections still exact
    # flattened view holds ONLY the committed tokens + EOS
    recs = [decode_record(r) for r in root.read(0, root.visible_tail)]
    assert [r["tok"] for r in recs if not r.get("eos")] == ref
    # the aborted forks' records are dead metadata: GC reclaims their bytes
    system.flush()
    system.gc()
    check_manifest_audit(system.metadata.state)
    check_storage_liveness(system)


# ---------------------------------------------------------------------------
# re-anchoring: commits rebase over a tail other writers moved
# ---------------------------------------------------------------------------

def test_rollout_commits_reanchor_over_moving_tail():
    system = BoltSystem(n_brokers=2)
    root = system.create_log("resp")
    monitor = [0]

    def pump(_positions):
        # another writer advances the response tail DURING the verify pass
        root.append(encode_eos("__monitor", monitor[0]))
        monitor[0] += 1

    dec = SpeculativeDecoder(SynthTarget(), SynthDraft(), k=4,
                             stats=system.serve_stats, on_target=pump)
    ref = sequential_decode(SynthTarget(), [2, 4], 16)
    res = dec.decode_request(root, "r0", [2, 4], 16)
    assert res.tokens == ref
    assert system.serve_stats.reanchors > 0       # rebases actually happened
    assert sum(r.rebases for r in res.rollouts) == system.serve_stats.reanchors
    # (id, seq) demux is interleaving-proof: monitor records don't corrupt
    view = decode_response(root.read(0, root.visible_tail))
    assert view == {"r0": ref}
    assert root.visible_tail == 16 + 1 + monitor[0]


def test_interleaved_requests_share_one_response_log():
    system = BoltSystem(n_brokers=2)
    root = system.create_log("resp")
    dec = SpeculativeDecoder(SynthTarget(), SynthDraft(), k=3,
                             stats=system.serve_stats)
    refs, results = {}, {}
    for rid, prompt in (("a", [1]), ("b", [2, 3]), ("c", [4, 5, 6])):
        refs[rid] = sequential_decode(SynthTarget(), prompt, 10)
        results[rid] = dec.decode_request(root, rid, prompt, 10).tokens
    assert results == refs
    assert decode_response(root.read(0, root.visible_tail)) == refs


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------

def test_serve_stats_accounting():
    system = BoltSystem(n_brokers=2)
    _, res = _decode(system, SynthDraft(), [7], 12, k=3)
    s = system.serve_stats
    assert s.tokens_out == 12 and s.responses == 1
    assert s.tokens_drafted == sum(r.drafted for r in res.rollouts)
    assert s.tokens_accepted + s.tokens_rejected == s.tokens_drafted
    assert s.rollouts == len(res.rollouts)
    assert s.rollouts_rejected == sum(1 for r in res.rollouts if r.rejected)
    assert abs(s.acceptance - res.acceptance) < 1e-12


def test_decoder_rejects_bad_k():
    with pytest.raises(ValueError):
        SpeculativeDecoder(SynthTarget(), SynthDraft(), k=0)


# ---------------------------------------------------------------------------
# JAX adapters: real decode_step weights through the same driver
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_jax_target_draft_speculative_is_exact():
    import jax
    from repro.models.config import ModelConfig
    from repro.models.lm import init_params
    from repro.serve import ModelDraft, ModelTarget

    tcfg = ModelConfig(name="spec-target", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=1, d_ff=64, vocab_size=128,
                       tie_embeddings=True, attn_chunk=32)
    dcfg = ModelConfig(name="spec-draft", n_layers=1, d_model=16, n_heads=2,
                       n_kv_heads=1, d_ff=32, vocab_size=128,
                       tie_embeddings=True, attn_chunk=32)
    system = BoltSystem(n_brokers=2)
    target = ModelTarget(tcfg, init_params(tcfg, jax.random.key(0)),
                         stats=system.serve_stats)
    draft = ModelDraft(dcfg, init_params(dcfg, jax.random.key(1)),
                       stats=system.serve_stats)
    prompt = [5, 9, 13]
    ref = sequential_decode(target, prompt, 8)
    root = system.create_log("resp")
    dec = SpeculativeDecoder(target, draft, k=2, stats=system.serve_stats)
    res = dec.decode_request(root, "r0", prompt, 8)
    assert res.tokens == ref                      # exact despite a real draft
    assert decode_response(root.read(0, root.visible_tail)) == {"r0": ref}
    assert all(0 <= t < tcfg.vocab_size for t in res.tokens)
