"""End-to-end failure semantics under the deterministic fault plane (§15).

The tentpole harness: run agent-shaped workloads with the fault plane LIVE —
store PUT/GET errors and torn PUTs, committed-but-unacked proposals, leader
crashes mid-operation, broker crashes between the segment PUT and its
proposal, scheduled kills — and hold the system to the client-visible
contract the paper's availability story implies:

* **Acked-append durability** — every append whose receipt resolved with
  positions stays readable at exactly those positions on every live log.
* **Exactly-once under retry** — no record ever appears twice, no matter how
  many times the client layer re-submitted it (idempotency tokens dedupe
  ambiguous proposals; broker failover re-routes staged records instead of
  re-appending them). Operations that exhausted the retry budget are
  *unknown*: they may appear at most once.
* **Replica convergence + storage safety with faults live** — the §13/§14
  oracles and ``check_convergence()`` hold after healing and draining.

The plane is seeded: every failing example replays byte-identically.
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BoltSystem, FaultConfig, FaultPlane, GroupCommitConfig,
                        RetryPolicy)
from repro.core.errors import (AgileLogError, RetryBudgetExhausted,
                               StoreFault, Unavailable)
from repro.core.oracle import (check_manifest_audit, check_storage_liveness,
                               check_storage_safety)


# ---------------------------------------------------------------------------
# the trace runner
# ---------------------------------------------------------------------------

class FaultTraceRunner:
    """Random agent-shaped workload with the fault plane live.

    Tracks, per log: ``acked[log_id][pos] = record`` from resolved receipts
    (the durability oracle) and a global ``unknown`` set of records whose
    append raised a transient error after possibly staging (the at-most-once
    oracle). Records are globally unique, so duplicate detection is exact.
    """

    FAULTS = dict(store_put_error=0.03, store_put_torn=0.02,
                  store_get_error=0.02, store_delete_error=0.02,
                  propose_unacked=0.03, leader_crash=0.01,
                  broker_crash_flush=0.03, broker_crash_append=0.02)

    def __init__(self, seed: int, group_commit: bool):
        self.rng = random.Random(seed ^ 0x5EED)
        cfg = FaultConfig(seed=seed, **self.FAULTS)
        self.system = BoltSystem(
            n_brokers=4, n_meta_replicas=5,
            group_commit=GroupCommitConfig(max_records=6) if group_commit
            else None,
            faults=cfg, retry=RetryPolicy(attempts=8))
        self.logs = {0: self.system.create_log("r")}
        self._next_slot = 1
        self.acked = {0: {}}            # slot -> {pos: record}
        self.outstanding = {0: []}      # slot -> [(receipt, records)]
        self.unknown = set()            # records with unresolved outcome
        self._rec = 0

    # -- bookkeeping ---------------------------------------------------------
    def _harvest(self, slot):
        """Record positions from receipts that resolved since last look."""
        still = []
        for receipt, records in self.outstanding[slot]:
            if not receipt.done:
                still.append((receipt, records))
                continue
            try:
                positions = receipt.positions()
            except AgileLogError:
                continue                       # failed: records never landed
            if positions is None:
                continue                       # withheld (not used here)
            for pos, rec in zip(positions, records):
                self.acked[slot][pos] = rec
        self.outstanding[slot] = still

    def _harvest_all(self):
        for slot in list(self.outstanding):
            self._harvest(slot)

    def _prune(self):
        """Drop slots whose log died (a squash kills its fork SUBTREE)."""
        state = self.system.metadata.state
        for slot in [s for s, log in self.logs.items()
                     if log.log_id not in state.logs
                     or not state.logs[log.log_id].alive]:
            del self.logs[slot], self.acked[slot], self.outstanding[slot]

    # -- one trace step ------------------------------------------------------
    def step(self):
        rng = self.rng
        self._prune()
        slot = rng.choice(sorted(self.logs))
        log = self.logs[slot]
        op = rng.random()
        if op < 0.55:
            recs = [f"s{slot}-r{self._rec + i}".encode() * rng.randint(1, 6)
                    for i in range(rng.randint(1, 3))]
            self._rec += len(recs)
            try:
                receipt = log.append_batch(recs)
            except Unavailable:
                # outcome unknown: possibly staged/committed, possibly not —
                # the records may appear AT MOST once
                self.unknown.update(recs)
            else:
                self.outstanding[slot].append((receipt, recs))
        elif op < 0.70:
            self._harvest(slot)
            if self.acked[slot]:
                # read a range fully covered by acked positions and check it
                positions = sorted(self.acked[slot])
                hi_run = 0
                while hi_run < len(positions) and positions[hi_run] == hi_run:
                    hi_run += 1            # contiguous acked prefix [0, hi_run)
                if hi_run > 0:
                    lo = rng.randrange(hi_run)
                    hi = rng.randint(lo + 1, hi_run)
                    try:
                        got = log.read(lo, hi)
                    except Unavailable:
                        pass               # budget ran out mid-fault-burst
                    else:
                        want = [self.acked[slot][p] for p in range(lo, hi)]
                        assert got == want, f"read [{lo},{hi}) diverged"
        elif op < 0.78 and len(self.logs) < 5:
            try:
                fork = log.cfork(promotable=False)
            except Unavailable:
                pass
            else:
                self.logs[self._next_slot] = fork
                self.acked[self._next_slot] = {}
                self.outstanding[self._next_slot] = []
                self._next_slot += 1
        elif op < 0.84 and slot != 0:
            self._harvest(slot)
            try:
                log.squash()
            except AgileLogError:
                pass
            self._prune()
        elif op < 0.90:
            # kill or restart a broker (beyond the probabilistic crash sites)
            dead = sorted(self.system._dead)
            live = [b.broker_id for b in self.system.brokers
                    if b.broker_id not in self.system._dead]
            if dead and rng.random() < 0.5:
                self.system.recover_broker(rng.choice(dead))
            elif len(live) > 1:
                self.system.fail_broker(rng.choice(live))
        elif op < 0.95:
            meta = self.system.metadata
            dead = [r.rid for r in meta.replicas if not r.alive]
            alive = [r.rid for r in meta.replicas if r.alive]
            if dead and rng.random() < 0.7:
                meta.recover_replica(rng.choice(dead))
            elif len(alive) * 2 > len(meta.replicas) + 2:
                victim = rng.choice(alive)
                try:
                    meta.fail_replica(victim)
                except Unavailable:
                    meta.recover_replica(victim)
        else:
            try:
                self.system.gc_quantum(limit=rng.randint(1, 4))
            except Unavailable:
                pass

    # -- final oracles -------------------------------------------------------
    def finish(self):
        system = self.system
        system.faults.heal()
        for r in system.metadata.replicas:     # full recovery, then drain
            if not r.alive:
                system.metadata.recover_replica(r.rid)
        for broker_id in sorted(system._dead):  # restart the broker fleet
            system.recover_broker(broker_id)
        system.flush()
        self._prune()
        self._harvest_all()
        for slot, log in sorted(self.logs.items()):
            content = log.read(0, log.tail)
            # acked-append durability: acked (pos, record) pairs hold exactly
            for pos, rec in sorted(self.acked[slot].items()):
                assert content[pos] == rec, (
                    f"acked record at slot {slot} pos {pos} lost/moved")
            # exactly-once: every record in the log is acked-or-unknown for
            # THIS slot's lineage, and nothing appears twice
            seen = set()
            for rec in content:
                assert rec not in seen, f"duplicate record {rec!r}"
                seen.add(rec)
        state = system.metadata.state
        assert system.metadata.check_convergence()
        check_manifest_audit(state)
        check_storage_safety(system)
        system.collector.resync()              # sweep torn/orphan carcasses
        system.gc()
        check_storage_safety(system)
        assert system.metadata.check_convergence()


# ---------------------------------------------------------------------------
# property harness (per-call and group-commit append modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("group_commit", [False, True],
                         ids=["per-call", "group-commit"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=10, deadline=None)
def test_linearizable_under_faults(group_commit, seed):
    runner = FaultTraceRunner(seed, group_commit)
    for _ in range(60):
        runner.step()
    runner.finish()


# ---------------------------------------------------------------------------
# the acceptance scenario, pinned at a fixed seed (CI fast lane)
# ---------------------------------------------------------------------------

def test_acceptance_schedule_broker_and_leader_kill_with_store_noise():
    """ISSUE acceptance: broker kill + leader kill on a schedule plus 1%
    store-op failure; every acked append durable, no duplicates, replicas
    converge, storage-safety oracle passes with the plane having been live."""
    cfg = FaultConfig(seed=1337,
                      store_put_error=0.01, store_get_error=0.01,
                      store_delete_error=0.01,
                      schedule=((0.3, "kill_broker", 1),
                                (0.6, "kill_leader", None)))
    system = BoltSystem(n_brokers=3, n_meta_replicas=5,
                        group_commit=GroupCommitConfig(max_records=4),
                        faults=cfg)
    log = system.create_log("events")
    receipts = []
    for i in range(120):
        t = i / 120.0
        system.faults.advance(t)               # DES clock drives the schedule
        receipts.append((log.append(b"ev-%03d" % i), b"ev-%03d" % i))
    system.flush()
    assert system.faults.events_fired == [(0.3, "kill_broker", 1),
                                          (0.6, "kill_leader", None)]
    assert 1 in system._dead
    positions = {}
    for receipt, rec in receipts:
        pos = receipt.position()               # every ack resolved, none lost
        assert pos not in positions
        positions[pos] = rec
    assert sorted(positions) == list(range(120))
    content = log.read(0, 120)
    assert content == [positions[p] for p in range(120)]   # durable + ordered
    system.faults.heal()
    assert system.metadata.check_convergence()
    check_manifest_audit(system.metadata.state)
    check_storage_safety(system)
    system.collector.resync()
    check_storage_liveness(system)


# ---------------------------------------------------------------------------
# directed: the individual §15 mechanisms
# ---------------------------------------------------------------------------

def test_ambiguous_proposal_dedups_instead_of_applying_twice():
    """propose_unacked=1.0: every attempt commits and then loses the ack.
    The client budget exhausts, but the replicated dedup table made every
    retry a no-op — the command applied exactly once."""
    system = BoltSystem(faults=FaultConfig(seed=1),
                        retry=RetryPolicy(attempts=4))
    log = system.create_log("r")
    system.faults.config.propose_unacked = 1.0   # arm AFTER setup
    with pytest.raises(RetryBudgetExhausted) as exc:
        log.append(b"once")
    assert exc.value.attempts == 4
    assert system.metadata.state.tail(log.log_id) == 1   # applied ONCE
    assert system.metadata.state.idem_hits == 3          # retries deduped
    system.faults.heal()
    assert log.read(0, 1) == [b"once"]
    assert system.metadata.check_convergence()


def test_retry_budget_exhausted_is_typed_and_carries_cause():
    system = BoltSystem(faults=FaultConfig(seed=2, store_put_error=1.0),
                        retry=RetryPolicy(attempts=3))
    log = system.create_log("r")
    with pytest.raises(RetryBudgetExhausted) as exc:
        log.append(b"never")
    assert isinstance(exc.value.last_error, StoreFault)
    assert system.retry_stats.budget_exhausted >= 1
    assert system.metadata.state.tail(log.log_id) == 0


def test_scan_resumes_across_broker_death():
    """A scan in flight when its broker dies finishes through a survivor."""
    system = BoltSystem(n_brokers=3, faults=FaultConfig(seed=3))
    log = system.create_log("r")
    want = [b"x%03d" % i for i in range(64)]
    for rec in want:
        log.append(rec)
    it = log.scan(0, 64, batch=16)
    got = [next(it) for _ in range(16)]        # first chunk via broker 0
    system.fail_broker(log.broker.broker_id)
    got.extend(it)                             # remaining chunks re-route
    assert got == want
    assert log.broker.broker_id != 0           # handle re-pointed


def test_subscription_survives_leader_failover():
    system = BoltSystem(n_brokers=2, n_meta_replicas=5,
                        faults=FaultConfig(seed=4))
    log = system.create_log("r")
    for i in range(8):
        log.append(b"a%d" % i)
    sub = log.subscribe(from_pos=0, batch=4, follow=False)
    first = sub.poll()
    assert first == [b"a%d" % i for i in range(4)]
    system.metadata.fail_replica(system.metadata.leader_id)
    rest = sub.poll()
    assert rest == [b"a%d" % i for i in range(4, 8)]


def test_same_seed_replays_identical_fault_sequence():
    def run(seed):
        system = BoltSystem(
            group_commit=GroupCommitConfig(max_records=4),
            faults=FaultConfig(seed=seed, store_put_error=0.1,
                               store_put_torn=0.05, propose_unacked=0.1))
        log = system.create_log("r")
        for i in range(60):
            log.append(b"r%d" % i)
        system.flush()
        return (dict(system.faults.counters), system.retry_stats.retries,
                system.metadata.state.idem_hits)

    assert run(99) == run(99)
    assert run(99) != run(100)      # and the seed actually matters


def test_optally_surfaces_fault_counters():
    from repro.core.sim import OpTally
    system = BoltSystem(faults=FaultConfig(seed=5, propose_unacked=0.5),
                        retry=RetryPolicy(attempts=10))
    before = OpTally.capture(system)
    log = system.create_log("r")
    for i in range(20):
        log.append(b"x%d" % i)
    delta = OpTally.capture(system, records=20).delta(before)
    assert delta.records == 20
    assert delta.retries > 0
    assert delta.faults_injected > 0
    assert delta.dedup_hits > 0


def test_faults_parameter_validation():
    assert BoltSystem(faults=None).faults is None
    assert BoltSystem(faults=False).faults is None
    assert isinstance(BoltSystem(faults=True).faults, FaultPlane)
    plane = FaultPlane(FaultConfig(seed=9))
    assert BoltSystem(faults=plane).faults is plane
    with pytest.raises(TypeError):
        BoltSystem(faults=0.5)
    with pytest.raises(AssertionError):
        FaultPlane(FaultConfig(schedule=((0.1, "kill_broker", 0),))).advance(1.0)
