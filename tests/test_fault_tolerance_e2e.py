"""End-to-end failure semantics under the deterministic fault plane (§15/§16).

The tentpole harness: run agent-shaped workloads with the fault plane LIVE —
store PUT/GET errors and torn PUTs, committed-but-unacked proposals, leader
crashes mid-operation, broker crashes between the segment PUT and its
proposal, scheduled kills, and (§16) message-level network faults with
partitions carved and healed mid-trace — and hold the system to the
client-visible contract the paper's availability story implies:

* **Linearizability** — every recorded append/read history admits a total
  order consistent with real time and a sequential log. The general checker
  in ``repro.core.linearize`` replaced this file's bespoke "acked positions
  hold, no duplicates" assertions: those follow from linearizability, and
  the checker additionally rejects reorderings, lost acks resurfacing at the
  wrong position, and dedup failures. A mutation test below breaks the §15
  dedup on purpose and requires the checker to catch it.
* **At-most-once for unknown outcomes** — operations that exhausted the
  retry budget are recorded as *unknown* and may linearize at one point or
  nowhere; the final full read settles which.
* **Replica convergence + storage safety with faults live** — the §13/§14
  oracles and ``check_convergence()`` hold after healing and draining, for
  arbitrary partition schedules (hypothesis property below).

The plane is seeded: every failing example replays byte-identically.
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BoltSystem, FaultConfig, FaultPlane, GroupCommitConfig,
                        History, RetryPolicy)
from repro.core.errors import (AgileLogError, RetryBudgetExhausted,
                               StoreFault, Unavailable)
from repro.core.oracle import (check_manifest_audit, check_storage_liveness,
                               check_storage_safety)


# ---------------------------------------------------------------------------
# the trace runner
# ---------------------------------------------------------------------------

class FaultTraceRunner:
    """Random agent-shaped workload with the fault plane live.

    Every append and read is recorded into a ``History`` (invoke at the call
    site, resolve when the receipt/read returns, unknown on a transient
    error) and checked for linearizability at the end. ``acked`` mirrors the
    resolved positions only to pick readable ranges mid-trace. Records are
    globally unique, so the final full read settles every unknown outcome.
    """

    FAULTS = dict(store_put_error=0.03, store_put_torn=0.02,
                  store_get_error=0.02, store_delete_error=0.02,
                  propose_unacked=0.03, leader_crash=0.01,
                  broker_crash_flush=0.03, broker_crash_append=0.02,
                  net_drop=0.02, net_delay=0.02,
                  net_duplicate=0.01, net_reorder=0.01)

    def __init__(self, seed: int, group_commit: bool):
        self.rng = random.Random(seed ^ 0x5EED)
        cfg = FaultConfig(seed=seed, **self.FAULTS)
        self.system = BoltSystem(
            n_brokers=4, n_meta_replicas=5,
            group_commit=GroupCommitConfig(max_records=6) if group_commit
            else None,
            faults=cfg, retry=RetryPolicy(attempts=8))
        self.logs = {0: self.system.create_log("r")}
        self._next_slot = 1
        self.acked = {0: {}}            # slot -> {pos: record}
        self.outstanding = {0: []}      # slot -> [(receipt, records, op)]
        self.hist = History()
        self.hist.register_log(self.logs[0].log_id, 0)
        self.t = 0.0                    # DES clock driving delayed delivery
        self._rec = 0

    # -- bookkeeping ---------------------------------------------------------
    def _harvest(self, slot):
        """Record positions from receipts that resolved since last look."""
        still = []
        for receipt, records, op in self.outstanding[slot]:
            if not receipt.done:
                still.append((receipt, records, op))
                continue
            try:
                positions = receipt.positions()
            except AgileLogError:
                self.hist.discard(op)          # failed: records never landed
                continue
            if positions is None:
                self.hist.unknown(op)          # withheld (not used here)
                continue
            self.hist.resolve(op, tuple(positions))
            for pos, rec in zip(positions, records):
                self.acked[slot][pos] = rec
        self.outstanding[slot] = still

    def _harvest_all(self):
        for slot in list(self.outstanding):
            self._harvest(slot)

    def _prune(self):
        """Drop slots whose log died (a squash kills its fork SUBTREE)."""
        state = self.system.metadata.state
        for slot in [s for s, log in self.logs.items()
                     if log.log_id not in state.logs
                     or not state.logs[log.log_id].alive]:
            del self.logs[slot], self.acked[slot], self.outstanding[slot]

    # -- one trace step ------------------------------------------------------
    def step(self):
        rng = self.rng
        self.t += 2e-3                     # tick the DES clock so delayed
        self.system.faults.advance(self.t)  # messages actually deliver
        self._prune()
        slot = rng.choice(sorted(self.logs))
        log = self.logs[slot]
        op = rng.random()
        if op < 0.55:
            recs = [f"s{slot}-r{self._rec + i}".encode() * rng.randint(1, 6)
                    for i in range(rng.randint(1, 3))]
            self._rec += len(recs)
            hop = self.hist.invoke("append", log.log_id, tuple(recs))
            try:
                receipt = log.append_batch(recs)
            except Unavailable:
                # outcome unknown: possibly staged/committed, possibly not —
                # the records may appear AT MOST once
                self.hist.unknown(hop)
            else:
                self.outstanding[slot].append((receipt, recs, hop))
        elif op < 0.70:
            self._harvest(slot)
            if self.acked[slot]:
                # read a range fully covered by acked positions; the
                # linearizability check at finish() judges the result
                positions = sorted(self.acked[slot])
                hi_run = 0
                while hi_run < len(positions) and positions[hi_run] == hi_run:
                    hi_run += 1            # contiguous acked prefix [0, hi_run)
                if hi_run > 0:
                    lo = rng.randrange(hi_run)
                    hi = rng.randint(lo + 1, hi_run)
                    hop = self.hist.invoke("read", log.log_id, (lo, hi))
                    try:
                        got = log.read(lo, hi)
                    except Unavailable:
                        self.hist.discard(hop)  # no response: reads have no
                    else:                       # effect, drop from history
                        self.hist.resolve(hop, tuple(got))
        elif op < 0.78 and len(self.logs) < 5:
            hop = self.hist.invoke("cfork", log.log_id, ())
            try:
                fork = log.cfork(promotable=False)
            except Unavailable:
                # the fork may exist as an orphan, but its handle is lost and
                # it will never be read — drop the op from the history
                self.hist.discard(hop)
            else:
                self.hist.resolve(hop, (fork.log_id,))
                self.logs[self._next_slot] = fork
                self.acked[self._next_slot] = {}
                self.outstanding[self._next_slot] = []
                self._next_slot += 1
        elif op < 0.84 and slot != 0:
            self._harvest(slot)
            try:
                log.squash()
            except AgileLogError:
                pass
            self._prune()
        elif op < 0.90:
            # kill or restart a broker (beyond the probabilistic crash sites)
            dead = sorted(self.system._dead)
            live = [b.broker_id for b in self.system.brokers
                    if b.broker_id not in self.system._dead]
            if dead and rng.random() < 0.5:
                self.system.recover_broker(rng.choice(dead))
            elif len(live) > 1:
                self.system.fail_broker(rng.choice(live))
        elif op < 0.94:
            meta = self.system.metadata
            dead = [r.rid for r in meta.replicas if not r.alive]
            alive = [r.rid for r in meta.replicas if r.alive]
            if dead and rng.random() < 0.7:
                meta.recover_replica(rng.choice(dead))
            elif len(alive) * 2 > len(meta.replicas) + 2:
                victim = rng.choice(alive)
                try:
                    meta.fail_replica(victim)
                except Unavailable:
                    meta.recover_replica(victim)
        elif op < 0.97:
            # carve or heal a network partition among the metadata replicas
            net = self.system.faults.net
            if net.blocked:
                self.system.heal_network()
            else:
                ids = list(range(len(self.system.metadata.replicas)))
                rng.shuffle(ids)
                cut = rng.randint(1, 2)    # minority side of a 5-replica ring
                if rng.random() < 0.3:
                    net.partition_oneway(ids[:cut], ids[cut:])
                else:
                    self.system.partition(ids[:cut], ids[cut:])
        else:
            try:
                self.system.gc_quantum(limit=rng.randint(1, 4))
            except Unavailable:
                pass

    # -- final oracles -------------------------------------------------------
    def finish(self):
        system = self.system
        system.faults.heal()
        for r in system.metadata.replicas:     # full recovery, then drain
            if not r.alive:
                system.metadata.recover_replica(r.rid)
        for broker_id in sorted(system._dead):  # restart the broker fleet
            system.recover_broker(broker_id)
        system.flush()
        self._prune()
        self._harvest_all()
        for slot, log in sorted(self.logs.items()):
            content = tuple(log.read(0, log.tail))
            # settle unknown-outcome appends against the final full read
            # (records are unique: absent = never landed, consecutive =
            # landed there), then record the read itself — the checker's
            # sequential-log model subsumes the old bespoke durability and
            # exactly-once assertions and is strictly stronger
            self.hist.settle(log.log_id, content)
            final = self.hist.invoke("read", log.log_id, (0, log.tail))
            self.hist.resolve(final, content)
        verdict = self.hist.check()
        assert verdict.ok, verdict.reason
        state = system.metadata.state
        assert system.metadata.check_convergence()
        check_manifest_audit(state)
        check_storage_safety(system)
        system.collector.resync()              # sweep torn/orphan carcasses
        system.gc()
        check_storage_safety(system)
        assert system.metadata.check_convergence()


# ---------------------------------------------------------------------------
# property harness (per-call and group-commit append modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("group_commit", [False, True],
                         ids=["per-call", "group-commit"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=10, deadline=None)
def test_linearizable_under_faults(group_commit, seed):
    runner = FaultTraceRunner(seed, group_commit)
    for _ in range(60):
        runner.step()
    runner.finish()


# ---------------------------------------------------------------------------
# lease-read fast path (DESIGN.md §18): consensus-free reads, proven
# linearizable by the §16 checker — including across a partition where the
# lease fences the deposed leader and the fallback re-elects
# ---------------------------------------------------------------------------

class _LeaseHistory:
    """Record client appends/reads into a ``History`` for the §16 checker."""

    def __init__(self, log):
        self.log = log
        self.hist = History()
        self.hist.register_log(log.log_id, 0)

    def append(self, rec: bytes) -> None:
        op = self.hist.invoke("append", self.log.log_id, (rec,))
        self.hist.resolve(op, tuple(self.log.append(rec).positions()))

    def read(self) -> None:
        hi = self.log.tail
        op = self.hist.invoke("read", self.log.log_id, (0, hi))
        self.hist.resolve(op, tuple(self.log.read(0, hi)))

    def check(self) -> None:
        verdict = self.hist.check()
        assert verdict.ok, verdict.reason


def test_lease_reads_skip_consensus_on_fast_path():
    """Steady state: every tail/read is served locally under the leader's
    lease — ZERO proposals, zero barrier no-ops — and the recorded history
    still linearizes."""
    system = BoltSystem(n_brokers=2, faults=True)
    meta = system.metadata
    run = _LeaseHistory(system.create_log("r"))
    for i in range(10):
        run.append(f"r{i}".encode())
    p0, l0 = meta.proposals, meta.lease_reads
    for _ in range(8):
        run.read()
        assert run.log.tail == 10
    assert meta.proposals == p0            # reads rode NO consensus round
    assert meta.lease_reads > l0
    assert meta.lease_fallbacks == 0
    run.check()


def test_lease_reads_linearizable_across_partition():
    """A minority-partitioned leader's lease lapses on the DES clock; the
    read falls back (LeaseExpired path), the majority side elects, the
    renewed lease re-arms the fast path — and every read in the history
    linearizes against the committed log."""
    system = BoltSystem(n_brokers=2, n_meta_replicas=5,
                        faults=FaultConfig(seed=3),
                        retry=RetryPolicy(attempts=8))
    plane, meta = system.faults, system.metadata
    run = _LeaseHistory(system.create_log("r"))
    for i in range(3):
        run.append(f"a{i}".encode())
    run.read()
    old = meta.leader_id
    minority = [old, (old + 1) % 5]
    majority = [r for r in range(5) if r not in minority]
    system.partition(minority, majority)
    # past the deposed leader's lease horizon its local reads are fenced
    plane.advance(meta.replicas[old].lease_until + 0.01)
    f0 = meta.lease_fallbacks
    run.read()                              # falls back + fails over
    assert meta.lease_fallbacks > f0
    assert meta.leader_id in majority
    for i in range(3):
        run.append(f"b{i}".encode())        # majority side serves writes
    # committed ack rounds renewed the new leader's lease: fast path resumes
    p0, l0 = meta.proposals, meta.lease_reads
    run.read()
    assert meta.lease_reads > l0 and meta.proposals == p0
    system.heal_network()
    meta.sync_followers()
    run.read()
    run.check()
    assert meta.check_convergence()


def test_lease_read_never_misses_acked_write():
    """The fast path's ``last_index <= commit_index`` guard: a fresh leader
    holds a lease immediately, but until the no-op barrier lands its local
    state may miss entries the old leader committed — the read must take the
    barrier path, not serve the stale lease read."""
    system = BoltSystem(n_brokers=2, n_meta_replicas=5,
                        faults=FaultConfig(seed=11),
                        retry=RetryPolicy(attempts=8))
    meta = system.metadata
    run = _LeaseHistory(system.create_log("r"))
    for i in range(5):
        run.append(f"r{i}".encode())
    # crash the leader: the winner's election barrier may or may not have
    # committed — read_state() must return the full acked prefix either way
    meta.fail_replica(meta.leader_id)
    run.read()
    assert run.log.tail == 5
    run.check()


# ---------------------------------------------------------------------------
# the acceptance scenario, pinned at a fixed seed (CI fast lane)
# ---------------------------------------------------------------------------

def test_acceptance_schedule_broker_and_leader_kill_with_store_noise():
    """ISSUE acceptance: broker kill + leader kill on a schedule plus 1%
    store-op failure; every acked append durable, no duplicates, replicas
    converge, storage-safety oracle passes with the plane having been live."""
    cfg = FaultConfig(seed=1337,
                      store_put_error=0.01, store_get_error=0.01,
                      store_delete_error=0.01,
                      schedule=((0.3, "kill_broker", 1),
                                (0.6, "kill_leader", None)))
    system = BoltSystem(n_brokers=3, n_meta_replicas=5,
                        group_commit=GroupCommitConfig(max_records=4),
                        faults=cfg)
    log = system.create_log("events")
    receipts = []
    for i in range(120):
        t = i / 120.0
        system.faults.advance(t)               # DES clock drives the schedule
        receipts.append((log.append(b"ev-%03d" % i), b"ev-%03d" % i))
    system.flush()
    assert system.faults.events_fired == [(0.3, "kill_broker", 1),
                                          (0.6, "kill_leader", None)]
    assert 1 in system._dead
    positions = {}
    for receipt, rec in receipts:
        pos = receipt.position()               # every ack resolved, none lost
        assert pos not in positions
        positions[pos] = rec
    assert sorted(positions) == list(range(120))
    content = log.read(0, 120)
    assert content == [positions[p] for p in range(120)]   # durable + ordered
    system.faults.heal()
    assert system.metadata.check_convergence()
    check_manifest_audit(system.metadata.state)
    check_storage_safety(system)
    system.collector.resync()
    check_storage_liveness(system)


# ---------------------------------------------------------------------------
# directed: the individual §15 mechanisms
# ---------------------------------------------------------------------------

def test_ambiguous_proposal_dedups_instead_of_applying_twice():
    """propose_unacked=1.0: every attempt commits and then loses the ack.
    The client budget exhausts, but the replicated dedup table made every
    retry a no-op — the command applied exactly once."""
    system = BoltSystem(faults=FaultConfig(seed=1),
                        retry=RetryPolicy(attempts=4))
    log = system.create_log("r")
    system.faults.config.propose_unacked = 1.0   # arm AFTER setup
    with pytest.raises(RetryBudgetExhausted) as exc:
        log.append(b"once")
    assert exc.value.attempts == 4
    assert system.metadata.state.tail(log.log_id) == 1   # applied ONCE
    assert system.metadata.state.idem_hits == 3          # retries deduped
    system.faults.heal()
    assert log.read(0, 1) == [b"once"]
    assert system.metadata.check_convergence()


def test_retry_budget_exhausted_is_typed_and_carries_cause():
    system = BoltSystem(faults=FaultConfig(seed=2, store_put_error=1.0),
                        retry=RetryPolicy(attempts=3))
    log = system.create_log("r")
    with pytest.raises(RetryBudgetExhausted) as exc:
        log.append(b"never")
    assert isinstance(exc.value.last_error, StoreFault)
    assert system.retry_stats.budget_exhausted >= 1
    assert system.metadata.state.tail(log.log_id) == 0


def test_scan_resumes_across_broker_death():
    """A scan in flight when its broker dies finishes through a survivor."""
    system = BoltSystem(n_brokers=3, faults=FaultConfig(seed=3))
    log = system.create_log("r")
    want = [b"x%03d" % i for i in range(64)]
    for rec in want:
        log.append(rec)
    it = log.scan(0, 64, batch=16)
    got = [next(it) for _ in range(16)]        # first chunk via broker 0
    system.fail_broker(log.broker.broker_id)
    got.extend(it)                             # remaining chunks re-route
    assert got == want
    assert log.broker.broker_id != 0           # handle re-pointed


def test_subscription_survives_leader_failover():
    system = BoltSystem(n_brokers=2, n_meta_replicas=5,
                        faults=FaultConfig(seed=4))
    log = system.create_log("r")
    for i in range(8):
        log.append(b"a%d" % i)
    sub = log.subscribe(from_pos=0, batch=4, follow=False)
    first = sub.poll()
    assert first == [b"a%d" % i for i in range(4)]
    system.metadata.fail_replica(system.metadata.leader_id)
    rest = sub.poll()
    assert rest == [b"a%d" % i for i in range(4, 8)]


def test_same_seed_replays_identical_fault_sequence():
    def run(seed):
        system = BoltSystem(
            group_commit=GroupCommitConfig(max_records=4),
            faults=FaultConfig(seed=seed, store_put_error=0.1,
                               store_put_torn=0.05, propose_unacked=0.1))
        log = system.create_log("r")
        for i in range(60):
            log.append(b"r%d" % i)
        system.flush()
        return (dict(system.faults.counters), system.retry_stats.retries,
                system.metadata.state.idem_hits)

    assert run(99) == run(99)
    assert run(99) != run(100)      # and the seed actually matters


def test_optally_surfaces_fault_counters():
    from repro.core.sim import OpTally
    system = BoltSystem(faults=FaultConfig(seed=5, propose_unacked=0.5),
                        retry=RetryPolicy(attempts=10))
    before = OpTally.capture(system)
    log = system.create_log("r")
    for i in range(20):
        log.append(b"x%d" % i)
    delta = OpTally.capture(system, records=20).delta(before)
    assert delta.records == 20
    assert delta.retries > 0
    assert delta.faults_injected > 0
    assert delta.dedup_hits > 0


def test_faults_parameter_validation():
    assert BoltSystem(faults=None).faults is None
    assert BoltSystem(faults=False).faults is None
    assert isinstance(BoltSystem(faults=True).faults, FaultPlane)
    plane = FaultPlane(FaultConfig(seed=9))
    assert BoltSystem(faults=plane).faults is plane
    with pytest.raises(TypeError):
        BoltSystem(faults=0.5)
    with pytest.raises(AssertionError):
        FaultPlane(FaultConfig(schedule=((0.1, "kill_broker", 0),))).advance(1.0)


# ---------------------------------------------------------------------------
# the §16 linearizability checker: direct sanity + the dedup mutation test
# ---------------------------------------------------------------------------

def test_linearize_checker_accepts_and_rejects_directly():
    """Pin the checker's semantics on hand-built histories, independent of
    the system under test."""
    # a clean sequential history passes
    h = History()
    h.register_log(7, 0)
    a = h.invoke("append", 7, (b"x", b"y"))
    h.resolve(a, (0, 1))
    r = h.invoke("read", 7, (0, 2))
    h.resolve(r, (b"x", b"y"))
    assert h.check().ok
    # a stale read AFTER a resolved append fails (real-time order violated)
    h2 = History()
    h2.register_log(7, 0)
    a2 = h2.invoke("append", 7, (b"x",))
    h2.resolve(a2, (0,))
    r2 = h2.invoke("read", 7, (0, 1))
    h2.resolve(r2, ())                     # returned nothing — too late
    assert not h2.check().ok
    # an unknown-outcome append may linearize nowhere...
    h3 = History()
    h3.register_log(7, 0)
    u = h3.invoke("append", 7, (b"ghost",))
    h3.unknown(u)
    r3 = h3.invoke("read", 7, (0, 0))
    h3.resolve(r3, ())
    assert h3.check().ok
    # ...but a duplicate application can never linearize
    h4 = History()
    h4.register_log(7, 0)
    a4 = h4.invoke("append", 7, (b"d",))
    h4.resolve(a4, (0,))
    r4 = h4.invoke("read", 7, (0, 2))
    h4.resolve(r4, (b"d", b"d"))           # the record landed twice
    assert not h4.check().ok
    # a cFork snapshots the parent, and later parent appends flow into it
    h5 = History()
    h5.register_log(0, 0)
    a5 = h5.invoke("append", 0, (b"p0",))
    h5.resolve(a5, (0,))
    f5 = h5.invoke("cfork", 0, ())
    h5.resolve(f5, (1,))
    b5 = h5.invoke("append", 0, (b"p1",))  # lands in BOTH logs
    h5.resolve(b5, (1,))
    c5 = h5.invoke("append", 1, (b"c0",))
    h5.resolve(c5, (2,))
    r5 = h5.invoke("read", 1, (0, 3))
    h5.resolve(r5, (b"p0", b"p1", b"c0"))
    assert h5.check().ok
    h5.resolve(h5.invoke("read", 1, (0, 3)), (b"p0", b"c0", b"p1"))
    assert not h5.check().ok               # fork saw a reordered share


def _dedup_mutation_trace(system, log):
    """Shared workload for the mutation test and its control: ambiguous
    proposals armed, every outcome recorded into a History."""
    hist = History()
    hist.register_log(log.log_id, 0)
    system.faults.config.propose_unacked = 0.5   # arm AFTER setup
    pending = []
    for i in range(15):
        rec = b"m%02d" % i
        hop = hist.invoke("append", log.log_id, (rec,))
        try:
            receipt = log.append(rec)
        except Unavailable:
            hist.unknown(hop)              # may have applied... how often?
        else:
            pending.append((hop, receipt))
    system.faults.config.propose_unacked = 0.0
    system.flush()
    for hop, receipt in pending:
        try:
            pos = receipt.position()
        except AgileLogError:
            hist.discard(hop)
        else:
            hist.resolve(hop, (pos,))
    system.faults.heal()
    tail = system.metadata.state.tail(log.log_id)
    content = tuple(log.read(0, tail))
    hist.settle(log.log_id, content)
    final = hist.invoke("read", log.log_id, (0, tail))
    hist.resolve(final, content)
    return hist


def test_linearize_checker_catches_broken_dedup(monkeypatch):
    """Mutation test (ISSUE §16 acceptance): break the §15 idempotency dedup
    so a retried ambiguous proposal applies TWICE, and require the checker
    to reject the recorded history. Guards the checker itself — if this
    passes vacuously, the checker has lost its teeth."""
    from repro.core.metadata import MetadataState
    monkeypatch.setattr(MetadataState, "_apply_idem",
                        lambda self, token, cmd: self.apply(cmd))
    system = BoltSystem(faults=FaultConfig(seed=11),
                        retry=RetryPolicy(attempts=5))
    log = system.create_log("r")
    hist = _dedup_mutation_trace(system, log)
    verdict = hist.check()
    assert not verdict.ok, "checker must flag the duplicated applies"


def test_linearize_checker_passes_with_dedup_intact():
    """Control for the mutation test: the identical workload with the real
    dedup in place yields a linearizable history."""
    system = BoltSystem(faults=FaultConfig(seed=11),
                        retry=RetryPolicy(attempts=5))
    log = system.create_log("r")
    hist = _dedup_mutation_trace(system, log)
    assert hist.check().ok


# ---------------------------------------------------------------------------
# satellite: heal() after an arbitrary partition/fault schedule converges
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=10, deadline=None)
def test_heal_after_arbitrary_partition_schedule_converges(seed):
    """Property: whatever partition/crash/message-fault schedule ran, after
    heal() + recovery the replica group reaches ``check_convergence()`` with
    every replica at the leader's commit index and an agreeing digest."""
    from repro.core.raft import MetadataService
    rng = random.Random(seed ^ 0xA11CE)
    plane = FaultPlane(FaultConfig(seed=seed, net_drop=0.1, net_delay=0.05,
                                   net_duplicate=0.05, net_reorder=0.05))
    meta = MetadataService(n_replicas=5)
    meta.faults = plane
    meta.retry = RetryPolicy(attempts=6)
    root = meta.propose(("create_root", "r"))
    n = len(meta.replicas)
    for i in range(40):
        plane.advance(plane.now + 1e-3)
        op = rng.random()
        if op < 0.55:
            try:
                meta.propose(("append", root, f"o{i}", (0,), (4,)))
            except Unavailable:
                pass
        elif op < 0.70:
            ids = list(range(n))
            rng.shuffle(ids)
            cut = rng.randint(1, 2)
            if rng.random() < 0.3:
                plane.net.partition_oneway(ids[:cut], ids[cut:])
            else:
                plane.net.partition(ids[:cut], ids[cut:])
        elif op < 0.80:
            plane.net.heal()
        elif op < 0.90:
            alive = [r.rid for r in meta.replicas if r.alive]
            if len(alive) * 2 > n + 2:
                try:
                    meta.fail_replica(rng.choice(alive))
                except Unavailable:
                    pass
        else:
            dead = [r.rid for r in meta.replicas if not r.alive]
            if dead:
                meta.recover_replica(rng.choice(dead))
    plane.heal()
    for r in meta.replicas:
        if not r.alive:
            meta.recover_replica(r.rid)
    assert meta.check_convergence()
    leader = meta.leader
    for r in meta.replicas:                # digests agree at equal commit
        assert r.commit_index == leader.commit_index
        assert r.last_index == leader.last_index
