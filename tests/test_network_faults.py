"""Message-level network fault plane + term-fenced leadership (DESIGN.md §16).

Directed tests for the tentpole mechanisms: symmetric/asymmetric partitions
over the replication traffic, majority-side election progress, stale-leader
term fencing (``NotLeader``), lease-fenced local reads (``LeaseExpired``),
divergent-suffix reconciliation on heal, per-link fault overrides, duplicate/
reorder absorption, the same-seed replay guarantee for message faults, and
the ``advance()`` same-timestamp tiebreak regression (ISSUE 8 satellite).
"""

import pytest

from repro.core import (BoltSystem, FaultConfig, FaultPlane, LinkFaults,
                        RetryPolicy)
from repro.core.errors import (LeaseExpired, NoQuorum, NotLeader,
                               RetryBudgetExhausted, Unavailable)
from repro.core.raft import MetadataService


def make_meta(n=5, attempts=8, **cfg_kwargs):
    """A standalone metadata group with a §16 plane attached."""
    plane = FaultPlane(FaultConfig(**cfg_kwargs))
    meta = MetadataService(n_replicas=n)
    meta.faults = plane
    meta.retry = RetryPolicy(attempts=attempts)
    return meta, plane


# ---------------------------------------------------------------------------
# message mode with a perfect network == direct mode
# ---------------------------------------------------------------------------

def test_zero_fault_message_mode_matches_direct():
    """A plane with no armed faults routes replication through messages, yet
    the observable outcome is identical to the direct path."""
    direct = MetadataService(n_replicas=5)
    msg, _plane = make_meta(5)
    for meta in (direct, msg):
        root = meta.propose(("create_root", "r"))
        for i in range(20):
            meta.propose(("append", root, f"o{i}", (0,), (4,)))
        meta.fail_replica(meta.leader_id)          # failover mid-stream
        for i in range(20, 30):
            meta.propose(("append", root, f"o{i}", (0,), (4,)))
        assert meta.state.tail(root) == 30
        assert meta.check_convergence()
    assert direct.leader_id == msg.leader_id
    assert direct.proposals == msg.proposals


# ---------------------------------------------------------------------------
# partitions: majority progress, stale-leader fencing, reconciliation
# ---------------------------------------------------------------------------

def test_minority_partition_majority_side_elects_and_serves():
    meta, plane = make_meta(5)
    root = meta.propose(("create_root", "r"))
    old = meta.leader_id
    plane.net.partition([0, 1], [2, 3, 4])         # leader 0 on the minority
    pos = meta.propose(("append", root, "p0", (0,), (4,)))
    assert pos == [0]                              # the client was served
    assert meta.leader_id in {2, 3, 4}             # by a majority-side leader
    assert meta.replicas[old].is_leader            # 0 has not learned yet
    assert meta.replicas[meta.leader_id].is_leader
    assert meta.state.tail(root) == 1


def test_stale_leader_is_term_fenced_after_heal():
    meta, plane = make_meta(5)
    root = meta.propose(("create_root", "r"))
    old = meta.leader_id
    plane.net.partition([0, 1], [2, 3, 4])
    meta.propose(("append", root, "p0", (0,), (4,)))   # elects on {2,3,4}
    # while partitioned the deposed leader cannot commit: no majority, and
    # no replica it can reach fences it either — it just fails
    with pytest.raises((NoQuorum, RetryBudgetExhausted)):
        meta.propose_via(old, ("append", root, "stale", (0,), (4,)))
    assert meta.replicas[old].is_leader            # still believes
    plane.net.heal()
    # healed: its stale term now reaches replicas that adopted a higher one
    with pytest.raises(NotLeader):
        meta.propose_via(old, ("append", root, "stale2", (0,), (4,)))
    assert not meta.replicas[old].is_leader        # deposition observed
    assert plane.counters.get("fenced_rejections", 0) > 0
    # nothing the stale leader tried ever committed
    assert meta.check_convergence()
    assert meta.state.tail(root) == 1


def test_divergent_minority_suffix_truncated_on_heal():
    meta, plane = make_meta(5)
    root = meta.propose(("create_root", "r"))
    old = meta.leader_id
    plane.net.partition([0, 1], [2, 3, 4])
    # several failed attempts leave lingering uncommitted entries on {0, 1}
    for i in range(3):
        with pytest.raises((NoQuorum, RetryBudgetExhausted, Unavailable)):
            meta.propose_via(old, ("append", root, f"junk{i}", (0,), (4,)))
    junk_len = meta.replicas[old].last_index
    # the majority side commits real entries at the same indices
    for i in range(5):
        meta.propose(("append", root, f"real{i}", (0,), (4,)))
    assert meta.replicas[old].last_index == junk_len   # divergence is real
    plane.net.heal()
    assert meta.check_convergence()                # reconciliation ran
    leader = meta.leader
    for r in meta.replicas:
        assert r.last_index == leader.last_index
        assert [e.cmd for e in r.log] == [e.cmd for e in leader.log]
    assert meta.state.tail(root) == 5              # junk never surfaced


def test_lease_fenced_read_expires_for_deposed_leader():
    meta, plane = make_meta(5)
    root = meta.propose(("create_root", "r"))
    old = meta.leader_id
    plane.net.partition([0, 1], [2, 3, 4])
    meta.propose(("append", root, "p0", (0,), (4,)))   # fails over
    new = meta.leader_id
    # the new leader's lease was granted by its commit round at now=0
    assert meta.read_fenced(new).tail(root) == 1
    # advance the DES clock past the stale leader's lease horizon
    plane.advance(meta.replicas[old].lease_until + 0.01)
    with pytest.raises(LeaseExpired):
        meta.read_fenced(old)
    # a committing leader keeps extending its lease
    meta.propose(("append", root, "p1", (0,), (4,)))
    assert meta.read_fenced(new).tail(root) == 2
    # a replica that never led rejects locally
    follower = next(r.rid for r in meta.replicas
                    if r.rid not in (old, new))
    with pytest.raises(NotLeader):
        meta.read_fenced(follower)


def test_asymmetric_partition_loses_acks_not_requests():
    meta, plane = make_meta(3)
    root = meta.propose(("create_root", "r"))
    plane.net.partition_oneway([1], [0])           # 1's replies to 0 vanish
    for i in range(6):
        meta.propose(("append", root, f"o{i}", (0,), (4,)))
    # follower 1 RECEIVED the entries (request leg delivers) but its acks
    # died, so the leader committed through follower 2
    assert meta.replicas[1].last_index == meta.leader.last_index
    assert plane.counters.get("msgs_partitioned", 0) > 0
    plane.net.heal()
    assert meta.check_convergence()
    assert meta.state.tail(root) == 6


# ---------------------------------------------------------------------------
# probabilistic link faults
# ---------------------------------------------------------------------------

def test_per_link_fault_override_flapping_link():
    cfg = dict(link_faults={(0, 1): LinkFaults(drop=1.0)})
    meta, plane = make_meta(3, **cfg)
    root = meta.propose(("create_root", "r"))
    for i in range(8):
        meta.propose(("append", root, f"o{i}", (0,), (4,)))
    # the 0->1 link is dead, yet every propose committed via follower 2
    assert meta.state.tail(root) == 8
    assert plane.counters["msgs_dropped"] >= 8
    assert meta.replicas[1].last_index < meta.leader.last_index
    plane.heal()                                    # disarm + drain
    assert meta.check_convergence()                 # reconciliation catches 1 up
    assert meta.replicas[1].last_index == meta.leader.last_index


def test_drop_delay_duplicate_reorder_absorbed_exactly_once():
    meta, plane = make_meta(5, attempts=10, seed=77, net_drop=0.15,
                            net_delay=0.10, net_duplicate=0.10,
                            net_reorder=0.10)
    root = meta.propose(("create_root", "r"))
    committed = []
    for i in range(40):
        plane.advance(plane.now + 1e-3)            # pump delayed messages
        try:
            meta.propose(("append", root, f"o{i}", (0,), (4,)))
        except Unavailable:
            pass                                   # at-most-once: may land
        else:
            committed.append(i)
    assert committed                               # the group made progress
    for site in ("msgs_dropped", "msgs_delayed", "msgs_duplicated",
                 "msgs_reordered"):
        assert plane.counters.get(site, 0) > 0, site
    plane.heal()
    assert meta.check_convergence()
    # exactly-once for resolved proposals, at-most-once for unknown ones
    tail = meta.state.tail(root)
    assert len(committed) <= tail <= 40


def test_same_seed_replays_identical_message_fault_sequence():
    def run(seed):
        meta, plane = make_meta(5, attempts=6, seed=seed, net_drop=0.2,
                                net_delay=0.1, net_duplicate=0.05,
                                net_reorder=0.05)
        root = meta.propose(("create_root", "r"))
        for i in range(30):
            plane.advance(plane.now + 1e-3)
            try:
                meta.propose(("append", root, f"o{i}", (0,), (4,)))
            except Unavailable:
                pass
        return (dict(plane.counters), meta.retry_stats.retries,
                meta.term, meta.state.tail(root))

    assert run(42) == run(42)
    assert run(42) != run(43)


# ---------------------------------------------------------------------------
# DES schedules: partitions over time + the tiebreak regression
# ---------------------------------------------------------------------------

def test_scheduled_partition_and_heal_end_to_end():
    cfg = FaultConfig(seed=21,
                      schedule=((0.3, "partition", ((0, 1), (2, 3, 4))),
                                (0.7, "heal_network", None)))
    system = BoltSystem(n_brokers=2, n_meta_replicas=5, faults=cfg,
                        retry=RetryPolicy(attempts=10))
    log = system.create_log("events")
    want = []
    for i in range(100):
        system.faults.advance(i / 100.0)
        rec = b"ev-%03d" % i
        log.append(rec)
        want.append(rec)
    system.flush()
    assert system.metadata.elections >= 1          # the partition forced one
    assert system.metadata.leader_id in {2, 3, 4}
    system.faults.heal()
    assert log.read(0, 100) == want                # all acked, none lost,
    assert system.metadata.state.tail(log.log_id) == 100   # none duplicated
    assert system.metadata.check_convergence()


def test_advance_tiebreak_same_timestamp_fires_in_schedule_order():
    """ISSUE 8 satellite: same-timestamp events with mutually incomparable
    targets (tuple / None / int) must fire in original schedule order — the
    pre-fix sort over raw triples was a TypeError on this schedule."""
    sched = ((0.2, "partition", ((0, 1), (2, 3, 4))),
             (0.2, "heal_network", None),
             (0.2, "kill_replica", 4),
             (0.2, "recover_replica", 4))
    cfg = FaultConfig(seed=5, schedule=sched)
    system = BoltSystem(n_meta_replicas=5, faults=cfg)
    fired = system.faults.advance(1.0)
    assert fired == 4
    assert system.faults.events_fired == list(sched)
    # order mattered: partition healed BEFORE the kill/recover pair ran,
    # and the kill fired before the recover (replica 4 is back up)
    assert not system.faults.net.blocked(0, 2)
    assert system.metadata.replicas[4].alive

    def run():
        s = BoltSystem(n_meta_replicas=5, faults=FaultConfig(
            seed=5, schedule=sched, net_drop=0.1))
        s.faults.advance(1.0)
        log = s.create_log("r")
        for i in range(10):
            log.append(b"x%d" % i)
        return (s.faults.events_fired, dict(s.faults.counters))

    assert run() == run()                          # same-seed replay holds


def test_partition_events_need_no_bound_system():
    plane = FaultPlane(FaultConfig(
        schedule=((0.1, "partition", ((0,), (1, 2))),
                  (0.2, "heal_network", None))))
    assert plane.advance(0.15) == 1                # partition fired unbound
    assert plane.net.blocked(0, 1)
    assert plane.advance(0.25) == 1
    assert not plane.net.blocked(0, 1)
    # kill/recover kinds still demand bind() (seed behavior, §15)
    with pytest.raises(AssertionError):
        FaultPlane(FaultConfig(
            schedule=((0.1, "kill_broker", 0),))).advance(1.0)


def test_bolt_system_partition_helpers():
    system = BoltSystem(n_meta_replicas=5, faults=True,
                        retry=RetryPolicy(attempts=8))
    log = system.create_log("r")
    log.append(b"before")
    system.partition([0, 1], [2, 3, 4])
    log.append(b"during")                          # majority side serves
    system.heal_network()
    log.append(b"after")
    assert log.read(0, 3) == [b"before", b"during", b"after"]
    assert system.metadata.check_convergence()
