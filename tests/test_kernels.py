"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.ref import flash_attention_ref, mlstm_ref

pytestmark = pytest.mark.slow  # JAX tracing/compilation; fast lane: -m 'not slow'


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


FLASH_CASES = [
    # (B, H, KH, S, Dh, dtype, causal, bq, bk)
    (1, 2, 2, 128, 64, jnp.float32, True, 64, 64),
    (2, 4, 2, 256, 64, jnp.float32, True, 128, 128),
    (2, 8, 2, 256, 128, jnp.bfloat16, True, 128, 64),
    (1, 3, 1, 384, 64, jnp.float32, True, 128, 128),   # GQA G=3
    (2, 4, 4, 256, 64, jnp.float32, False, 128, 128),  # non-causal (encoder)
    (1, 2, 1, 512, 32, jnp.bfloat16, True, 128, 128),
]


@pytest.mark.parametrize("B,H,KH,S,Dh,dtype,causal,bq,bk", FLASH_CASES)
def test_flash_attention_matches_ref(B, H, KH, S, Dh, dtype, causal, bq, bk):
    rng = np.random.default_rng(hash((B, H, S)) % 2**31)
    q = _rand(rng, (B, H, S, Dh), dtype)
    k = _rand(rng, (B, KH, S, Dh), dtype)
    v = _rand(rng, (B, KH, S, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


MLSTM_CASES = [
    # (B, H, S, Dh, chunk)
    (1, 2, 64, 32, 16),
    (2, 3, 128, 64, 32),
    (1, 4, 128, 128, 64),
    (2, 2, 96, 32, 32),
]


@pytest.mark.parametrize("B,H,S,Dh,chunk", MLSTM_CASES)
def test_mlstm_chunk_matches_recurrent_ref(B, H, S, Dh, chunk):
    rng = np.random.default_rng(hash((B, H, S, Dh)) % 2**31)
    q = _rand(rng, (B, H, S, Dh), jnp.float32)
    k = _rand(rng, (B, H, S, Dh), jnp.float32) * Dh ** -0.5
    v = _rand(rng, (B, H, S, Dh), jnp.float32)
    li = _rand(rng, (B, H, S), jnp.float32)
    lf = jax.nn.log_sigmoid(_rand(rng, (B, H, S), jnp.float32) + 2.0)
    out = mlstm_chunk(q, k, v, li, lf, chunk=chunk, interpret=True)
    ref, _ = mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_mlstm_kernel_matches_model_layer():
    """The kernel agrees with the model's jnp chunked path too."""
    from repro.models.xlstm import mlstm_sequence
    rng = np.random.default_rng(7)
    B, H, S, Dh = 2, 2, 128, 32
    q = _rand(rng, (B, H, S, Dh), jnp.float32)
    k = _rand(rng, (B, H, S, Dh), jnp.float32)
    v = _rand(rng, (B, H, S, Dh), jnp.float32)
    li = _rand(rng, (B, H, S), jnp.float32)
    lf = jax.nn.log_sigmoid(_rand(rng, (B, H, S), jnp.float32))
    h_model, _ = mlstm_sequence(q, k, v, li, lf, chunk=32)
    h_kernel = mlstm_chunk(q, k, v, li, lf, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_kernel),
                               rtol=3e-4, atol=3e-4)
