"""Hold-tolerant metadata fast path (DESIGN.md §11).

The regime PR 2's suite could not exercise: with the old global gate the
flattened-view cache turned OFF whenever any promotable cFork existed, so
cached-vs-uncached comparisons under holds compared the slow path with
itself. Now the cache stays engaged per lineage, so these tests assert both
*correctness* (span-for-span equality with the exact resolver, including
raised ForkBlocked, while holds are active) and *engagement* (the reads
really were served from views, via ViewStats).
"""

import pickle
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.errors import AgileLogError, ForkBlocked, InvalidOperation
from repro.core.metadata import MetadataState
from repro.core.raft import MetadataService


# ---------------------------------------------------------------------------
# property suite: cached == uncached with promotable holds ACTIVE
# ---------------------------------------------------------------------------

class HoldingRunner:
    """Like test_read_path.DualStateRunner, but biased so that promotable
    holds are usually live while reads happen: high promotable-cFork rate,
    deliberate reads on the holder, the promotable child, its descendants,
    and unrelated sibling branches."""

    def __init__(self, seed: int, promote_mode: str):
        self.rng = random.Random(seed)
        self.cached = MetadataState(view_cache=True, promote_mode=promote_mode)
        self.plain = MetadataState(view_cache=False, promote_mode=promote_mode)
        ra = self._both(("create_root", "r"))[0]
        # a second topic: reads here must stay fast however many holds exist
        rb = self._both(("create_root", "other-topic"))[0]
        self.live = [ra, rb]
        self.obj = 0

    def _both(self, cmd):
        res, errs = [], []
        for state in (self.cached, self.plain):
            try:
                res.append(state.apply(cmd))
                errs.append(None)
            except AgileLogError as e:
                res.append(None)
                errs.append(type(e).__name__)
        assert errs[0] == errs[1], f"error mismatch on {cmd}: {errs}"
        assert res[0] == res[1], f"result mismatch on {cmd}: {res}"
        return res[0], errs[0]

    def _compare_reads(self, lid: int):
        tail = self.plain.tail(lid)
        lo = self.rng.randint(0, tail)
        hi = self.rng.randint(lo, tail)
        outs, errs = [], []
        for state in (self.cached, self.plain):
            try:
                outs.append((state.read_spans(lid, lo, hi),
                             state.read_record_spans(lid, lo, hi)))
                errs.append(None)
            except AgileLogError as e:
                outs.append(None)
                errs.append(type(e).__name__)
        assert errs[0] == errs[1], \
            f"read error mismatch on log {lid} [{lo},{hi}): {errs}"
        assert outs[0] == outs[1], f"span mismatch on log {lid} [{lo},{hi})"

    def step(self):
        rng = self.rng
        lid = rng.choice(self.live)
        op = rng.random()
        if op < 0.40:
            k = rng.randint(1, 4)
            sizes = [rng.randint(1, 64) for _ in range(k)]
            offsets, off = [], 0
            for s in sizes:
                offsets.append(off)
                off += s
            self._both(("append", lid, f"o{self.obj}",
                        tuple(offsets), tuple(sizes)))
            self.obj += 1
        elif op < 0.60:
            # promotable-heavy: the whole point of this suite
            self._both(("cfork", lid, rng.random() < 0.6))
        elif op < 0.68:
            past = None
            tail = self.plain.tail(lid)
            if tail > 0 and rng.random() < 0.5:
                past = rng.randrange(tail)
            self._both(("sfork", lid, past))
        elif op < 0.76:
            self._both(("promote", lid, rng.choice(["copy", "splice"])))
        elif op < 0.82:
            self._both(("squash", lid))
        self.live = self.cached.live_log_ids()
        assert self.live == self.plain.live_log_ids()
        for _ in range(3):
            self._compare_reads(rng.choice(self.live))


@pytest.mark.parametrize("promote_mode", ["copy", "splice"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=25, deadline=None)
def test_cached_resolver_matches_plain_under_holds(promote_mode, seed):
    runner = HoldingRunner(seed, promote_mode=promote_mode)
    for _ in range(70):
        runner.step()
    for lid in runner.live:
        for _ in range(4):
            runner._compare_reads(lid)


def test_hold_heavy_traces_actually_hit_the_cache():
    """Meta-assertion for the suite above: across a handful of seeds the
    cached state must serve a sizable share of reads from views (hits or
    capped hits) even though promotable holds are active most of the time —
    otherwise this suite would be comparing the slow path with itself, the
    exact blind spot it exists to remove."""
    cached = capped = slow = 0
    for seed in range(8):
        runner = HoldingRunner(seed, promote_mode="splice")
        for _ in range(60):
            runner.step()
        stats = runner.cached.stats
        cached += stats.cached_reads
        capped += stats.capped_hits
        slow += stats.slow_reads
    assert capped > 0, "no read was ever served from a view under a lineage hold"
    assert cached >= slow, f"cache mostly disengaged: {cached} vs {slow}"


# ---------------------------------------------------------------------------
# lineage scoping: holds elsewhere never disengage an unrelated log's cache
# ---------------------------------------------------------------------------

def _fill(state, log_id, n, tag, batch=64):
    done = 0
    while done < n:
        k = min(batch, n - done)
        state.apply(("append", log_id, f"{tag}-{done}",
                     tuple(range(0, 8 * k, 8)), tuple([8] * k)))
        done += k


def test_sibling_branch_reads_stay_cached_under_hold():
    state = MetadataState(view_cache=True)
    root = state.apply(("create_root", "r"))
    _fill(state, root, 64, "r")
    a = state.apply(("cfork", root, False))       # agent branch
    b = state.apply(("cfork", root, False))       # serving branch
    _fill(state, a, 32, "a")
    _fill(state, b, 32, "b")
    state.read_spans(b, 0, 96)                    # warm b's view
    hold = state.apply(("cfork", a, True))        # hold on the AGENT branch
    assert state._holders == {a}
    s0 = state.stats.slow_reads
    h0 = state.stats.hits
    for _ in range(5):
        assert state.read_spans(b, 0, 96)         # b's lineage: {b, root}
        assert state.read_spans(root, 0, 64)
    assert state.stats.slow_reads == s0, \
        "reads on a sibling branch fell back to the chain walk"
    assert state.stats.hits >= h0 + 10
    # the holder itself: visible prefix served from its (capped) view
    c0 = state.stats.capped_hits
    assert state.read_spans(a, 0, 96)             # fp is at tail: all visible
    assert state.stats.capped_hits > c0
    # the promotable child is entitled to read EVERYTHING, cached
    _fill(state, a, 16, "hidden")                 # withheld parent appends
    c1 = state.stats.capped_hits
    assert state.read_spans(hold, 0, state.tail(hold))
    assert state.stats.capped_hits > c1


def test_holder_reads_beyond_fork_point_still_blocked():
    state = MetadataState(view_cache=True)
    root = state.apply(("create_root", "r"))
    _fill(state, root, 16, "r")
    state.read_spans(root, 0, 16)                 # warm the view past fp
    state.apply(("cfork", root, True))            # fp = 16
    _fill(state, root, 8, "withheld")
    assert state.read_spans(root, 0, 16)          # visible prefix: cached
    with pytest.raises(ForkBlocked):
        state.read_spans(root, 0, 20)             # crosses fp: exact error
    # descendants on the blocked lineage are capped identically
    plain = MetadataState(view_cache=False)
    plain.apply(("create_root", "r"))
    _fill(plain, 0, 16, "r")
    plain.apply(("cfork", 0, True))
    _fill(plain, 0, 8, "withheld")
    assert state.read_spans(root, 4, 12) == plain.read_spans(0, 4, 12)


# ---------------------------------------------------------------------------
# scoped invalidation
# ---------------------------------------------------------------------------

def test_promote_keeps_views_on_unrelated_logs():
    state = MetadataState(view_cache=True, promote_mode="splice")
    root = state.apply(("create_root", "r"))
    _fill(state, root, 8, "r")
    other = state.apply(("create_root", "other"))
    _fill(state, other, 8, "o")
    unrelated = [state.apply(("cfork", other, False)) for _ in range(4)]
    for u in unrelated:
        state.read_spans(u, 0, 8)                 # warm views on other topic
    state.read_spans(root, 0, 8)
    child = state.apply(("cfork", root, True))
    state.apply(("append", child, "c", (0,), (8,)))
    state.apply(("promote", child, "splice"))
    assert root not in state._views, "promoted-into log's view must drop"
    for u in unrelated:
        assert u in state._views, "unrelated topic's views must survive promote"
    # and the surviving views still serve exact spans
    plain = MetadataState(view_cache=False)
    plain.apply(("create_root", "r"))
    _fill(plain, 0, 8, "o")                       # same content as `other`
    got = state.read_record_spans(unrelated[0], 0, 8)
    assert [s[0].split("-")[0] for s in got] == ["o"] * 8


def test_squash_keeps_parent_and_sibling_views():
    state = MetadataState(view_cache=True)
    root = state.apply(("create_root", "r"))
    _fill(state, root, 8, "r")
    keeper = state.apply(("cfork", root, False))
    victim = state.apply(("cfork", root, False))
    state.read_spans(root, 0, 8)
    state.read_spans(keeper, 0, 8)
    state.read_spans(victim, 0, 8)
    state.apply(("squash", victim))
    assert victim not in state._views
    assert root in state._views and keeper in state._views, \
        "squash must only drop views through the removed subtree"
    # the surviving views still resolve the same bytes as a fresh resolution
    plain = MetadataState(view_cache=False)
    plain.apply(("create_root", "r"))
    _fill(plain, 0, 8, "r")
    assert state.read_record_spans(keeper, 0, 8) == plain.read_record_spans(0, 0, 8)


def test_stale_view_version_is_dropped_not_served():
    """Belt-and-braces: a view whose version predates a wholesale clear is
    discarded on next read even if it somehow survived in the dict."""
    state = MetadataState(view_cache=True)
    root = state.apply(("create_root", "r"))
    _fill(state, root, 8, "r")
    state.read_spans(root, 0, 8)
    view = state._views[root]
    state._invalidate_views()
    state._views[root] = view                     # simulate a leak
    assert state.read_spans(root, 0, 8)
    assert state._views[root] is not view, "stale-version view must be rebuilt"


def test_cached_read_checks_current_tail():
    """Satellite regression (ISSUE 3): the old covered-view branch skipped
    the `hi <= tail` bound, so any restructure that shrank a log's range
    could serve stale spans from a wide view. Shrink the tail out from under
    a built view and require InvalidOperation, not data."""
    state = MetadataState(view_cache=True)
    root = state.apply(("create_root", "r"))
    _fill(state, root, 12, "r")
    state.read_spans(root, 0, 12)                 # view covers [0, 12)
    assert state._views[root].hi == 12
    state.tails.range_add(root, d_tail=-4)        # simulate a shrinking splice
    with pytest.raises(InvalidOperation):
        state.read_spans(root, 0, 12)
    with pytest.raises(InvalidOperation):
        state.read_record_spans(root, 10, 11)
    assert state.read_spans(root, 0, 8)           # in-range still served


# ---------------------------------------------------------------------------
# promote re-bind regression (pre-existing bug exposed by view extension)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("second_mode", ["copy", "splice"])
def test_live_fork_chain_bottom_survives_parent_promote(second_mode):
    """A live cFork whose own chain bottom is a frozen splice stand-in kept
    inheriting from the root; promoting ANOTHER child of the root used to
    re-bind that stand-in onto the root's position-capped pre-promote
    snapshot, leaving the live fork with unresolvable (UnknownLog) positions
    for everything the root appended afterwards."""
    for view_cache in (False, True):              # plain resolver had it too
        state = MetadataState(view_cache=view_cache, promote_mode="splice")
        root = state.apply(("create_root", "r"))
        state.apply(("append", root, "a", (0,), (8,)))
        fork = state.apply(("cfork", root, False))          # live fork of root
        inner = state.apply(("cfork", fork, True))
        state.apply(("append", inner, "b", (0,), (8,)))
        state.apply(("promote", inner, "splice"))  # fork -> frozen -> root
        promo = state.apply(("cfork", root, True))
        state.apply(("append", promo, "c", (0,), (8,)))
        state.apply(("promote", promo, second_mode))
        state.apply(("append", root, "d", (0,), (8,)))      # post-promote root data
        tail = state.tail(fork)
        spans = state.read_record_spans(fork, 0, tail)      # must not raise
        assert [s[0] for s in spans] == ["a", "b", "c", "d"]
        assert spans == [("a", 0, 8), ("b", 0, 8), ("c", 0, 8), ("d", 0, 8)]


# ---------------------------------------------------------------------------
# pipelined replica apply (raft)
# ---------------------------------------------------------------------------

def test_followers_defer_apply_until_forced():
    svc = MetadataService(n_replicas=3, pipeline_apply=True)
    root = svc.propose(("create_root", "r"))
    for i in range(10):
        svc.propose(("append", root, f"o{i}", (0,), (8,)))
    followers = [r for r in svc.replicas if r is not svc.leader]
    assert all(f.pending_applies == 11 for f in followers), \
        "pipelined followers must not apply on the propose critical path"
    assert all(f.commit_index == svc.leader.commit_index for f in followers)
    assert svc.leader.pending_applies == 0
    assert svc.check_convergence()                # forces the deferred batch
    assert all(f.pending_applies == 0 for f in followers)
    assert all(f.lazy_applies == 11 for f in followers)


def test_sync_mode_preserves_seed_behavior():
    svc = MetadataService(n_replicas=3, pipeline_apply=False)
    root = svc.propose(("create_root", "r"))
    svc.propose(("append", root, "o", (0,), (8,)))
    assert all(r.pending_applies == 0 for r in svc.replicas)
    assert svc.check_convergence()


def test_failover_drains_backlog_before_serving():
    svc = MetadataService(n_replicas=3, pipeline_apply=True)
    root = svc.propose(("create_root", "r"))
    for i in range(20):
        svc.propose(("append", root, f"o{i}", (0, 8), (8, 8)))
    old_leader = svc.leader_id
    svc.fail_replica(old_leader)
    assert svc.leader_id != old_leader
    # the new leader must answer linearizable queries immediately
    assert svc.state.tail(root) == 40
    assert len(svc.state.read_spans(root, 0, 40)) >= 1
    svc.propose(("append", root, "post", (0,), (8,)))
    assert svc.state.tail(root) == 41


def test_snapshot_forces_pending_applies():
    svc = MetadataService(n_replicas=3, snapshot_every=5, pipeline_apply=True)
    root = svc.propose(("create_root", "r"))
    for i in range(9):
        svc.propose(("append", root, f"o{i}", (0,), (8,)))
    # snapshot_every=5 fired at least once: snapshots serialize APPLIED state
    for r in svc.replicas:
        assert r.snapshot_index >= 0
        restored = pickle.loads(r.snapshot)
        assert restored.tail(root) == r.snapshot_index  # root + k appends

    victim = (svc.leader_id + 1) % 3
    svc.fail_replica(victim)
    for i in range(7):
        svc.propose(("append", root, f"p{i}", (0,), (8,)))
    svc.recover_replica(victim)
    assert svc.replicas[victim].state.tail(root) == 16
    assert svc.check_convergence()


def test_convergence_digest_catches_content_divergence():
    """Satellite regression (ISSUE 3): replicas agreeing on membership and
    tails but differing in index-run CONTENT (a promote splice replayed
    differently) must fail the convergence check."""
    svc = MetadataService(n_replicas=3, pipeline_apply=True)
    root = svc.propose(("create_root", "r"))
    svc.propose(("append", root, "good", (0, 8), (8, 8)))
    assert svc.check_convergence()
    follower = next(r for r in svc.replicas if r is not svc.leader)
    # corrupt one follower's byte mapping without touching its tail
    run = follower.state.logs[root].index.runs()[0]
    run.object_id = "evil"
    assert follower.state.tails.get(root) == svc.leader.state.tails.get(root)
    assert not svc.check_convergence(), \
        "same tails + different content must not pass convergence"
