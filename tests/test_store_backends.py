"""Backend-conformance suite for the object-store protocol (DESIGN.md §18).

Every backend — memory, file, tiered, ranged — must present the SAME
contract: put/get/ranged-get/delete/exists/size/list semantics, one typed
miss (:class:`ObjectMissing`, never a backend-native ``KeyError``/
``FileNotFoundError``), the §15 fault hooks at every entry point (torn PUTs
commit their prefix then raise), and the op counters ``OpTally`` captures.
The file backend additionally owns the crash-consistency story: atomic
tmp+rename PUTs with file AND parent-directory fsync, and a ``*.tmp``
carcass sweep on open (a crash between write and rename leaves an un-acked,
unreferenced tmp file — mirroring ``resync()``'s orphan sweep).
"""

import os

import pytest

from repro.core import (BoltSystem, FaultConfig, FaultPlane, FileObjectStore,
                        MemoryObjectStore, ObjectMissing, RangedStore,
                        StoreFault, TieredObjectStore)

BACKENDS = ["memory", "file", "tiered", "ranged"]


@pytest.fixture(params=BACKENDS)
def store(request, tmp_path):
    if request.param == "memory":
        return MemoryObjectStore()
    if request.param == "file":
        return FileObjectStore(str(tmp_path / "store"))
    if request.param == "tiered":
        return TieredObjectStore()
    return RangedStore()


# ---------------------------------------------------------------------------
# core semantics, identical across backends
# ---------------------------------------------------------------------------

def test_put_get_roundtrip(store):
    store.put("a/b/k1", b"hello world")
    assert store.get("a/b/k1") == b"hello world"
    store.put("a/b/k1", b"overwritten")        # PUT replaces
    assert store.get("a/b/k1") == b"overwritten"


def test_ranged_get(store):
    store.put("k", b"0123456789")
    assert store.get("k", 2, 3) == b"234"
    assert store.get("k", 0, 10) == b"0123456789"
    assert store.get("k", 8, 100) == b"89"     # truncates at the end
    assert store.get("k", 50, 4) == b""        # offset past the end
    assert store.get("k", 4) == b"456789"      # open-ended suffix


def test_missing_key_is_object_missing(store):
    with pytest.raises(ObjectMissing):
        store.get("nope")
    with pytest.raises(ObjectMissing):
        store.get("nope", 0, 4)                # ranged miss types the same
    # backward compat: the dict-backed seed raised KeyError; callers that
    # caught it keep working against every backend
    with pytest.raises(KeyError):
        store.get("nope")
    err = pytest.raises(ObjectMissing, store.get, "nope").value
    assert err.key == "nope"
    assert "nope" in str(err)


def test_delete_exists_size(store):
    store.put("k", b"abcd")
    assert store.exists("k")
    assert store.size("k") == 4
    store.delete("k")
    assert not store.exists("k")
    assert store.size("k") is None
    with pytest.raises(ObjectMissing):
        store.get("k")
    store.delete("k")                          # idempotent


def test_list_prefix(store):
    store.put("seg-1", b"a")
    store.put("seg-2", b"b")
    store.put("obj-1", b"c")
    assert store.list("seg-") == ["seg-1", "seg-2"]
    assert store.list() == ["obj-1", "seg-1", "seg-2"]


def test_op_counters(store):
    store.put("k", b"abcdef")
    store.get("k", 0, 2)
    store.delete("k")
    assert store.put_count == 1
    assert store.bytes_written == 6
    assert store.get_count == 1
    assert store.bytes_read == 2
    assert store.delete_count == 1
    assert store.bytes_deleted == 6


# ---------------------------------------------------------------------------
# fault hooks on every backend (§15 — the seed only wired the dict stores)
# ---------------------------------------------------------------------------

def test_injected_get_and_delete_faults(store):
    plane = FaultPlane(FaultConfig(store_get_error=1.0,
                                   store_delete_error=1.0))
    store.put("k", b"data")
    store.attach_faults(plane)
    with pytest.raises(StoreFault):
        store.get("k")
    with pytest.raises(StoreFault):
        store.delete("k")
    store.attach_faults(None)
    assert store.get("k") == b"data"           # nothing was actually lost


def test_torn_put_commits_prefix_then_raises(store):
    plane = FaultPlane(FaultConfig(seed=7, store_put_torn=1.0))
    store.attach_faults(plane)
    data = b"x" * 1000
    with pytest.raises(StoreFault):
        store.put("torn", data)
    # the torn prefix is durably visible under the key — the §13/§15 orphan
    # paths (resync) are what reclaim it, not the store
    assert store.exists("torn")
    assert store.size("torn") < len(data)
    assert plane.counters.get("store_put_torn", 0) == 1


# ---------------------------------------------------------------------------
# file backend: crash consistency
# ---------------------------------------------------------------------------

def test_file_store_sweeps_tmp_carcasses_on_open(tmp_path):
    root = str(tmp_path / "store")
    s1 = FileObjectStore(root)
    s1.put("live", b"data")
    # a crash between the tmp write and the rename leaves a carcass
    with open(os.path.join(root, "seg-crashed.tmp"), "wb") as f:
        f.write(b"partial")
    s2 = FileObjectStore(root)                 # reopen = crash recovery
    assert s2.tmp_swept == 1
    assert not os.path.exists(os.path.join(root, "seg-crashed.tmp"))
    assert s2.get("live") == b"data"           # completed PUTs survive
    assert s2.list() == ["live"]


def test_file_store_persists_across_reopen(tmp_path):
    root = str(tmp_path / "store")
    s1 = FileObjectStore(root)
    s1.put("a/b", b"nested")
    s2 = FileObjectStore(root)
    assert s2.get("a/b") == b"nested"
    assert s2.total_bytes == 6


def test_file_store_list_skips_inflight_tmp(tmp_path):
    s = FileObjectStore(str(tmp_path / "store"))
    s.put("k", b"v")
    with open(os.path.join(s.root, "other.tmp"), "wb") as f:
        f.write(b"inflight")
    assert s.list() == ["k"]
    assert s.total_bytes == 1


# ---------------------------------------------------------------------------
# DES cost profiles (§18)
# ---------------------------------------------------------------------------

def test_profiles_present_only_on_modeled_backends(store):
    if isinstance(store, (FileObjectStore, RangedStore)):
        prof = store.profile
        assert prof.put_base > 0 and prof.get_base > 0
    else:
        # memory/tiered book the global ServiceTimes rates (pre-§18 model)
        assert store.profile is None


def test_ranged_store_bills_min_get_bytes():
    s = RangedStore()
    s.put("k", b"x" * 1024)
    s.get("k", 0, 100)
    assert s.bytes_read == 100                  # logical traffic
    assert s.billed_read_bytes == s.profile.min_get_bytes   # billed floor
    s.get("k")                                  # whole object still >= floor?
    assert s.billed_read_bytes == 2 * s.profile.min_get_bytes


# ---------------------------------------------------------------------------
# BoltSystem(store_backend=...) selection + end-to-end under the file backend
# ---------------------------------------------------------------------------

def test_store_backend_selection(tmp_path):
    assert isinstance(BoltSystem(store_backend="memory").store,
                      MemoryObjectStore)
    assert isinstance(BoltSystem(store_backend="ranged").store, RangedStore)
    assert isinstance(BoltSystem(store_backend="tiered").store,
                      TieredObjectStore)
    sysf = BoltSystem(store_backend="file", store_root=str(tmp_path / "s"))
    assert isinstance(sysf.store, FileObjectStore)
    assert sysf.store.root == str(tmp_path / "s")
    with pytest.raises(ValueError, match="unknown store_backend"):
        BoltSystem(store_backend="tape")
    with pytest.raises(TypeError, match="not both"):
        BoltSystem(store=MemoryObjectStore(), store_backend="memory")


def test_file_backend_default_root_is_tempdir():
    system = BoltSystem(store_backend="file")
    assert isinstance(system.store, FileObjectStore)
    assert os.path.isdir(system.store.root)


@pytest.mark.parametrize("backend", BACKENDS)
def test_end_to_end_append_read_on_every_backend(backend, tmp_path):
    kwargs = {"store_root": str(tmp_path / "s")} if backend == "file" else {}
    system = BoltSystem(n_brokers=2, group_commit=8,
                        store_backend=backend, **kwargs)
    log = system.create_log("root")
    recs = [f"r{i}".encode() * 4 for i in range(20)]
    for r in recs:
        log.append(r)
    system.flush()
    assert log.tail == 20
    assert list(log.read(0, 20)) == recs
    fork = log.cfork()
    fork.append(b"forked")
    assert list(fork.read(0, 21))[-1] == b"forked"
    assert system.store.put_count > 0           # counters work everywhere
