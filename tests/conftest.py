"""Test-environment compatibility shims.

The property-test suite uses `hypothesis`, but the benchmark container cannot
pip-install extra packages. When the real library is absent we install a tiny
deterministic fallback into ``sys.modules`` implementing exactly the subset
the suite uses — ``given``, ``settings``, and the ``integers`` / ``lists`` /
``tuples`` / ``booleans`` / ``sampled_from`` strategies — as a seeded example
generator. It has no shrinking and no adaptive search; it simply runs each
property ``max_examples`` times with reproducible pseudo-random draws (the
RNG is seeded from the test's qualified name via crc32, so runs are stable
across processes regardless of PYTHONHASHSEED).

With a real `hypothesis` installed (see requirements.txt) this file is a
no-op and the full engine is used.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def integers(min_value=0, max_value=1 << 16):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size if max_size is not None else min_size + 10)
            return [elements._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def tuples(*elements):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elements))

    def binary(min_size=0, max_size=16):
        return _Strategy(lambda rng: rng.randbytes(rng.randint(min_size, max_size)))

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._gc_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters)
            strategies = dict(kw_strategies)
            # like hypothesis, positional strategies bind right-to-left
            for name, strat in zip(params[len(params) - len(arg_strategies):],
                                   arg_strategies):
                strategies[name] = strat
            remaining = [sig.parameters[p] for p in params if p not in strategies]
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(seed)
                # @settings may sit above @given (attribute lands on `wrapper`)
                # or below it (attribute lands on `fn`) — honor both orders
                n = getattr(wrapper, "_gc_max_examples",
                            getattr(fn, "_gc_max_examples", _DEFAULT_MAX_EXAMPLES))
                for _ in range(n):
                    drawn = {k: s._draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # hide strategy-bound params so pytest only supplies the rest
            # (fixtures / parametrize args)
            wrapper.__signature__ = sig.replace(parameters=remaining)
            del wrapper.__wrapped__  # pytest must not unwrap to the full signature
            return wrapper
        return deco

    hyp = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for _s in (integers, booleans, sampled_from, lists, tuples, binary):
        setattr(st_mod, _s.__name__, _s)
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None,
                                            filter_too_much=None)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
