"""Serving engine + checkpoint substrate integration tests (§17 APIs).

The deep suites live in ``test_serve_on_log.py`` / ``test_checkpoint_fork_gc``
— this file keeps the original end-to-end scenarios alive on the reworked
interfaces: a subscription-fed engine emitting (id, seq) token records, and a
CheckpointManager whose checkpoints are log forks."""

import jax
import numpy as np

from repro.core import BoltSystem
from repro.models.config import ModelConfig
from repro.models.lm import init_params
from repro.serve import ServeEngine, decode_response
from repro.streams import Producer, Topic
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def _tiny_cfg():
    return ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=1, d_ff=64, vocab_size=128,
                       tie_embeddings=True, attn_chunk=32)


def test_serve_engine_roundtrip():
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.key(0))
    system = BoltSystem(n_brokers=3)
    req = Topic.create(system, "req")
    resp = Topic.create(system, "resp")
    prod = Producer(req)
    rng = np.random.default_rng(0)
    for rid in range(3):
        prod.produce({"id": f"r{rid}",
                      "prompt": [int(t) for t in rng.integers(2, 128, 5)]})
    prod.flush()
    eng = ServeEngine(cfg, params, req, resp, batch_size=4)
    assert eng.poll_and_serve(gen_tokens=4) == 3
    # responses are per-token (id, seq) records on the shared stream
    log = resp.log
    out = decode_response(log.read(0, log.visible_tail))
    assert set(out) == {"r0", "r1", "r2"}
    assert all(len(toks) == 4 for toks in out.values())
    assert all(0 <= t < cfg.vocab_size for toks in out.values() for t in toks)
    # durable request cursor: nothing left to serve...
    assert eng.poll_and_serve() == 0
    # ...even for a RESTARTED engine in the same consumer group
    eng2 = ServeEngine(cfg, params, req, resp, batch_size=4)
    assert eng2.poll_and_serve() == 0
    assert system.serve_stats.requests == 3
    assert system.serve_stats.responses == 3


def test_checkpoint_atomic_roundtrip_and_gc():
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.key(1))
    opt_cfg = AdamWConfig()
    opt = adamw_init(params, opt_cfg)
    system = BoltSystem(n_brokers=2, gc=True)
    ckpt = CheckpointManager(system, keep=2)
    grads = jax.tree.map(lambda p: 0.01 * jax.numpy.ones_like(p), params)
    forks = {}
    for step in (10, 20, 30):
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        forks[step] = ckpt.save(step, params, opt,
                                extra={"cursor": [step, 0]})
    assert ckpt.latest_step() == 30
    step, p2, o2, extra = ckpt.restore()
    assert step == 30 and extra["cursor"] == [30, 0]
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # keep=2 pruned step 10: its data FORK is dead (squash -> §13 chain-GC),
    # steps 20/30 stay live and restorable
    logs = system.metadata.state.logs
    meta10 = logs.get(forks[10])
    assert meta10 is None or not meta10.alive
    assert ckpt.steps() == [20, 30]
    ckpt.restore(20)
