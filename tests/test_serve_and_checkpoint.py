"""Serving engine + checkpoint substrate integration tests."""

import jax
import numpy as np

from repro.core import BoltSystem
from repro.models.config import ModelConfig
from repro.models.lm import init_params
from repro.serve import ServeEngine
from repro.streams import Consumer, Producer, Topic
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def _tiny_cfg():
    return ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=1, d_ff=64, vocab_size=128,
                       tie_embeddings=True, attn_chunk=32)


def test_serve_engine_roundtrip():
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.key(0))
    system = BoltSystem(n_brokers=3)
    req = Topic.create(system, "req")
    resp = Topic.create(system, "resp")
    prod = Producer(req)
    rng = np.random.default_rng(0)
    for rid in range(3):
        prod.produce({"id": rid,
                      "prompt": [int(t) for t in rng.integers(2, 128, 5)]})
    prod.flush()
    eng = ServeEngine(cfg, params, req, resp, batch_size=4)
    n = eng.poll_and_serve(gen_tokens=4)
    assert n == 3
    out = Consumer(resp).poll(8)
    assert {r["id"] for r in out} == {0, 1, 2}
    assert all(len(r["tokens"]) == 4 for r in out)
    assert all(0 <= t < cfg.vocab_size for r in out for t in r["tokens"])
    # idempotent-ish: nothing left to serve
    assert eng.poll_and_serve() == 0


def test_checkpoint_atomic_roundtrip_and_gc():
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.key(1))
    opt_cfg = AdamWConfig()
    opt = adamw_init(params, opt_cfg)
    system = BoltSystem(n_brokers=2)
    ckpt = CheckpointManager(system.store, keep=2)
    grads = jax.tree.map(lambda p: 0.01 * jax.numpy.ones_like(p), params)
    for step in (10, 20, 30):
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        ckpt.save(step, params, opt, extra={"cursor": [step, 0]})
    assert ckpt.latest_step() == 30
    step, p2, o2, extra = ckpt.restore()
    assert step == 30 and extra["cursor"] == [30, 0]
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # keep=2 garbage-collected step 10
    assert not any("step-00000010" in k for k in system.store.list("ckpt/"))
    assert any("step-00000020" in k for k in system.store.list("ckpt/"))
