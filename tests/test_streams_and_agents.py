"""Streaming layer + agent applications + training data pipeline tests."""

import numpy as np
import pytest

from repro.agents import AnalyticsAgent, StreamTestingAgent, SupplyChainAgent
from repro.agents.supplychain import InventoryConsumer
from repro.core import BoltSystem
from repro.data import LogDataPipeline, TokenStreamWriter, synthetic_token_docs
from repro.streams import Consumer, Producer, Topic
from repro.streams.records import encode_record
from repro.streams.topics import StreamProcessor


@pytest.fixture
def system():
    return BoltSystem(n_brokers=4)


def _iot_topic(system, n=2000, anomalies=(500, 1500)):
    topic = Topic.create(system, "iot")
    prod = Producer(topic, linger_records=64)
    rng = np.random.default_rng(0)
    for i in range(n):
        temp = float(rng.normal(20.0, 0.5))
        hum = float(rng.normal(55.0, 1.0))
        status = "ok"
        if i in anomalies:
            temp += 40.0
            status = "sensor-fault"
        prod.produce({"ts": i * 0.001, "temperature": temp,
                      "humidity": hum, "status": status})
    prod.flush()
    return topic


# ---------------------------------------------------------------- streams layer
def test_producer_consumer_roundtrip(system):
    topic = Topic.create(system, "t")
    prod = Producer(topic, linger_records=8)
    for i in range(100):
        prod.produce({"i": i})
    prod.flush()
    cons = Consumer(topic)
    got = []
    while True:
        batch = cons.poll(17)
        if not batch:
            break
        got.extend(r["i"] for r in batch)
    assert got == list(range(100))
    cons.commit()
    cons2 = Consumer.restore(topic)
    assert cons2.offset == 100


def test_stream_processor_windows(system):
    topic = Topic.create(system, "w")
    prod = Producer(topic, linger_records=16)
    for i in range(50):
        prod.produce({"ts": float(i), "value": 2.0})
    prod.flush()
    out = Topic.create(system, "w-out")
    proc = StreamProcessor(topic, out, window_ms=10.0)
    proc.run_to_tail()
    assert len(proc.results) == 5
    assert all(r.count == 10 and r.aggregate == 20.0 for r in proc.results)
    assert out.tail == 5  # results written downstream


# ---------------------------------------------------------------- agents (§6.8)
def test_analytics_agent_finds_injected_anomalies(system):
    topic = _iot_topic(system, n=3000, anomalies=(700, 2100))
    root_tail_before = topic.tail
    agent = AnalyticsAgent(topic, scan_limit=3000, chunk=512)
    result = agent.run()
    spikes = result["spikes"].get("temperature", [])
    assert 700 in spikes and 2100 in spikes
    assert sorted(result["bad_status_positions"]) == [700, 2100]
    assert result["correlated"]  # spike correlated with sensor-fault status
    agent.cleanup()
    assert topic.tail == root_tail_before  # root untouched


def test_testing_agent_finds_processor_bugs_in_isolation(system):
    topic = Topic.create(system, "events")
    prod = Producer(topic, linger_records=32)
    for i in range(300):
        prod.produce({"ts": i * 0.1, "value": 1.0})
    prod.flush()
    agent = StreamTestingAgent(topic, window_ms=5.0)
    result = agent.run()
    assert "malformed-records" in result["bugs_found"]   # strict proc crashes
    assert "late-records" not in result["bugs_found"]
    assert topic.tail == 300                             # no test event leaked
    # all test forks were squashed
    live = system.metadata.state.live_log_ids()
    assert live == [topic.log.log_id]


def test_supplychain_agent_safe_vs_direct(system):
    def fill_orders(topic, n=40):
        prod = Producer(topic, linger_records=8)
        for i in range(n):
            prod.produce({"kind": "order", "item": "widget", "qty": 1})
        prod.flush()

    # direct mode with a mistake: downstream consumer crashes (Kafka behavior)
    t1 = Topic.create(system, "sc-direct")
    fill_orders(t1)
    agent = SupplyChainAgent(t1, inject_mistake=True)
    agent.run_direct()
    consumer = InventoryConsumer()
    with pytest.raises(Exception):
        consumer.process(t1)

    # safe mode with the same mistake: validation fails, fork squashed, main
    # stream unaffected; without the mistake, promote integrates the writes
    t2 = Topic.create(system, "sc-safe")
    fill_orders(t2)
    validator = InventoryConsumer()
    validator.process(t2)
    bad_agent = SupplyChainAgent(t2, inject_mistake=True)
    assert bad_agent.run_safe(validator) is False
    assert bad_agent.squashes == 1
    good_agent = SupplyChainAgent(t2)
    assert good_agent.run_safe(validator) is True
    consumer2 = InventoryConsumer()
    consumer2.process(t2)  # no crash
    assert consumer2.inventory["widget"] == -40 + 80  # orders + promoted restock


# ------------------------------------------------------------- data pipeline
def test_pipeline_resume_exactness(system):
    topic = Topic.create(system, "tokens")
    writer = TokenStreamWriter(topic, batch_docs=16)
    for doc in synthetic_token_docs(200, vocab=1000, seed=3):
        writer.write_doc(doc)
    writer.flush()

    pipe = LogDataPipeline(topic, batch_size=4, seq_len=128)
    batches = [next(pipe) for _ in range(10)]
    cursor = pipe.cursor()
    more = [next(pipe) for _ in range(5)]

    pipe2 = LogDataPipeline(topic, batch_size=4, seq_len=128)
    pipe2.restore(cursor)
    more2 = [next(pipe2) for _ in range(5)]
    for a, b in zip(more, more2):
        np.testing.assert_array_equal(a, b)


def test_pipeline_host_sharding_disjoint(system):
    topic = Topic.create(system, "tokens2")
    writer = TokenStreamWriter(topic, batch_docs=16)
    for doc in synthetic_token_docs(100, vocab=500, seed=4):
        writer.write_doc(doc)
    writer.flush()
    seen = []
    for h in range(4):
        pipe = LogDataPipeline(topic, batch_size=2, seq_len=64,
                               host_id=h, num_hosts=4)
        for _ in range(3):
            seen.append(next(pipe))
    # different hosts must produce different token streams
    flat = [tuple(b.ravel()[:32]) for b in seen]
    assert len(set(flat)) == len(flat)


def test_pipeline_on_promoted_synthetic_data(system):
    """Synthetic-data-agent story: inject curriculum docs on a promotable
    cFork, validate, promote — the training pipeline sees them interleaved."""
    topic = Topic.create(system, "tokens3")
    writer = TokenStreamWriter(topic, batch_docs=8)
    for doc in synthetic_token_docs(50, vocab=100, seed=5):
        writer.write_doc(doc)
    writer.flush()
    fork = topic.cfork(promotable=True)
    synth = np.full((64,), 7, dtype=np.int32)
    for _ in range(10):
        fork.log.append(synth.tobytes())
    # validation: fork batches are well-formed
    probe = LogDataPipeline(fork, batch_size=2, seq_len=32)
    b = next(probe)
    assert b.shape == (2, 33)
    fork.log.promote()
    assert topic.tail == 60
    pipe = LogDataPipeline(topic, batch_size=2, seq_len=32)
    found_synth = False
    try:
        while True:
            if (next(pipe) == 7).sum() > 32:
                found_synth = True
                break
    except StopIteration:
        pass
    assert found_synth
