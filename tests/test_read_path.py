"""Read-path tests (DESIGN.md §10).

The hard property: the memoized flattened-view resolver and the page-granular
scatter-gather object cache must be *observationally invisible* — byte-match
the seed's recursive resolver + whole-object cache across arbitrary
fork/append/promote/squash interleavings, including the cache-invalidation
points (promote and squash restructure indexes and HLI edges under the cache).
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BoltSystem, ForkBlocked, UnknownLog
from repro.core.broker import Broker, GroupCommitConfig
from repro.core.errors import AgileLogError
from repro.core.metadata import MetadataState
from repro.core.objectstore import LRUObjectCache, MemoryObjectStore
from repro.core.raft import MetadataService
from repro.core.sim import Resource, ServiceTimes, Simulator


# ---------------------------------------------------------------------------
# flattened-view cache vs the uncached chain resolver
# ---------------------------------------------------------------------------

class DualStateRunner:
    """Apply one random command trace to two MetadataStates — view cache on
    vs off — and require identical observables: results, error types, live
    logs, tails, and resolved spans (span-level equality implies byte
    equality: both states sequence identical object ids)."""

    def __init__(self, seed: int, promote_mode: str = "copy"):
        self.rng = random.Random(seed)
        self.cached = MetadataState(view_cache=True, promote_mode=promote_mode)
        self.plain = MetadataState(view_cache=False, promote_mode=promote_mode)
        ra = self._both(("create_root", "r"))[0]
        self.live = [ra]
        self.obj = 0

    def _both(self, cmd):
        res = []
        errs = []
        for state in (self.cached, self.plain):
            try:
                res.append(state.apply(cmd))
                errs.append(None)
            except AgileLogError as e:
                res.append(None)
                errs.append(type(e).__name__)
        assert errs[0] == errs[1], f"error mismatch on {cmd}: {errs}"
        assert res[0] == res[1], f"result mismatch on {cmd}: {res}"
        return res[0], errs[0]

    def _compare_reads(self, lid: int):
        tail = self.plain.tail(lid)
        lo = self.rng.randint(0, tail)
        hi = self.rng.randint(lo, tail)
        outs = []
        errs = []
        for state in (self.cached, self.plain):
            try:
                outs.append((state.read_spans(lid, lo, hi),
                             state.read_record_spans(lid, lo, hi)))
                errs.append(None)
            except AgileLogError as e:
                outs.append(None)
                errs.append(type(e).__name__)
        assert errs[0] == errs[1], \
            f"read error mismatch on log {lid} [{lo},{hi}): {errs}"
        assert outs[0] == outs[1], \
            f"span mismatch on log {lid} [{lo},{hi})"

    def step(self):
        rng = self.rng
        lid = rng.choice(self.live)
        op = rng.random()
        if op < 0.40:
            k = rng.randint(1, 4)
            sizes = [rng.randint(1, 64) for _ in range(k)]
            offsets, off = [], 0
            for s in sizes:
                offsets.append(off)
                off += s
            self._both(("append", lid, f"o{self.obj}",
                        tuple(offsets), tuple(sizes)))
            self.obj += 1
        elif op < 0.55:
            self._both(("cfork", lid, rng.random() < 0.3))
        elif op < 0.65:
            past = None
            tail = self.plain.tail(lid)
            if tail > 0 and rng.random() < 0.5:
                past = rng.randrange(tail)
            self._both(("sfork", lid, past))
        elif op < 0.73:
            self._both(("promote", lid,
                        rng.choice(["copy", "splice"])))
        elif op < 0.80:
            self._both(("squash", lid))
        # refresh live set and verify it agrees
        self.live = self.cached.live_log_ids()
        assert self.live == self.plain.live_log_ids()
        for _ in range(2):
            self._compare_reads(rng.choice(self.live))

    def final_check(self):
        for lid in self.live:
            for _ in range(4):
                self._compare_reads(lid)


@pytest.mark.parametrize("promote_mode", ["copy", "splice"])
@given(seed=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=25, deadline=None)
def test_flat_view_matches_plain_resolver(promote_mode, seed):
    runner = DualStateRunner(seed, promote_mode=promote_mode)
    for _ in range(80):
        runner.step()
    runner.final_check()


def test_view_cache_invalidated_on_promote():
    """Regression: a promote rewrites the parent's post-fork-point positions;
    a flattened view built *before* the promote must not serve stale spans."""
    st_ = MetadataState(view_cache=True, promote_mode="copy")
    root = st_.apply(("create_root", "r"))
    st_.apply(("append", root, "base", (0, 10), (10, 10)))
    # populate the root's flattened view
    before = st_.read_spans(root, 0, 2)
    assert root in st_._views
    child = st_.apply(("cfork", root, True))
    st_.apply(("append", child, "child", (0, 0 + 7), (7, 7)))
    st_.apply(("promote", child, "copy"))
    assert st_._views == {}, "promote must drop every flattened view"
    after = st_.read_spans(root, 0, 4)
    assert after[:len(before)] == before            # pre-fp prefix unchanged
    assert [s[0] for s in st_.read_record_spans(root, 2, 4)] == ["child", "child"]
    # and the rebuilt view byte-matches a from-scratch uncached resolution
    fresh = MetadataState(view_cache=False, promote_mode="copy")
    fresh.apply(("create_root", "r"))
    fresh.apply(("append", 0, "base", (0, 10), (10, 10)))
    c = fresh.apply(("cfork", 0, True))
    fresh.apply(("append", c, "child", (0, 7), (7, 7)))
    fresh.apply(("promote", c, "copy"))
    assert st_.read_spans(root, 0, 4) == fresh.read_spans(0, 0, 4)


def test_view_cache_invalidated_on_squash():
    st_ = MetadataState(view_cache=True)
    root = st_.apply(("create_root", "r"))
    st_.apply(("append", root, "a", (0,), (8,)))
    mid = st_.apply(("cfork", root, False))
    st_.apply(("append", mid, "b", (0,), (8,)))
    leaf_snapshot = st_.apply(("sfork", mid, None))   # depends on mid's index
    st_.read_spans(leaf_snapshot, 0, 2)               # populate its view
    st_.apply(("squash", mid))                        # mid frozen, not deleted
    assert leaf_snapshot in st_.live_log_ids()
    assert st_.read_record_spans(leaf_snapshot, 0, 2) == [("a", 0, 8), ("b", 0, 8)]


def test_view_cache_dropped_from_raft_snapshots():
    svc = MetadataService(n_replicas=3, snapshot_every=0)
    root = svc.propose(("create_root", "r"))
    svc.propose(("append", root, "a", (0, 8), (8, 8)))
    svc.state.read_spans(root, 0, 2)                  # populate leader view
    assert svc.state._views
    for r in svc.replicas:
        r.take_snapshot()
    svc.fail_replica(2)
    svc.recover_replica(2)
    restored = svc.replicas[2].state
    assert restored._views == {}                      # derived data not shipped
    assert restored.read_spans(root, 0, 2) == svc.state.read_spans(root, 0, 2)
    assert svc.check_convergence()


# ---------------------------------------------------------------------------
# page-granular LRU object cache
# ---------------------------------------------------------------------------

def _rand_store(rng, n_objects=5, max_bytes=200_000):
    store = MemoryObjectStore()
    objs = {}
    for i in range(n_objects):
        data = bytes(rng.randrange(256) for _ in range(rng.randrange(1, max_bytes)))
        objs[f"o{i}"] = data
        store.put(f"o{i}", data)
    return store, objs


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=15, deadline=None)
def test_page_cache_matches_store(seed):
    rng = random.Random(seed)
    store, objs = _rand_store(rng)
    cache = LRUObjectCache(store, capacity_bytes=128 << 10,
                           page_bytes=4096, readahead_bytes=16 << 10)
    keys = list(objs)
    for _ in range(300):
        k = rng.choice(keys)
        n = len(objs[k])
        off = rng.randrange(0, n + 10)
        ln = rng.choice([None, rng.randrange(0, n + 10)])
        want = objs[k][off:] if ln is None else objs[k][off:off + ln]
        assert cache.get(k, off, ln) == want
    for _ in range(100):
        spans = []
        for _ in range(rng.randrange(1, 12)):
            k = rng.choice(keys)
            n = len(objs[k])
            off = rng.randrange(0, n)
            spans.append((k, off, rng.randrange(0, n - off + 5)))
        assert cache.get_spans(spans) == [objs[k][o:o + l] for k, o, l in spans]


def test_oversized_object_bypasses_cache():
    """Satellite regression: the seed admitted objects larger than capacity,
    evicting the entire cache and then caching the oversized object anyway."""
    store = MemoryObjectStore()
    store.put("small", b"s" * 1000)
    big = b"b" * (2 << 20)
    store.put("big", big)
    cache = LRUObjectCache(store, capacity_bytes=1 << 20, page_bytes=4096)
    assert cache.get("small", 0, None) == b"s" * 1000
    size_before = cache._size
    assert size_before > 0
    assert cache.get("big", 0, None) == big              # whole-object read
    assert cache.get("big", 10, 2 << 20) == big[10:]     # oversized range
    assert cache._size == size_before, "oversized object must not be admitted"
    h0 = cache.hits
    assert cache.get("small", 0, 4) == b"ssss"           # still resident
    assert cache.hits > h0


def test_single_record_read_fetches_pages_not_whole_object():
    store = MemoryObjectStore()
    store.put("seg", b"x" * (1 << 20))                   # 1 MB segment
    cache = LRUObjectCache(store, capacity_bytes=64 << 20, page_bytes=64 << 10)
    assert cache.get("seg", 500_000, 256) == b"x" * 256
    assert cache.bytes_fetched <= 64 << 10               # one page, not 1 MB


def test_scatter_gather_coalesces_ranged_gets():
    store = MemoryObjectStore()
    store.put("a", bytes(range(256)) * 1024)             # 256 KB
    cache = LRUObjectCache(store, capacity_bytes=64 << 20, page_bytes=4096)
    # 16 adjacent spans inside one page range -> ONE coalesced ranged GET
    spans = [("a", 1000 + 100 * i, 100) for i in range(16)]
    blobs = cache.get_spans(spans)
    assert blobs == [store.get("a", off, ln) for _, off, ln in spans]
    assert cache.ranged_gets == 1


def test_sequential_readahead_reduces_gets():
    store = MemoryObjectStore()
    store.put("s", b"q" * (1 << 20))
    with_ra = LRUObjectCache(store, capacity_bytes=64 << 20,
                             page_bytes=4096, readahead_bytes=64 << 10)
    without = LRUObjectCache(store, capacity_bytes=64 << 20,
                             page_bytes=4096, readahead_bytes=0)
    for cache in (with_ra, without):
        pos = 0
        while pos + 1000 <= (1 << 20):
            assert cache.get("s", pos, 1000) == b"q" * 1000
            pos += 1000
    assert with_ra.ranged_gets * 4 <= without.ranged_gets


def test_invalidate_object_drops_stale_pages_and_hints():
    """Regression (ISSUE 5 satellite): pages are keyed (object, page#) with
    no versioning, so a deleted-then-recreated key kept serving the OLD
    bytes from cache — load-bearing once the GC reaper deletes objects.
    ``invalidate_object`` must drop the pages AND the size/readahead hints
    (a stale size hint would truncate reads of a larger recreation)."""
    store = MemoryObjectStore()
    store.put("k", b"old" * 1000)                        # 3000 bytes
    cache = LRUObjectCache(store, capacity_bytes=1 << 20, page_bytes=1024)
    assert cache.get("k", 0, 3000) == b"old" * 1000      # warm: 3 pages + size
    store.delete("k")
    store.put("k", b"NEWBYTES" * 1000)                   # 8000 bytes, same key
    # without invalidation the stale pages would still serve b"old"...
    dropped = cache.invalidate_object("k")
    assert dropped == 3 and cache.invalidations == 1
    assert cache.get("k", 0, 8000) == b"NEWBYTES" * 1000
    # ...and the stale 3000-byte size hint must not clip the whole-object get
    assert cache.get("k") == b"NEWBYTES" * 1000
    # invalidating an uncached key is a harmless no-op
    assert cache.invalidate_object("never-seen") == 0


def test_invalidate_object_keeps_lru_size_accounting_consistent():
    store = MemoryObjectStore()
    for i in range(8):
        store.put(f"o{i}", bytes([i]) * 4096)
    cache = LRUObjectCache(store, capacity_bytes=16 << 10, page_bytes=4096)
    for i in range(8):                       # capacity 4 pages: evictions run
        cache.get(f"o{i}", 0, 4096)
    assert cache._size == sum(len(p) for p in cache._pages.values())
    for i in range(8):
        cache.invalidate_object(f"o{i}")
    assert cache._size == 0 and not cache._pages and not cache._obj_pages


# ---------------------------------------------------------------------------
# broker + system level
# ---------------------------------------------------------------------------

def test_read_records_books_des_time_and_counts():
    """Satellite regression: record-oriented reads never called _book and
    never bumped `reads`, making them invisible to the isolation model."""
    sim = Simulator()
    store = MemoryObjectStore()
    store_res = Resource(servers=4)
    meta = MetadataService(n_replicas=3)
    broker = Broker(0, store, meta, sim=sim, service=ServiceTimes(),
                    store_resource=store_res)
    log_id = meta.propose(("create_root", "r"))
    broker.append(log_id, [b"a" * 512, b"b" * 512], arrival=0.0)
    jobs0 = store_res.jobs
    records, done = broker.read_records(log_id, 0, 2, arrival=1.0)
    assert records == [b"a" * 512, b"b" * 512]
    assert broker.reads == 1
    assert done > 1.0, "read_records must book simulated service time"
    assert store_res.jobs > jobs0, "cold read must hit the store resource"
    # warm read: pages resident, so no store GET is booked
    jobs1 = store_res.jobs
    _, done2 = broker.read_records(log_id, 0, 2, arrival=2.0)
    assert broker.reads == 2
    assert store_res.jobs == jobs1
    assert 2.0 < done2 < done - 1.0 + 2.0


def test_dedicated_fork_broker_never_parents_broker():
    """Satellite regression: with 2 brokers and the parent on broker 1, the
    re-map `(b % (len-1)) + 1` landed back on the parent's broker."""
    system = BoltSystem(n_brokers=2)
    root = system.create_log("r")
    assert root.broker.broker_id == 0
    f1 = root.cfork()
    assert f1.broker.broker_id == 1
    for _ in range(4):
        f2 = f1.cfork(dedicated=True)
        assert f2.broker.broker_id != f1.broker.broker_id
        f3 = f1.sfork(dedicated=True)
        assert f3.broker.broker_id != f1.broker.broker_id


def test_scan_streams_identical_to_read():
    with BoltSystem(group_commit=GroupCommitConfig(max_records=64)) as system:
        log = system.create_log("s")
        records = [f"r{i:05d}".encode() for i in range(1000)]
        for r in records:
            log.append(r)
        # staged records: scan must flush first (read-your-writes)
        assert list(log.scan()) == records
        assert list(log.scan(batch=7)) == records          # odd batch splits
        assert list(log.scan(100, 900, batch=256)) == records[100:900]
        assert list(log.scan(500, 500)) == []
        # eager validation: errors raise at the call site, like read()
        from repro.core.errors import InvalidOperation
        with pytest.raises(InvalidOperation):
            log.scan(10, 5)
        with pytest.raises(InvalidOperation):
            log.scan(0, 10_000)
        with pytest.raises(InvalidOperation):
            log.scan(batch=0)
        fork = log.cfork()
        fork.append(b"tail")
        assert list(fork.scan(990)) == records[990:] + [b"tail"]


def test_scan_snapshots_tail_at_start():
    system = BoltSystem()
    log = system.create_log("s")
    for i in range(10):
        log.append(b"%d" % i)
    it = log.scan(batch=4)
    first = [next(it) for _ in range(4)]
    log.append(b"late")
    rest = list(it)
    assert first + rest == [b"%d" % i for i in range(10)]  # no 'late'


# --------------------- scan iterators vs concurrent promote/squash (ISSUE 4)
# A scan() resolves metadata PER BATCH (DESIGN.md §10), so a promote/squash
# of the scanned lineage mid-iteration is observed at the next batch
# boundary, never inside a batch. These tests pin the observed semantics.

def test_scan_crossing_concurrent_promote_observes_the_merge():
    """Scanning a non-promotable sibling fork while its parent's promotable
    cFork promotes mid-iteration: batches fetched BEFORE the promote see the
    pre-promote prefix; batches fetched AFTER resolve through the promoted
    lineage — re-sequenced positions beyond the fork point now carry the
    winner's suffix, then the parent's withheld records. No error, no torn
    batch, no position yielded twice."""
    system = BoltSystem(n_brokers=3, promote_mode="splice")
    root = system.create_log("root")
    pre = [b"p%d" % i for i in range(10)]
    root.append_batch(pre)
    sib = root.cfork()                      # scans this; inherits continuously
    cand = root.cfork(promotable=True)      # fork point 10
    cand.append_batch([b"a0", b"a1"])       # child-local: positions 10, 11
    root.append_batch([b"w0", b"w1", b"w2"])   # withheld; the child inherits
    # them at 12-14, but the SIBLING holds them (blocked) at 10-12 pre-promote
    it = sib.scan(0, 13, batch=4)
    got = [next(it) for _ in range(4)]      # [0,4): below the cap, served
    cand.promote()                          # restructures the scanned lineage
    got += list(it)                         # [4,13): post-promote resolution
    assert got == pre + [b"a0", b"a1", b"w0"]
    # the same post-promote content, scanned from scratch, agrees
    assert list(sib.scan(0, 13)) == got


def test_scan_beyond_hold_cap_raises_at_the_crossing_batch():
    """Without the promote, the same mid-scan crossing hits the §4.1 block:
    bounds validate eagerly against the TAIL at scan() time, but the hold is
    enforced per batch — the iterator yields the visible prefix, then raises
    ForkBlocked at the first batch crossing the fork point."""
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append_batch([b"p%d" % i for i in range(10)])
    sib = root.cfork()
    root.cfork(promotable=True)             # active hold, fork point 10
    root.append_batch([b"w0", b"w1"])       # sib tail 12, cap 10
    it = sib.scan(0, 12, batch=4)
    assert [next(it) for _ in range(8)] == [b"p%d" % i for i in range(8)]
    with pytest.raises(ForkBlocked):
        next(it)                            # batch [8,12) crosses the cap


def test_scan_of_squashed_lineage_raises_unknown_log_at_next_batch():
    """Scanning a fork that a concurrent squash removes mid-iteration:
    records already yielded stay valid; the next batch raises UnknownLog."""
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append_batch([b"p%d" % i for i in range(8)])
    fork = root.cfork()
    it = fork.scan(0, 8, batch=4)
    assert [next(it) for _ in range(4)] == [b"p%d" % i for i in range(4)]
    fork.squash()
    with pytest.raises(UnknownLog):
        next(it)


def test_scan_of_holder_resumes_after_concurrent_squash():
    """Scanning the HOLDER beyond its own fork point blocks while the hold
    is active — but a squash of the promotable child mid-iteration releases
    it, and the same iterator proceeds (scan re-resolves per batch)."""
    system = BoltSystem(n_brokers=3)
    root = system.create_log("root")
    root.append_batch([b"p%d" % i for i in range(6)])
    cand = root.cfork(promotable=True)      # fork point 6
    root.append_batch([b"w0", b"w1"])       # withheld, tail 8
    it = root.scan(0, 8, batch=4)           # explicit hi beyond the cap
    assert [next(it) for _ in range(4)] == [b"p%d" % i for i in range(4)]
    it2 = root.scan(0, 8, batch=4)
    assert [next(it2) for _ in range(4)] == [b"p%d" % i for i in range(4)]
    with pytest.raises(ForkBlocked):
        next(it)                            # hold still active: batch blocks
    cand.squash()                           # releases the hold mid-scan
    assert list(it2) == [b"p4", b"p5", b"w0", b"w1"]
