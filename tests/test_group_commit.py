"""Group-commit append pipeline (DESIGN.md §9).

Covers: multi-log batched proposals and position assignment, flush policies,
read-your-writes, interaction with promotable cForks (withheld positions and
deterministic per-entry errors), replay/snapshot determinism of the
``append_batch_multi`` SMR command, and a property test that group-commit
append streams are read-equivalent to per-record appends.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AgileLogError, BoltSystem, ForkBlocked,
                        GroupCommitConfig, NoLiveBrokers, NoQuorum)
from repro.core.metadata import MetadataState
from repro.core.objectstore import SegmentWriter
from repro.core.sim import OpTally

REC = lambda tag, i: f"{tag}{i}".encode()  # noqa: E731


# ------------------------------------------------------------- segment writer
def test_segment_writer_merges_per_log_entries():
    w = SegmentWriter()
    assert w.add(7, [b"aa", b"b"]) == (0, 0)
    assert w.add(9, [b"ccc"]) == (1, 0)
    assert w.add(7, [b"dddd"]) == (0, 2)   # same log merges into entry 0
    payload, entries = w.finish()
    assert payload == b"aabcccdddd"
    assert entries == [(7, (0, 2, 6), (2, 1, 4)), (9, (3,), (3,))]
    assert w.nrecords == 4 and w.nbytes == 10


# ------------------------------------------------- batched proposal mechanics
def test_multi_log_flush_is_one_proposal_one_put():
    system = BoltSystem(n_brokers=3, group_commit=GroupCommitConfig(max_records=64))
    logs = [system.create_log(f"l{i}") for i in range(3)]  # all on broker 0
    before = OpTally.capture(system)
    pending = []
    for i in range(8):
        for tag, log in zip("abc", logs):
            pending.append(log.append(REC(tag, i)))
    system.flush()
    delta = OpTally.capture(system, records=24).delta(before)
    assert delta.proposals == 1
    assert delta.puts == 1
    for j, tag in enumerate("abc"):
        positions = [p.positions() for p in pending[j::3]]
        assert positions == [[i] for i in range(8)]
        assert logs[j].read(0, 8) == [REC(tag, i) for i in range(8)]


def test_positions_match_per_call_path():
    per_call = BoltSystem(n_brokers=2)
    grouped = BoltSystem(n_brokers=2, group_commit=GroupCommitConfig(max_records=5))
    a1, b1 = per_call.create_log("a"), per_call.create_log("b")
    a2, b2 = grouped.create_log("a"), grouped.create_log("b")
    got, want = [], []
    for i in range(17):
        log1, log2 = (a1, a2) if i % 3 else (b1, b2)
        want.append(log1.append(REC("r", i)))
        got.append(log2.append(REC("r", i)))
    grouped.flush()
    assert [p.positions()[0] for p in got] == [w.position() for w in want]
    for lo, hi in [(a1, a2), (b1, b2)]:
        assert hi.read(0, hi.tail) == lo.read(0, lo.tail)


def test_flush_thresholds_and_context_manager():
    cfg = GroupCommitConfig(max_records=4, max_bytes=100)
    with BoltSystem(group_commit=cfg) as system:
        log = system.create_log("x")
        p1 = [log.append(b"r") for _ in range(3)]
        assert not any(p.done for p in p1)          # under both thresholds
        p2 = log.append(b"r")
        assert all(p.done for p in p1 + [p2])       # record-count flush
        p3 = log.append(b"x" * 100)
        assert p3.done                               # byte flush
        p4 = log.append(b"tail")
    assert p4.done                                   # context-exit flush
    assert p4.positions() == [5]


def test_read_flushes_staged_records():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=1000))
    log = system.create_log("x")
    log.append(b"one")
    log.append(b"two")
    assert log.read(0, 2) == [b"one", b"two"]   # read-your-writes via flush
    # reading a log with nothing staged does not flush other logs' records
    other = system.create_log("y")  # same broker, no staged records
    pending = log.append(b"three")
    assert other.read(0, 0) == []
    assert not pending.done                      # 'three' still staged
    assert log.read(2, 3) == [b"three"]          # this read flushes it


def test_des_time_deadline_flushes_old_batch():
    cfg = GroupCommitConfig(max_records=1000, max_delay=1e-3)
    system = BoltSystem(group_commit=cfg)
    broker = system.brokers[0]
    log = system.create_log("x")
    p1 = broker.stage(log.log_id, [b"a"], arrival=0.0)
    p2 = broker.stage(log.log_id, [b"b"], arrival=0.5e-3)
    assert not p1.done and not p2.done
    p3 = broker.stage(log.log_id, [b"c"], arrival=2e-3)  # > max_delay later
    assert p1.done and p2.done and not p3.done
    assert p1.result() == [0] and p2.result() == [1]
    broker.flush()
    assert p3.result() == [2]


def test_idle_deadline_flushes_from_clock_advance():
    """§9 bugfix regression (ISSUE 10): the ``max_delay`` deadline must fire
    from DES clock advance alone. The seed check lived inside ``stage()``, so
    an idle staged record sat past its deadline until the NEXT record
    arrived — with a fault plane attached, the deadline is now a
    ``call_at`` callback fired by ``plane.advance()``."""
    cfg = GroupCommitConfig(max_records=1000, max_delay=1e-3)
    system = BoltSystem(group_commit=cfg, faults=True)
    plane = system.faults
    broker = system.brokers[0]
    log = system.create_log("x")
    p1 = broker.stage(log.log_id, [b"a"], arrival=0.0)
    plane.advance(0.5e-3)                    # before the deadline: staged
    assert not p1.done
    plane.advance(2e-3)                      # past it: flushes, NO new record
    assert p1.done
    assert p1.result() == [0]
    assert log.read(0, 1) == [b"a"]
    # a deadline armed for an already-flushed batch is a no-op (epoch guard)
    p2 = broker.stage(log.log_id, [b"b"], arrival=3e-3)
    broker.flush()                           # explicit flush first
    flushes = broker.flushes
    plane.advance(10e-3)                     # stale callback fires harmlessly
    assert broker.flushes == flushes
    assert p2.result() == [1]


def test_receipt_wait_forces_flush():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=1000))
    log = system.create_log("x")
    receipt = log.append(b"r")
    assert not receipt.done
    assert receipt.positions() == [0]   # positions() waits: flushes the broker
    assert receipt.done


def test_metadata_ops_flush_staged_records():
    """Read-your-writes across planes: tail/fork/promote observe staged appends."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=100))
    log = system.create_log("x")
    p = log.append(b"a")
    assert log.tail == 1 and p.done          # tail read flushed the staging
    log.append(b"b")
    fork = log.sfork()                       # fork point includes the staged record
    assert fork.read(0, fork.tail) == [b"a", b"b"]


def test_failed_broker_staging_fails_over():
    """DESIGN.md §15: a dead broker's unacked staging moves to a surviving
    broker; the receipt resolves with the surviving positions — nothing
    acked is lost, nothing unacked is dropped."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=100))
    log = system.create_log("x")
    p = log.append(b"moved")
    system.fail_broker(0)
    assert system.broker_failovers == 1
    assert p.positions() == [0]              # committed via the adopter
    assert system.metadata.state.tail(log.log_id) == 1
    assert log.read(0, 1) == [b"moved"]


def test_failed_broker_no_live_peer_fails_staging():
    """With NO survivor to adopt the staging, the unacked records are lost —
    each pending FAILS with NoLiveBrokers instead of resolving."""
    system = BoltSystem(n_brokers=2, group_commit=GroupCommitConfig(max_records=100))
    log = system.create_log("x")
    p = log.append(b"lost")
    system.fail_broker(1)
    system.fail_broker(0)
    with pytest.raises(NoLiveBrokers):
        p.wait()                             # never acked -> failed, not committed
    assert system.metadata.state.tail(log.log_id) == 0


def test_flush_failure_fails_pendings_and_recovers():
    """A flush losing metadata quorum must FAIL its pendings (not strand them
    as None == 'withheld'), and a retry after recovery must commit cleanly."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=100),
                        n_meta_replicas=3)
    log = system.create_log("x")
    p = log.append(b"r")
    system.metadata.fail_replica(1)
    system.metadata.fail_replica(2)
    with pytest.raises(NoQuorum):
        system.flush()
    with pytest.raises(AgileLogError):
        p.wait()
    system.metadata.recover_replica(1)
    p2 = log.append(b"r")
    system.flush()
    assert p2.positions() == [0]        # nothing from the failed flush leaked
    assert log.tail == 1
    assert system.metadata.check_convergence()


def test_group_commit_config_validation():
    assert BoltSystem(group_commit=0).group_commit is None     # falsy: off
    assert BoltSystem(group_commit=False).group_commit is None
    assert BoltSystem(group_commit=True).group_commit is not None
    with pytest.raises(ValueError):
        BoltSystem(group_commit=-3)
    with pytest.raises(TypeError):
        BoltSystem(group_commit=0.5)


# ------------------------------------------------ promotable-cFork interaction
def test_batch_withholds_positions_under_promotable_cfork():
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=64))
    root = system.create_log("root")
    root.append(b"base")
    system.flush()
    child = root.cfork(promotable=True)
    p = root.append(b"hidden")
    system.flush()
    assert p.withheld and p.positions() is None  # §4.1: withheld, not lost
    assert root.tail == 2
    child.promote()
    assert root.read(0, 2) == [b"base", b"hidden"]


def test_batch_entry_errors_are_isolated_and_deterministic():
    """A blocked log's entry fails its own appenders; batch-mates commit."""
    system = BoltSystem(group_commit=GroupCommitConfig(max_records=64))
    root = system.create_log("root")
    free = system.create_log("free")
    root.append(b"base")
    system.flush()
    sibling = root.cfork()            # ordinary fork of root...
    root.cfork(promotable=True)       # ...now blocked by the ancestor's hold
    p_blocked = sibling.append(b"nope")
    p_free = free.append(b"yep")
    system.flush()
    assert p_free.positions() == [0]
    with pytest.raises(ForkBlocked):
        p_blocked.wait()
    # every replica applied the partial batch identically
    assert system.metadata.check_convergence()


# ------------------------------------------------- replay / snapshot determinism
def test_append_batch_multi_replays_deterministically_from_snapshot():
    system = BoltSystem(n_brokers=2, n_meta_replicas=3, snapshot_every=3,
                        group_commit=GroupCommitConfig(max_records=8))
    a = system.create_log("a")
    b = system.create_log("b")
    for i in range(20):
        (a if i % 2 else b).append(REC("r", i))
    system.flush()
    # crash + recover a follower from a snapshot + suffix replay
    follower = next(r.rid for r in system.metadata.replicas
                    if r.rid != system.metadata.leader_id)
    system.metadata.fail_replica(follower)
    for i in range(20, 31):
        (a if i % 2 else b).append(REC("r", i))
    system.flush()
    system.metadata.recover_replica(follower)
    assert system.metadata.check_convergence()
    # kill the leader: the new leader's state must serve identical reads
    want_a = a.read(0, a.tail)
    system.metadata.fail_replica(system.metadata.leader_id)
    assert a.read(0, a.tail) == want_a
    assert system.metadata.check_convergence()


def test_apply_append_batch_multi_outcomes_shape():
    state = MetadataState()
    rid = state.apply(("create_root", "r"))
    outcomes = state.apply(("append_batch_multi", (
        (rid, "obj", (0, 3), (3, 3)),
        (999, "obj", (6,), (3,)),          # unknown log -> error outcome
    )))
    assert outcomes[0] == ("ok", [0, 1])
    assert outcomes[1][0] == "error" and outcomes[1][1] == "UnknownLog"
    assert state.tails.get(rid)[0] == 2    # the bad entry changed nothing else


# ---------------------------------------------------------------- property test
@given(trace=st.lists(st.tuples(st.integers(0, 2),      # which log
                                st.integers(1, 4),      # how many records
                                st.integers(0, 4)),     # flush when 0
                      min_size=1, max_size=60))
@settings(max_examples=30, deadline=None)
def test_group_commit_read_equivalent_to_per_record(trace):
    per_call = BoltSystem(n_brokers=2)
    grouped = BoltSystem(n_brokers=2, group_commit=GroupCommitConfig(max_records=7))
    logs1 = [per_call.create_log(f"l{i}") for i in range(3)]
    logs2 = [grouped.create_log(f"l{i}") for i in range(3)]
    counter = 0
    for which, k, flush_roll in trace:
        records = [REC("t", counter + j) for j in range(k)]
        counter += k
        want = logs1[which].append_batch(records).positions()
        pending = logs2[which].append_batch(records)
        if flush_roll == 0:
            grouped.flush()
            assert pending.positions() == want
    grouped.flush()
    for l1, l2 in zip(logs1, logs2):
        assert l1.tail == l2.tail
        assert l2.read(0, l2.tail) == l1.read(0, l1.tail)
    assert grouped.metadata.proposals <= per_call.metadata.proposals
    assert grouped.store.put_count <= per_call.store.put_count
