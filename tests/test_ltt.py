"""Property tests: LazyTailTree (treap over Euler tour) vs eager oracle."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ltt import EagerTailMap, LazyTailTree


def _apply_random_ops(seed: int, n_ops: int, check_every: int = 1):
    rng = random.Random(seed)
    ltt = LazyTailTree(seed=seed)
    oracle = EagerTailMap()
    live = []
    next_id = 0

    def new_root():
        nonlocal next_id
        ltt.add_root(next_id, tail0=rng.randrange(10))
        oracle.add_root(next_id, tail0=ltt.get(next_id)[0])
        live.append(next_id)
        next_id += 1

    new_root()
    for step in range(n_ops):
        op = rng.random()
        if op < 0.25 or not live:
            if rng.random() < 0.3 or not live:
                new_root()
            else:
                parent = rng.choice(live)
                t0, b0 = ltt.get(parent)
                ltt.add_child(parent, next_id, t0, b0)
                oracle.add_child(parent, next_id, t0, b0)
                live.append(next_id)
                next_id += 1
        elif op < 0.65:
            x = rng.choice(live)
            dt = rng.randrange(1, 5)
            db = rng.choice([-1, 0, 1])
            ltt.range_add(x, dt, db)
            oracle.range_add(x, dt, db)
        elif op < 0.8 and len(live) > 1:
            x = rng.choice(live[1:])  # keep first root alive
            removed = sorted(ltt.remove_subtree(x))
            removed_o = sorted(oracle.remove_subtree(x))
            assert removed == removed_o
            live[:] = [l for l in live if l not in removed]
        elif op < 0.9 and len(live) > 1:
            x = rng.choice(live[1:])
            # only remove-keep-children for non-roots (oracle semantics match)
            if oracle.parent.get(x) is not None:
                ltt.remove_node_keep_children(x)
                oracle.remove_node_keep_children(x)
                live.remove(x)
        if step % check_every == 0:
            for l in live:
                assert ltt.get(l) == oracle.get(l), f"mismatch at {l} step {step}"
            # subtree order agreement on a sample
            x = rng.choice(live)
            assert ltt.subtree_ids(x) == oracle.subtree_ids(x)
            assert ltt.direct_children(x) == oracle.direct_children(x)
    # final full check
    for l in live:
        assert ltt.get(l) == oracle.get(l)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=60, deadline=None)
def test_ltt_matches_oracle_random_traces(seed):
    _apply_random_ops(seed, n_ops=120)


def test_ltt_long_trace():
    _apply_random_ops(seed=1234, n_ops=2000, check_every=10)


def test_ltt_deep_chain():
    ltt = LazyTailTree()
    ltt.add_root(0, tail0=0)
    for i in range(1, 300):
        t, b = ltt.get(i - 1)
        ltt.add_child(i - 1, i, t, b)
    ltt.range_add(0, d_tail=7)          # hits every node
    ltt.range_add(150, d_tail=5)        # hits deep half
    assert ltt.get(0) == (7, 0)
    assert ltt.get(149) == (7, 0)
    assert ltt.get(150) == (12, 0)
    assert ltt.get(299) == (12, 0)
    ltt.remove_node_keep_children(150)  # 151 re-parents to 149
    ltt.range_add(149, d_tail=1)
    assert ltt.get(151) == (13, 0)
    assert ltt.get(299) == (13, 0)


def test_ltt_direct_children_skips_subtrees():
    """direct_children must hop over grandchildren (promote re-parents only
    the promoted node's immediate children, DESIGN.md §11)."""
    ltt = LazyTailTree()
    ltt.add_root(0)
    ltt.add_child(0, 1, 0, 0)
    ltt.add_child(1, 2, 0, 0)     # grandchild under 1
    ltt.add_child(2, 3, 0, 0)     # great-grandchild
    ltt.add_child(0, 4, 0, 0)
    ltt.add_child(4, 5, 0, 0)
    ltt.add_child(0, 6, 0, 0)
    assert ltt.direct_children(0) == [1, 4, 6]
    assert ltt.direct_children(1) == [2]
    assert ltt.direct_children(3) == []
    ltt.remove_node_keep_children(1)   # 2 re-parents to 0
    assert ltt.direct_children(0) == [2, 4, 6]


def test_ltt_wide_fanout():
    ltt = LazyTailTree()
    oracle = EagerTailMap()
    ltt.add_root(0)
    oracle.add_root(0)
    for i in range(1, 1001):
        ltt.add_child(0, i, *ltt.get(0))
        oracle.add_child(0, i, *oracle.get(0))
    ltt.range_add(0, d_tail=3)
    oracle.range_add(0, d_tail=3)
    for i in (0, 1, 500, 1000):
        assert ltt.get(i) == oracle.get(i) == (3, 0)
