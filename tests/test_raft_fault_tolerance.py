"""Fault-tolerance tests for the replicated metadata layer."""

import pytest

from repro.core import BoltSystem
from repro.core.errors import AgileLogError, NoQuorum, Unavailable


def _fill(log, n, prefix=b"r"):
    for i in range(n):
        log.append(prefix + str(i).encode())


def test_leader_failover_preserves_committed_state():
    sys = BoltSystem(n_brokers=2)
    log = sys.create_log("root")
    _fill(log, 20)
    fork = log.cfork()
    fork.append(b"fork-only")
    assert sys.metadata.check_convergence()

    old_leader = sys.metadata.leader_id
    sys.metadata.fail_replica(old_leader)
    assert sys.metadata.leader_id != old_leader

    # committed state fully visible through the new leader
    assert log.tail == 20
    assert fork.tail == 21
    assert fork.read(19, 21) == [b"r19", b"fork-only"]

    # and the system still takes writes
    _fill(log, 5, prefix=b"post")
    assert log.tail == 25
    assert fork.tail == 26  # cfork keeps inheriting across failover


def test_no_quorum_rejects_writes():
    sys = BoltSystem(n_brokers=2, n_meta_replicas=3)
    log = sys.create_log("root")
    sys.metadata.fail_replica(1)
    log.append(b"ok-with-2-of-3")
    with pytest.raises(NoQuorum):
        sys.metadata.fail_replica(sys.metadata.leader_id)  # second failure: no quorum
    assert isinstance(NoQuorum("x"), Unavailable)          # typed as retryable (§15)


def test_no_quorum_proposal_rolls_back_and_recovers():
    """A rejected (no-quorum) proposal must leave NO trace in minority logs:
    after recovery, later proposals commit at consistent indices."""
    sys = BoltSystem(n_brokers=2, n_meta_replicas=3)
    log = sys.create_log("root")
    sys.metadata.fail_replica(1)
    sys.metadata.fail_replica(2)
    with pytest.raises(NoQuorum):
        log.append(b"never-committed")
    sys.metadata.recover_replica(1)
    assert log.append(b"first-real").position() == 0
    assert log.read(0, 1) == [b"first-real"]
    assert sys.metadata.check_convergence()


def test_replica_recovery_from_snapshot():
    sys = BoltSystem(n_brokers=2, snapshot_every=10)
    log = sys.create_log("root")
    _fill(log, 25)
    victim = (sys.metadata.leader_id + 1) % 3
    sys.metadata.fail_replica(victim)
    _fill(log, 25)   # progress while the replica is down
    sys.metadata.recover_replica(victim)
    # recovered replica converges (snapshot install + suffix replay)
    r = sys.metadata.replicas[victim]
    assert r.state.tail(log.log_id) == 50
    assert sys.metadata.check_convergence()


def test_recovery_from_donor_with_stale_snapshot_and_backlog():
    """Regression (§15): the recovery donor is picked by commit_index, but a
    pipelined follower (§11) can be ahead on commit_index while carrying a
    STALE snapshot plus a deferred-apply backlog — its log is shorter than
    its commit point says. recover_replica must drain the donor's backlog
    and refresh its snapshot before handing state over, or the recovering
    replica would install old state and replay an incomplete suffix."""
    sys = BoltSystem(n_brokers=2, n_meta_replicas=3, snapshot_every=5,
                     pipeline_apply=True)
    log = sys.create_log("root")
    _fill(log, 12)                      # several snapshot rounds
    victim = (sys.metadata.leader_id + 1) % 3
    sys.metadata.fail_replica(victim)
    _fill(log, 12)                      # progress while the replica is down
    # pick the donor the way recover_replica does, and make it maximally
    # awkward: a non-leader follower whose snapshot predates its commit point
    donor = max((p for p in sys.metadata.replicas
                 if p.alive and p.rid != victim),
                key=lambda p: p.commit_index)
    if donor.rid != sys.metadata.leader_id:
        assert donor.snapshot_index < donor.commit_index
    sys.metadata.recover_replica(victim)
    r = sys.metadata.replicas[victim]
    assert donor.pending_applies == 0          # backlog drained pre-handover
    assert r.snapshot_index == donor.snapshot_index
    assert r.state.tail(log.log_id) == 24
    assert sys.metadata.check_convergence()


def test_failover_and_recovery_with_forks_and_promote():
    sys = BoltSystem(n_brokers=3, snapshot_every=8)
    log = sys.create_log("root")
    _fill(log, 10)
    agent_fork = log.cfork(promotable=True)
    agent_fork.append(b"agent-1")
    _fill(log, 3, prefix=b"live")

    sys.metadata.fail_replica(sys.metadata.leader_id)

    agent_fork.append(b"agent-2")
    assert agent_fork.promote()
    assert log.tail == 15
    data = log.read(0, 15)
    assert data.count(b"agent-1") == 1 and data.count(b"agent-2") == 1
    # linearizable interleave survived the failover
    assert data.index(b"agent-1") < data.index(b"live0") < data.index(b"agent-2")


def test_deterministic_errors_do_not_diverge_replicas():
    sys = BoltSystem(n_brokers=2)
    log = sys.create_log("root")
    _fill(log, 4)
    pf = log.cfork(promotable=True)
    _fill(log, 2)            # appends still fine (positions withheld)
    with pytest.raises(AgileLogError):
        log.sfork(past=None)  # forking beyond fp while hold active: rejected
    pf.squash()
    assert sys.metadata.check_convergence()
    assert log.tail == 6


def test_broker_failover_reroutes_transparently():
    """Stateless brokers (§5.2): killing a fork's broker loses only its
    cache; clients re-route and reads/appends continue (straggler story)."""
    sys = BoltSystem(n_brokers=4)
    log = sys.create_log("root")
    _fill(log, 10)
    fork = log.cfork()
    fork.append(b"on-fork")
    victim = fork.broker.broker_id
    sys.fail_broker(victim)
    assert fork.read(9, 11) == [b"r9", b"on-fork"]   # re-routed read
    fork.append(b"after-failover")
    assert fork.broker.broker_id != victim
    assert fork.read(11, 12) == [b"after-failover"]


def test_paper_deployment_config():
    from repro.configs.bolt_paper import PAPER
    sys = PAPER.make()
    log = sys.create_log("root")
    _fill(log, int(3 * PAPER.snapshot_every / 2))  # crosses a snapshot
    assert sys.metadata.leader.snapshot_index >= 0
    assert log.read(0, 2) == [b"r0", b"r1"]
