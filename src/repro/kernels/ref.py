"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Naive softmax attention. q: (B,H,S,Dh); k,v: (B,KH,S,Dh)."""
    B, H, S, Dh = q.shape
    KH = k.shape[1]
    G = H // KH
    qg = q.reshape(B, KH, G, S, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) * Dh ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, Dh).astype(q.dtype)


def mlstm_ref(q, k, v, li, lf):
    """Step-by-step stabilized mLSTM recurrence (fp32).
    q,k,v: (B,H,S,Dh); li,lf: (B,H,S) (i~ raw, logsig(f~)). Returns (h, (C,n,m))."""
    B, H, S, Dh = q.shape
    C = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    n = jnp.zeros((B, H, Dh), jnp.float32)
    m = jnp.full((B, H), -1e30, jnp.float32)
    hs = []
    for t in range(S):
        m_new = jnp.maximum(lf[:, :, t] + m, li[:, :, t])
        f_ = jnp.exp(lf[:, :, t] + m - m_new)
        i_ = jnp.exp(li[:, :, t] - m_new)
        C = (f_[..., None, None] * C
             + i_[..., None, None] * k[:, :, t, :, None] * v[:, :, t, None, :])
        n = f_[..., None] * n + i_[..., None] * k[:, :, t]
        m = m_new
        num = jnp.einsum("bhde,bhd->bhe", C, q[:, :, t])
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, q[:, :, t]))
        hs.append(num / jnp.maximum(den, jnp.exp(-m))[..., None])
    return jnp.stack(hs, axis=2), (C, n, m)
