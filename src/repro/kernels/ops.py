"""Jit'd dispatch wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the kernels validate on CPU;
on a real TPU deployment (cfg.use_pallas) they lower natively.
"""

from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .mlstm_chunk import mlstm_chunk as _mlstm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=not _on_tpu())


def mlstm_chunk_pallas(q, k, v, li, lf, *, chunk: int = 64):
    return _mlstm(q, k, v, li, lf, chunk=chunk, interpret=not _on_tpu())
