"""Pallas TPU kernels for the compute hot-spots (validated in interpret mode).

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd dispatch wrappers selected by cfg.use_pallas), and ref.py
(pure-jnp oracles that tests compare against).
"""

from .ops import flash_attention_pallas, mlstm_chunk_pallas

__all__ = ["flash_attention_pallas", "mlstm_chunk_pallas"]
