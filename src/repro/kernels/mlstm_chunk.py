"""Chunkwise mLSTM as a Pallas TPU kernel.

Grid: (B*H, n_chunks); the chunk dimension is sequential and carries the
matrix-memory state (C, n, m) in VMEM scratch. Each chunk does three
MXU matmuls (intra-chunk scores, value combine, state outer-product) plus
cheap vector work on the cumulative gates — the TPU-friendly factorization of
xLSTM's recurrence (the per-step recurrent form is pure VPU and ~Dh x slower).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  C_ref, n_ref, m_ref, *, chunk: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    q = q_ref[0].astype(jnp.float32)          # (L, Dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)        # (L,)
    lf = lf_ref[0].astype(jnp.float32)

    m0 = m_ref[0]
    F = jnp.cumsum(lf)                        # (L,) inclusive
    g = li - F
    run = jnp.maximum(m0, jax.lax.cummax(g, axis=0))
    m = F + run                               # stabilizer per step
    logw = (F - m)[:, None] + g[None, :]      # (L, L): t rows, s cols
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    W = jnp.where(t_idx >= s_idx, jnp.exp(logw), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * W
    h_num = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n_intra = jax.lax.dot_general(W, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    w_state = jnp.exp(F + m0 - m)             # (L,)
    h_num = h_num + w_state[:, None] * jax.lax.dot_general(
        q, C_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_t = n_intra + w_state[:, None] * n_ref[...][None, :]
    den = jnp.abs(jnp.sum(q * n_t, axis=1))
    h_ref[0] = (h_num / jnp.maximum(den, jnp.exp(-m))[:, None]).astype(h_ref.dtype)

    m_L = m[-1]
    wk = jnp.exp((F[-1] - F) + li - m_L)      # (L,)
    C_ref[...] = (jnp.exp(F[-1] + m0 - m_L) * C_ref[...]
                  + jax.lax.dot_general(k * wk[:, None], v,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    n_ref[...] = (jnp.exp(F[-1] + m0 - m_L) * n_ref[...]
                  + jnp.sum(k * wk[:, None], axis=0))
    m_ref[0] = m_L


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q: jax.Array, k: jax.Array, v: jax.Array,
                li: jax.Array, lf: jax.Array, *,
                chunk: int = DEFAULT_CHUNK,
                interpret: bool = False) -> jax.Array:
    """q,k,v: (B,H,S,Dh) (k pre-scaled); li,lf: (B,H,S). Returns h (B,H,S,Dh)."""
    B, H, S, Dh = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    qf = q.reshape(B * H, S, Dh)
    kf = k.reshape(B * H, S, Dh)
    vf = v.reshape(B * H, S, Dh)
    lif = li.reshape(B * H, S)
    lff = lf.reshape(B * H, S)

    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, Dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, Dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, Dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk), lambda b, j: (b, j)),
            pl.BlockSpec((1, chunk), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Dh), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Dh, Dh), jnp.float32),   # C state
            pltpu.VMEM((Dh,), jnp.float32),      # n state
            pltpu.VMEM((1,), jnp.float32),       # m stabilizer
        ],
        interpret=interpret,
    )(qf, kf, vf, lif, lff)
    return out.reshape(B, H, S, Dh)
