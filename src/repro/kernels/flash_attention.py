"""Causal GQA flash attention as a Pallas TPU kernel.

TPU-native design (DESIGN.md §3/§7): the grid is (batch*q_heads, q_blocks,
kv_blocks); the kv_blocks dimension is sequential, carrying the online-softmax
state (m, l, acc) in VMEM scratch so score blocks never touch HBM — the
fix for the score-materialization memory-boundedness the dry-run shows for the
pure-XLA path. Block shapes are MXU-aligned (multiples of 128 on the block
dims); fp32 accumulation; GQA is handled in the kv index_map (q head h reads
kv head h // G), so kv blocks are reused across the q-head group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0].astype(jnp.float32)                     # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                     # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(q_pos >= k_pos, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isfinite(m_new)[:, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # skip fully-masked kv blocks (top-right of the causal band)
        pl.when(kj * block_k <= qi * block_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(kj == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, Dh); k, v: (B, KH, S, Dh); H = KH * G. Returns (B,H,S,Dh)."""
    B, H, S, Dh = q.shape
    KH = k.shape[1]
    G = H // KH
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    qf = q.reshape(B * H, S, Dh)
    kf = k.reshape(B * KH, S, Dh)
    vf = v.reshape(B * KH, S, Dh)

    kernel = functools.partial(
        _flash_kernel, scale=Dh ** -0.5, causal=causal,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        scratch_shapes=[               # VMEM state carried across kv steps
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, Dh)
