"""Speculative decoding as log speculation (DESIGN.md §17).

The paper's claim is that agents acting on model-generated streams want a
forkable log; speculative decoding is the degenerate-but-load-bearing case:

* a k-token draft rollout IS a ``log.speculate()`` session — the fork is the
  sequence branch (draft tokens live on the fork, invisible to response
  subscribers until promoted);
* ``promote_if`` IS the acceptance gate — the rollout commits into the
  shared response stream atomically, or not at all;
* auto-rebase IS re-anchoring — when other decoders (or the request pump)
  advance the response stream's tail between draft and commit, the session
  replays its token suffix zero-copy onto the moved tail. Token records are
  keyed ``(id, seq)``, so interleaving with other requests' records is
  harmless and the ``on_rebase`` hook just counts the re-anchor.

Greedy speculative decoding is exact: the emitted stream is byte-identical
to sequential greedy decoding of the target model (tests/test_serve_on_log.py
proves it record-for-record). A rejected rollout aborts its session — the
squash leaves no trace in the flattened view and hands the draft's segment
bytes to §13 GC.

This module is deliberately JAX-free: the driver works over two small
callables (below) so the DES benchmark can run it with synthetic models and
hlo_cost-derived step costs, while ``serve/engine.py`` provides the real
``decode_step``-backed adapters.

  TargetModel.verify(prefix, draft) -> k+1 greedy tokens: position i is the
      target's argmax conditioned on ``prefix + draft[:i]``. ``verify(p, [])``
      is one sequential decode step.
  DraftModel.propose(prefix, k) -> k greedy draft tokens.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from ..core.api import AgileLog, CommitResult, Speculation
from ..core.sim import ServeStats
from ..streams.records import decode_record, encode_record


def encode_token(req_id: str, seq: int, tok: int) -> bytes:
    """One response-stream token record. ``seq`` orders tokens within a
    request; readers demux the shared stream by ``id``."""
    return encode_record({"id": req_id, "seq": seq, "tok": int(tok)})


def encode_eos(req_id: str, n: int) -> bytes:
    """End-of-response marker: ``n`` tokens were emitted for ``req_id``."""
    return encode_record({"id": req_id, "eos": True, "n": int(n)})


def decode_response(records: Sequence[bytes]) -> Dict[str, List[int]]:
    """Demux a response-stream slice into per-request token lists (in seq
    order; EOS markers dropped). The inverse of the encoders above."""
    out: Dict[str, List[Dict]] = {}
    for raw in records:
        rec = decode_record(raw)
        if rec.get("eos"):
            continue
        out.setdefault(rec["id"], []).append(rec)
    return {rid: [r["tok"] for r in sorted(recs, key=lambda r: r["seq"])]
            for rid, recs in out.items()}


@dataclass
class RolloutResult:
    """One draft-verify-commit round."""
    emitted: List[int]              # tokens durably committed this rollout
    drafted: int                    # draft tokens proposed
    accepted: int                   # draft tokens the target accepted
    rejected: bool                  # True iff the rollout session aborted
    commit: Optional[CommitResult]  # the accepting session's promote result
    rebases: int = 0                # re-anchors over a moved stream tail


@dataclass
class DecodeResult:
    """One request decoded to completion."""
    req_id: str
    tokens: List[int]
    rollouts: List[RolloutResult] = field(default_factory=list)

    @property
    def acceptance(self) -> float:
        drafted = sum(r.drafted for r in self.rollouts)
        return sum(r.accepted for r in self.rollouts) / max(1, drafted)


class SpeculativeDecoder:
    """Drive one target/draft pair over a shared response log.

    ``on_draft(steps)`` / ``on_target(positions)`` are cost hooks: the DES
    benchmark books roofline step times through them (real wall-clock decode
    books nothing — the JAX step itself is the cost).
    """

    def __init__(self, target, draft, k: int = 4,
                 stats: Optional[ServeStats] = None,
                 max_rebases: int = 8,
                 on_draft: Optional[Callable[[int], None]] = None,
                 on_target: Optional[Callable[[int], None]] = None) -> None:
        if k < 1:
            raise ValueError(f"draft depth k must be >= 1, got {k}")
        self.target = target
        self.draft = draft
        self.k = k
        self.stats = stats
        self.max_rebases = max_rebases
        self.on_draft = on_draft
        self.on_target = on_target

    # -- per-phase accounting ------------------------------------------------
    def _draft_steps(self, n: int) -> None:
        if self.stats is not None:
            self.stats.draft_steps += n
        if self.on_draft is not None:
            self.on_draft(n)

    def _target_pass(self, positions: int) -> None:
        if self.stats is not None:
            self.stats.model_steps += 1
        if self.on_target is not None:
            self.on_target(positions)

    def _on_rebase(self, counter: List[int]):
        def hook(spec: Speculation, lo: int, hi: int) -> bool:
            # tokens are (id, seq)-keyed: any interleaving of other writers'
            # records in [lo, hi) is safe to re-anchor over
            counter[0] += 1
            if self.stats is not None:
                self.stats.reanchors += 1
            return True
        return hook

    # -- one rollout ---------------------------------------------------------
    def rollout(self, log: AgileLog, req_id: str, prefix: List[int],
                seq0: int, k: Optional[int] = None) -> RolloutResult:
        """One draft-verify-commit round against ``log``.

        The k draft tokens are appended to the speculation fork FIRST — the
        fork is the sequence branch, and verification validates the fork's
        suffix. Full acceptance appends the bonus token and promotes the
        session; any rejection aborts it (no trace) and commits the accepted
        prefix + correction token through a short second session, so every
        durable token rode a ``promote_if``."""
        k = self.k if k is None else k
        rebases = [0]
        drafted = self.draft.propose(prefix, k)
        self._draft_steps(len(drafted))
        with log.speculate(promotable=True, max_rebases=self.max_rebases,
                           on_rebase=self._on_rebase(rebases)) as spec:
            spec.append_batch([encode_token(req_id, seq0 + i, t)
                               for i, t in enumerate(drafted)])
            truth = self.target.verify(prefix, drafted)
            self._target_pass(len(drafted) + 1)
            n_acc = 0
            while n_acc < len(drafted) and drafted[n_acc] == truth[n_acc]:
                n_acc += 1
            if self.stats is not None:
                self.stats.rollouts += 1
                self.stats.tokens_drafted += len(drafted)
                self.stats.tokens_accepted += n_acc
            if n_acc == len(drafted):
                # full accept: bonus token rides the same session
                bonus = truth[n_acc]
                spec.append(encode_token(req_id, seq0 + n_acc, bonus))
                commit = spec.commit()
                emitted = list(drafted) + [bonus]
                if self.stats is not None:
                    self.stats.tokens_out += len(emitted)
                return RolloutResult(emitted=emitted, drafted=len(drafted),
                                     accepted=n_acc, rejected=False,
                                     commit=commit, rebases=rebases[0])
            # partial/zero accept: the fork holds rejected records — squash
            # the whole session (no trace, §12) ...
            spec.abort()
        if self.stats is not None:
            self.stats.tokens_rejected += len(drafted) - n_acc
            self.stats.rollouts_rejected += 1
        # ... and commit the accepted prefix + the target's correction token
        # through a fresh session (still promote_if-gated, still re-anchors)
        emitted = list(drafted[:n_acc]) + [truth[n_acc]]
        with log.speculate(promotable=True, max_rebases=self.max_rebases,
                           on_rebase=self._on_rebase(rebases)) as spec:
            spec.append_batch([encode_token(req_id, seq0 + i, t)
                               for i, t in enumerate(emitted)])
            commit = spec.commit()
        if self.stats is not None:
            self.stats.tokens_out += len(emitted)
        return RolloutResult(emitted=emitted, drafted=len(drafted),
                             accepted=n_acc, rejected=True,
                             commit=commit, rebases=rebases[0])

    # -- one request ---------------------------------------------------------
    def decode_request(self, log: AgileLog, req_id: str, prompt: List[int],
                       max_new: int, eos: bool = True) -> DecodeResult:
        """Decode ``max_new`` tokens for one request onto the shared
        response log, one speculation session per rollout."""
        result = DecodeResult(req_id=req_id, tokens=[])
        prefix = list(prompt)
        while len(result.tokens) < max_new:
            remaining = max_new - len(result.tokens)
            if remaining == 1:
                # no room for draft + bonus: one plain target step, still
                # committed through a promote_if-gated session
                tok = self.target.verify(prefix, [])[0]
                self._target_pass(1)
                rebases = [0]
                with log.speculate(promotable=True,
                                   max_rebases=self.max_rebases,
                                   on_rebase=self._on_rebase(rebases)) as spec:
                    spec.append(encode_token(req_id, len(result.tokens), tok))
                    commit = spec.commit()
                if self.stats is not None:
                    self.stats.rollouts += 1
                    self.stats.tokens_out += 1
                r = RolloutResult(emitted=[tok], drafted=0, accepted=0,
                                  rejected=False, commit=commit,
                                  rebases=rebases[0])
            else:
                # a rollout emits at most k+1 tokens, so k <= remaining-1
                # guarantees the response never overshoots max_new
                k = min(self.k, remaining - 1)
                r = self.rollout(log, req_id, prefix,
                                 seq0=len(result.tokens), k=k)
            result.rollouts.append(r)
            result.tokens.extend(r.emitted)
            prefix.extend(r.emitted)
        if eos:
            log.append(encode_eos(req_id, len(result.tokens))).wait()
            if self.stats is not None:
                self.stats.responses += 1
        return result


def sequential_decode(target, prompt: List[int], max_new: int,
                      on_target: Optional[Callable[[int], None]] = None,
                      stats: Optional[ServeStats] = None) -> List[int]:
    """Plain greedy decode of the target model — the equivalence reference
    (no log, no draft): ``verify(prefix, [])`` is exactly one decode step."""
    prefix, out = list(prompt), []
    for _ in range(max_new):
        tok = target.verify(prefix, [])[0]
        if stats is not None:
            stats.model_steps += 1
            stats.tokens_out += 1
        if on_target is not None:
            on_target(1)
        out.append(tok)
        prefix.append(tok)
    return out


def sequential_decode_on_log(target, log: AgileLog, req_id: str,
                             prompt: List[int], max_new: int,
                             on_target: Optional[Callable[[int], None]] = None,
                             stats: Optional[ServeStats] = None,
                             eos: bool = True) -> List[int]:
    """The non-speculative serving baseline: one decode step AND one durable
    append per token (each token is acked to subscribers as it is produced —
    the per-token commit cost the rollout sessions amortize away)."""
    prefix, out = list(prompt), []
    for i in range(max_new):
        tok = target.verify(prefix, [])[0]
        if on_target is not None:
            on_target(1)
        log.append(encode_token(req_id, i, tok)).wait()
        if stats is not None:
            stats.model_steps += 1
            stats.tokens_out += 1
        out.append(tok)
        prefix.append(tok)
    if eos:
        log.append(encode_eos(req_id, len(out))).wait()
        if stats is not None:
            stats.responses += 1
    return out
