"""Serving substrate: batched decode engine fed by request streams."""

from .engine import ServeEngine

__all__ = ["ServeEngine"]
