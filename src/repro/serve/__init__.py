"""Serving ON the log (DESIGN.md §17): subscription-fed batched decode,
speculative-decode rollouts as ``log.speculate()`` sessions, and
hlo_cost-derived step costs for the DES benchmarks.

``ServeEngine`` / ``ModelTarget`` / ``ModelDraft`` need JAX, so they load
lazily — the DES benchmark imports only the JAX-free half (``costs``,
``speculative``)."""

from .costs import ServeCosts
from .speculative import (DecodeResult, RolloutResult, SpeculativeDecoder,
                          decode_response, sequential_decode,
                          sequential_decode_on_log)

__all__ = ["ServeEngine", "ModelTarget", "ModelDraft", "ServeCosts",
           "SpeculativeDecoder", "DecodeResult", "RolloutResult",
           "decode_response", "sequential_decode", "sequential_decode_on_log"]

_LAZY = {"ServeEngine", "ModelTarget", "ModelDraft"}


def __getattr__(name: str):
    if name in _LAZY:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
