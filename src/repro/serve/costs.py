"""Model-step service times for serving ON the log (DESIGN.md §17).

The serving benchmark runs real AgileLog sessions under the DES clock, so the
GPU/TPU side of a serving step has to enter the simulation as a service time.
This module derives those times from the SAME cost pipeline `launch/dryrun.py`
uses for training shapes: a step is a :class:`launch.hlo_cost.Cost` (dot
flops / HBM-traffic bytes / collective link-bytes) pushed through the TPU v5e
roofline. Two paths produce the Cost:

* :func:`step_cost_from_hlo` — parse a compiled (post-SPMD) HLO dump through
  ``hlo_cost.analyze``, trip-count-aware. Ground truth, but needs a compiled
  artifact, which CI does not have for 8B-class configs.
* the analytic constructors (:func:`decode_cost`, :func:`prefill_cost`,
  :func:`verify_cost`) — build an equivalent Cost from a
  :class:`~repro.models.config.ModelConfig`'s geometry: ``2 * active_params``
  dot flops per token, parameter + KV-cache bytes as the HBM traffic, and the
  2x-ring all-reduce link bytes tensor parallelism adds per block. Validated
  against the HLO path for the small configs JAX can actually compile here
  (tests/test_serve_on_log.py).

Both paths meet in :func:`roofline_seconds`, which applies the per-chip
roofline `max(flops/PEAK, bytes/BW, coll/ICI)` — the same constants and
dominant-term rule as ``launch/dryrun.py``.

Why decode is PUT-shaped: one decode step of qwen3-8b is ~20 µs of roofline
time, while committing its token to the response stream costs a ~1.5 ms
object PUT (``ServiceTimes.store_put_base``). Serving on the log is therefore
*commit-amortization*-bound, which is exactly what the speculative-decoding
driver exploits: a k-token rollout session commits once per k+1 tokens
instead of once per token (benchmarks/bench_serve.py).
"""

from __future__ import annotations

from dataclasses import dataclass

from ..launch.hlo_cost import Cost, analyze
from ..models.config import ModelConfig

# TPU v5e roofline, per chip — keep in sync with launch/dryrun.py.
PEAK_FLOPS = 197e12   # bf16 FLOP/s
HBM_BW = 819e9        # HBM B/s
ICI_BW = 50e9         # ICI B/s per link

_BF16 = 2  # serving weights/KV are bf16


def roofline_seconds(cost: Cost, n_devices: int = 1) -> float:
    """Per-step seconds for a PER-DEVICE cost under the v5e roofline.

    ``n_devices > 1`` shards a whole-model analytic cost across a tensor-
    parallel group (flops and HBM traffic split evenly; collective link
    bytes in our analytic constructors are already per-device). Costs from
    :func:`step_cost_from_hlo` are post-SPMD and therefore already
    per-device — pass ``n_devices=1`` for those."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    return max(cost.flops / n_devices / PEAK_FLOPS,
               cost.bytes / n_devices / HBM_BW,
               cost.collective_bytes / ICI_BW)


def step_cost_from_hlo(hlo_text: str) -> Cost:
    """Cost of one compiled serving step from its post-SPMD HLO text —
    the `launch/dryrun.py` path, reused verbatim (trip-count-aware,
    TPU dtype correction on, since serving runs bf16)."""
    return analyze(hlo_text, tpu_dtype_correction=True)


def _attn_layers(cfg: ModelConfig) -> int:
    """Layers that hold a KV cache (mamba/linear blocks do not)."""
    per_group = sum(1 for b in cfg.pattern_layers if "attn" in b)
    return per_group * cfg.n_groups + (1 if cfg.first_layer_dense else 0)


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV-cache bytes one sequence position occupies (bf16, all layers)."""
    if cfg.mla is not None:
        # MLA caches the compressed kv latent + rope key, not full heads
        per_layer = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
    else:
        per_layer = 2 * cfg.n_kv_heads * cfg.head_dim_
    return _attn_layers(cfg) * per_layer * _BF16


def decode_cost(cfg: ModelConfig, batch: int, context: int,
                n_devices: int = 1) -> Cost:
    """One greedy decode step: every weight is read once (weights stream,
    batch=O(10) reuses them from registers, not HBM), the whole KV cache is
    read and one position appended, and TP all-reduces the block outputs."""
    total, active = cfg.count_params()
    flops = 2.0 * active * batch
    kv = kv_bytes_per_token(cfg)
    bytes_ = (total * _BF16                   # streamed weights
              + batch * context * kv          # KV read (flash decode)
              + batch * kv                    # KV append
              + batch * cfg.padded_vocab * _BF16)   # logits out
    return Cost(flops=flops, bytes=bytes_,
                coll=_tp_collectives(cfg, batch * 1, n_devices))


def prefill_cost(cfg: ModelConfig, batch: int, prompt_len: int,
                 n_devices: int = 1) -> Cost:
    """Prompt ingestion for a batch: compute-bound (2*active per token) plus
    the O(T^2) attention score flops, writing the prompt's KV cache."""
    total, active = cfg.count_params()
    tokens = batch * prompt_len
    flops = 2.0 * active * tokens
    # causal attention scores/values: 2 matmuls of [T, Dh] @ [Dh, T] per head
    attn = (2.0 * 2.0 * _attn_layers(cfg) * cfg.n_heads * cfg.head_dim_
            * prompt_len * prompt_len / 2.0) * batch
    bytes_ = (total * _BF16
              + tokens * kv_bytes_per_token(cfg)      # KV write
              + 2.0 * tokens * cfg.d_model * _BF16)   # activations in/out
    return Cost(flops=flops + attn, bytes=bytes_,
                coll=_tp_collectives(cfg, tokens, n_devices))


def verify_cost(cfg: ModelConfig, batch: int, context: int, k: int,
                n_devices: int = 1) -> Cost:
    """Target-model verification of k draft tokens in ONE forward pass:
    k+1 positions of compute (k drafts + the bonus/correction logits), but
    the weights still stream only once — this is the whole speculative win:
    ``verify_cost(k) ≪ (k+1) * decode_cost`` whenever decode is
    memory-bound."""
    total, active = cfg.count_params()
    positions = k + 1
    flops = 2.0 * active * batch * positions
    kv = kv_bytes_per_token(cfg)
    bytes_ = (total * _BF16
              + batch * context * kv                 # cache read (once)
              + batch * positions * kv               # speculative KV append
              + batch * positions * cfg.padded_vocab * _BF16)
    return Cost(flops=flops, bytes=bytes_,
                coll=_tp_collectives(cfg, batch * positions, n_devices))


def _tp_collectives(cfg: ModelConfig, tokens: int, n_devices: int) -> dict:
    """Per-device all-reduce link bytes tensor parallelism adds: two
    activation all-reduces per block (attn out, mlp out), ring coefficient
    2x — matching hlo_cost's ``_COLL_COEF`` convention. Zero off TP."""
    if n_devices <= 1:
        return {}
    link_bytes = (2.0                      # ring coefficient (all-reduce)
                  * 2.0 * cfg.n_layers     # two all-reduces per block
                  * tokens * cfg.d_model * _BF16)
    return {"all-reduce": [2.0 * cfg.n_layers, link_bytes]}


@dataclass(frozen=True)
class ServeCosts:
    """Per-phase service times (seconds) a serving workload books against
    the DES clock. ``verify(k)`` is affine in k so the bench can sweep draft
    depth without rebuilding costs."""

    prefill_per_token: float   # target prefill, per prompt token (per batch)
    decode_step: float         # one target decode step (whole batch)
    draft_step: float          # one draft-model decode step (whole batch)
    verify_base: float         # verify pass at k=0 (just the bonus position)
    verify_per_token: float    # marginal verify cost per extra draft token

    def verify(self, k: int) -> float:
        """One target verification pass over k draft tokens."""
        return self.verify_base + self.verify_per_token * k

    @classmethod
    def for_models(cls, target: ModelConfig, draft: ModelConfig,
                   batch: int = 8, context: int = 512,
                   target_devices: int = 1, draft_devices: int = 1
                   ) -> "ServeCosts":
        """Analytic costs for a (target, draft) pair at a fixed batch and
        nominal context length (KV traffic is charged at `context` — the
        mid-stream steady state — rather than growing per step, keeping the
        DES deterministic in shape)."""
        v0 = roofline_seconds(verify_cost(target, batch, context, 0,
                                          target_devices), target_devices)
        v4 = roofline_seconds(verify_cost(target, batch, context, 4,
                                          target_devices), target_devices)
        return cls(
            prefill_per_token=roofline_seconds(
                prefill_cost(target, batch, context, target_devices),
                target_devices) / max(1, context),
            decode_step=roofline_seconds(
                decode_cost(target, batch, context, target_devices),
                target_devices),
            draft_step=roofline_seconds(
                decode_cost(draft, batch, context, draft_devices),
                draft_devices),
            verify_base=v0,
            verify_per_token=(v4 - v0) / 4.0,
        )
