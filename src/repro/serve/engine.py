"""Batched serving engine: request topic -> prefill -> decode -> response topic.

Reworked for DESIGN.md §17: both sides of the engine now ride the §12
session API end-to-end. Requests arrive through a tailing
``log.subscribe()`` (held by the offset-tracking :class:`Consumer`, whose
cursor is a durable resume token), and every response token batch is
appended with an :class:`AppendReceipt` the engine waits on before
committing its request cursor — a crash between the two replays the batch
rather than losing it. Per-token response records are ``(id, seq)``-keyed so
clients demux the shared response stream from their own subscription.

The production-shape decode step (sequence-sharded KV cache, flash-decoding
combine) is what the dry-run compiles per (arch × decode shape); this engine
is the same step driven end-to-end at host scale. :class:`ModelTarget` /
:class:`ModelDraft` adapt that step to the JAX-free speculative driver
(``serve/speculative.py``), which maps each draft rollout onto a
``log.speculate()`` session.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sim import ServeStats
from ..models.config import ModelConfig
from ..models.lm import decode_step, init_caches
from ..streams.topics import Consumer, Topic
from .speculative import encode_eos, encode_token


class _JaxStepper:
    """Greedy decode over the repo's ``decode_step``, recomputed from the
    prefix each call. O(T) steps per call is the honest trade for test-scale
    configs: no per-request cache registry to invalidate when a speculative
    branch is squashed — the log IS the state, the model is a pure function
    of it (the §17 mapping taken literally)."""

    def __init__(self, cfg: ModelConfig, params,
                 stats: Optional[ServeStats] = None) -> None:
        self.cfg = cfg
        self.params = params
        self.stats = stats
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def _greedy(self, logits: jax.Array) -> int:
        return int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))

    def _run(self, tokens: List[int], extra: int) -> tuple:
        """Feed ``tokens`` one position at a time; returns (logits, caches)
        after the last token, with cache room for ``extra`` more."""
        caches = init_caches(self.cfg, 1, len(tokens) + extra)
        arr = jnp.asarray(np.asarray(tokens, np.int32)[None, :])
        logits = None
        for t in range(len(tokens)):
            logits, caches = self._step(self.params, caches,
                                        arr[:, t:t + 1],
                                        jnp.asarray(t, jnp.int32))
        return logits, caches


class ModelTarget(_JaxStepper):
    """TargetModel adapter: ``verify(prefix, draft)`` returns the k+1 greedy
    tokens (position i conditioned on ``prefix + draft[:i]``) — one logical
    forward pass over the draft window."""

    def verify(self, prefix: List[int], draft: List[int]) -> List[int]:
        logits, caches = self._run(list(prefix), extra=len(draft) + 1)
        if self.stats is not None:
            self.stats.model_steps += 1
        out = [self._greedy(logits)]
        base = len(prefix)
        for i, tok in enumerate(draft):
            arr = jnp.asarray([[tok]], jnp.int32)
            logits, caches = self._step(self.params, caches, arr,
                                        jnp.asarray(base + i, jnp.int32))
            out.append(self._greedy(logits))
        return out


class ModelDraft(_JaxStepper):
    """DraftModel adapter: k greedy tokens from the (smaller) draft model."""

    def propose(self, prefix: List[int], k: int) -> List[int]:
        logits, caches = self._run(list(prefix), extra=k)
        if self.stats is not None:
            self.stats.draft_steps += k
        out = []
        base = len(prefix)
        for i in range(k):
            tok = self._greedy(logits)
            out.append(tok)
            if i + 1 < k:
                arr = jnp.asarray([[tok]], jnp.int32)
                logits, caches = self._step(self.params, caches, arr,
                                            jnp.asarray(base + i, jnp.int32))
        return out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, requests: Topic,
                 responses: Topic, batch_size: int = 4,
                 max_len: int = 64, group: str = "serve") -> None:
        self.cfg = cfg
        self.params = params
        # subscription-backed consumer (§12): restore() makes the request
        # cursor a durable resume token, so a restarted engine re-serves
        # exactly the uncommitted suffix
        self.consumer = Consumer.restore(requests, group=group)
        self.responses = responses
        self.batch_size = batch_size
        self.max_len = max_len
        self.stats: ServeStats = requests.log.system.serve_stats
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
        self.served = 0

    def _greedy(self, logits: jax.Array) -> jax.Array:
        return jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1)

    def poll_and_serve(self, gen_tokens: int = 16) -> int:
        """Serve one batch of requests from the subscription; returns
        #served. Response tokens are appended as ``(id, seq)`` records plus
        an EOS marker per request; the receipt is waited before the request
        cursor commits (at-least-once across a crash, deduped by key)."""
        reqs = self.consumer.poll(self.batch_size)
        if not reqs:
            return 0
        B = len(reqs)
        self.stats.requests += B
        prompts = [r["prompt"] for r in reqs]
        plen = max(len(p) for p in prompts)
        toks = np.full((B, plen), 1, np.int32)
        for i, p_ in enumerate(prompts):
            toks[i, plen - len(p_):] = p_   # left-pad
        tokens = jnp.asarray(toks)
        caches = init_caches(self.cfg, B, plen + gen_tokens)
        logits = None
        for t in range(plen):   # teacher-forced prefill through the decode path
            logits, caches = self._step(self.params, caches,
                                        tokens[:, t:t + 1],
                                        jnp.asarray(t, jnp.int32))
            self.stats.model_steps += 1
        outs = [self._greedy(logits)]
        for t in range(plen, plen + gen_tokens - 1):
            logits, caches = self._step(self.params, caches,
                                        outs[-1][:, None],
                                        jnp.asarray(t, jnp.int32))
            outs.append(self._greedy(logits))
            self.stats.model_steps += 1
        gen = np.asarray(jnp.stack(outs, axis=1))
        records: List[bytes] = []
        for i, r in enumerate(reqs):
            records.extend(encode_token(r["id"], j, int(tok))
                           for j, tok in enumerate(gen[i]))
            records.append(encode_eos(r["id"], int(gen.shape[1])))
        receipt = self.responses.log.append_batch(records)
        receipt.wait()          # durable before the request cursor moves
        self.consumer.commit()
        self.stats.tokens_out += int(gen.size)
        self.stats.responses += B
        self.served += B
        return B
