"""Batched serving engine: request topic -> prefill -> decode -> response topic.

The production-shape decode step (sequence-sharded KV cache, flash-decoding
combine) is what the dry-run compiles per (arch × decode shape); this engine
is the same step driven end-to-end at host scale, with the log as both the
request queue and the response sink (the paper's "agents consume model
outputs from streams" loop).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.lm import decode_step, init_caches
from ..streams.topics import Consumer, Producer, Topic


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, requests: Topic,
                 responses: Topic, batch_size: int = 4,
                 max_len: int = 64) -> None:
        self.cfg = cfg
        self.params = params
        self.consumer = Consumer(requests, group="serve")
        self.producer = Producer(responses)
        self.batch_size = batch_size
        self.max_len = max_len
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
        self.served = 0

    def _greedy(self, logits: jax.Array) -> jax.Array:
        return jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1)

    def poll_and_serve(self, gen_tokens: int = 16) -> int:
        """Serve one batch of requests from the stream; returns #served."""
        reqs = self.consumer.poll(self.batch_size)
        if not reqs:
            return 0
        B = len(reqs)
        prompts = [r["prompt"] for r in reqs]
        plen = max(len(p) for p in prompts)
        toks = np.full((B, plen), 1, np.int32)
        for i, p_ in enumerate(prompts):
            toks[i, plen - len(p_):] = p_   # left-pad
        tokens = jnp.asarray(toks)
        caches = init_caches(self.cfg, B, plen + gen_tokens)
        logits = None
        for t in range(plen):   # teacher-forced prefill through the decode path
            logits, caches = self._step(self.params, caches,
                                        tokens[:, t:t + 1],
                                        jnp.asarray(t, jnp.int32))
        outs = [self._greedy(logits)]
        for t in range(plen, plen + gen_tokens - 1):
            logits, caches = self._step(self.params, caches,
                                        outs[-1][:, None],
                                        jnp.asarray(t, jnp.int32))
            outs.append(self._greedy(logits))
        gen = np.asarray(jnp.stack(outs, axis=1))
        for i, r in enumerate(reqs):
            self.producer.produce({"id": r["id"],
                                   "tokens": [int(x) for x in gen[i]]})
        self.producer.flush()
        self.consumer.commit()
        self.served += B
        return B
