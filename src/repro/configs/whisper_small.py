"""whisper-small [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

12L (decoder; + 12L encoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
input_specs provides precomputed frame embeddings (B, 1500, d_model) — the
conv frontend is the assignment's modality stub. Decoder uses RoPE instead of
Whisper's learned positions (geometry-preserving; noted in DESIGN.md).
Small model: attention replicates over 'model'; MLP/vocab TP-shard.
"""
from ..models.config import EncDecCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, encdec=EncDecCfg(enc_layers=12, enc_len=1500),
    mlp_gated=False, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, encdec=EncDecCfg(enc_layers=2, enc_len=30),
)
