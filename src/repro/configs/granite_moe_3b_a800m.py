"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

32L d_model=1536 24H (GQA kv=8) d_ff=512 (expert dim) vocab=49155,
MoE 40e top-8. Experts pad 40->48 for EP-16 (padded experts masked to -inf in
the router). Small attention (24H) replicates over 'model'.
"""
from ..models.config import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab_size=49155,
    block_pattern=("attn+moe",),
    moe=MoECfg(n_experts=40, top_k=8, d_expert=512),
    pad_experts_to=48, rope_theta=10_000.0,
    # TP-16: pad 24 q-heads to 32 (one zero slot per kv superblock, exact
    # geometry) + kv_repeat 8->16; unpadded attention replicates over 'model'
    # = 16x redundant attention flops (hillclimb iteration 3, §Perf)
    pad_heads_to=32, kv_repeat=2,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=512, block_pattern=("attn+moe",),
    moe=MoECfg(n_experts=8, top_k=2, d_expert=64),
)
