"""deepseek-67b [dense] — llama-arch. [arXiv:2401.02954; hf]

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
kv_repeat=2 -> 16 effective kv heads (exact; tied copies) for TP-16.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=102400, rope_theta=10_000.0, kv_repeat=2,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", n_layers=3, d_model=96, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512, kv_repeat=2,
)
