"""smollm-135m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152. Heads (9) don't divide
the 16-way model axis: attention weights replicate over 'model' (tiny model —
DESIGN.md §5 divisibility fallback); MLP/vocab dims still TP-shard.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab_size=49152, rope_theta=10_000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, tie_embeddings=True,
)
