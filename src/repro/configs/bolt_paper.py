"""The paper's OWN system configuration (§6 Setup) — used by benchmarks.

CloudLab x1170: nine MinIO storage nodes, three metadata replicas, one
broker per node, 4 KB records. Our benchmarks scale record counts for the
1-CPU container but keep the structural ratios; `BoltDeployment.make()`
builds the equivalently-shaped in-process system.
"""

from dataclasses import dataclass

from ..core import BoltSystem


@dataclass(frozen=True)
class BoltDeployment:
    n_storage_nodes: int = 9       # MinIO nodes (store parallelism in DES)
    n_meta_replicas: int = 3       # Raft group size
    n_brokers: int = 4             # broker pool (root + fork brokers)
    record_bytes: int = 4096       # paper's record size
    snapshot_every: int = 1024     # metadata log compaction cadence

    def make(self, **overrides) -> BoltSystem:
        kw = dict(n_brokers=self.n_brokers,
                  n_meta_replicas=self.n_meta_replicas,
                  snapshot_every=self.snapshot_every)
        kw.update(overrides)
        return BoltSystem(**kw)


PAPER = BoltDeployment()
