"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.
[arXiv:2405.04434; hf]

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400; first layer dense
(d_ff=10944 per the HF config), 26 MoE layers. MLA: kv_lora_rank=512,
rope_head_dim=64, head_dim=128. 16 heads / 64 experts divide TP/EP-16 exactly.
"""
from ..models.config import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=10944, vocab_size=102400, head_dim=128,
    block_pattern=("mla+moe",), first_layer_dense=True,
    mla=MLACfg(kv_lora_rank=512, rope_head_dim=64),
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512, head_dim=16,
    block_pattern=("mla+moe",), first_layer_dense=True,
    mla=MLACfg(kv_lora_rank=32, rope_head_dim=8),
    moe=MoECfg(n_experts=4, top_k=2, d_expert=32, n_shared=1),
)
