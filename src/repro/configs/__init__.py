"""Architecture registry: the 10 assigned configs (+ reduced smoke variants).

``get_config(arch)`` returns the full config; ``get_smoke_config(arch)`` the
reduced same-family config used by CPU smoke tests. Exact geometry per the
assignment table; [source; tier] recorded in each module.
"""

from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "smollm-135m",
    "deepseek-67b",
    "starcoder2-15b",
    "qwen3-8b",
    "llava-next-34b",
    "jamba-1.5-large-398b",
    "whisper-small",
    "granite-moe-3b-a800m",
    "deepseek-v2-lite-16b",
    "xlstm-1.3b",
]


def _module(arch: str):
    name = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f".{name}", __package__)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE
