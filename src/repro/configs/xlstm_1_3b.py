"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1). [arXiv:2405.04517; unverified]

48L d_model=2048 4H d_ff=0 vocab=50304. Blocks carry their own projections
(no separate FFN). O(1) recurrent state -> runs the long_500k shape.
"""
from ..models.config import ModelConfig

_PATTERN = ("mlstm",) * 7 + ("slstm",)

CONFIG = ModelConfig(
    name="xlstm-1.3b", n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, block_pattern=_PATTERN, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", n_layers=8, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=512, block_pattern=_PATTERN,
)
