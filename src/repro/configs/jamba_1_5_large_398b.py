"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
(d_expert = d_ff; total ~398B, active ~98B). Pattern of 8 layers: one
attention per 8 (1:7) and MoE on alternate layers (4 of 8).
kv_repeat=2 -> 16 effective kv heads.
"""
from ..models.config import MambaCfg, ModelConfig, MoECfg

_PATTERN = ("mamba+moe", "mamba+mlp", "mamba+moe", "mamba+mlp",
            "attn+moe", "mamba+mlp", "mamba+moe", "mamba+mlp")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=24576, vocab_size=65536, block_pattern=_PATTERN,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=24576),
    mamba=MambaCfg(d_state=16, expand=2, conv_width=4),
    rope_theta=1_000_000.0, kv_repeat=2,
)

SMOKE = ModelConfig(
    name="jamba-smoke", n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, block_pattern=_PATTERN,
    moe=MoECfg(n_experts=4, top_k=2, d_expert=128),
    mamba=MambaCfg(d_state=4, expand=2, conv_width=4),
)
