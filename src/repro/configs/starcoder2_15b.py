"""starcoder2-15b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
kv_repeat=4 -> 16 effective kv heads for TP-16.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab_size=49152, rope_theta=100_000.0, kv_repeat=4,
    mlp_gated=False,
)

SMOKE = ModelConfig(
    name="starcoder2-15b-smoke", n_layers=4, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab_size=512, kv_repeat=1,
)
