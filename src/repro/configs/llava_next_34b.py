"""llava-next-34b [vlm] — anyres tiling (stubbed frontend).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. Backbone only; the
vision frontend is a stub: input_specs provides 576 precomputed patch
embeddings. 56 heads pad to 64 (per-superblock zero slots, exact geometry)
and kv_repeat=2 -> 16 effective kv heads for TP-16.
"""
from ..models.config import ModelConfig, VLMCfg

CONFIG = ModelConfig(
    name="llava-next-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, rope_theta=1_000_000.0,
    vlm=VLMCfg(n_patches=576), pad_heads_to=64, kv_repeat=2,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", n_layers=3, d_model=64, n_heads=7, n_kv_heads=1,
    head_dim=8, d_ff=128, vocab_size=512, vlm=VLMCfg(n_patches=16),
    pad_heads_to=8,
)
