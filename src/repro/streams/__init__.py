"""Data-streaming layer on top of AgileLog: topics, producers, consumers,
consumer groups, schemas, and windowed stream processors."""

from .records import decode_record, encode_record
from .topics import Consumer, Producer, SchemaRegistry, StreamProcessor, Topic

__all__ = ["Topic", "Producer", "Consumer", "SchemaRegistry",
           "StreamProcessor", "encode_record", "decode_record"]
