"""Record (de)serialization for the streaming layer.

Records are dicts serialized with orjson (fast, deterministic byte output).
A leading schema-id byte sequence is intentionally NOT used: schema validation
is a consumer/registry concern (and the supply-chain experiment relies on a
malformed record crashing an unguarded consumer, as with real Kafka payloads).
"""

from __future__ import annotations

from typing import Any, Dict

try:
    import orjson as _json

    def encode_record(rec: Dict[str, Any]) -> bytes:
        return _json.dumps(rec)

    def decode_record(data: bytes) -> Dict[str, Any]:
        return _json.loads(data)

except ImportError:  # pragma: no cover
    import json as _json2

    def encode_record(rec: Dict[str, Any]) -> bytes:
        return _json2.dumps(rec, separators=(",", ":")).encode()

    def decode_record(data: bytes) -> Dict[str, Any]:
        return _json2.loads(data.decode())
