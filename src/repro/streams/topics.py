"""Topics, producers, consumer groups, schemas, and stream processors.

A Topic wraps one AgileLog. Consumers are built on the session layer's
tailing subscriptions (DESIGN.md §12) — the log's Subscription owns the
cursor; `commit` persists it through the object store so restarts resume
exactly. A StreamProcessor is the classic stateful consumer: tumbling-window
aggregation, which the stream-processor-testing agent (§6.8) exercises on
cForks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from ..core.api import AgileLog, AppendReceipt, BoltSystem, Speculation
from .records import decode_record, encode_record


class SchemaError(Exception):
    pass


@dataclass
class Schema:
    """Field name -> python type. `strict` rejects unknown fields."""
    fields: Dict[str, type]
    required: Tuple[str, ...] = ()
    strict: bool = False

    def validate(self, rec: Dict[str, Any]) -> None:
        for f in self.required:
            if f not in rec:
                raise SchemaError(f"missing required field {f!r}")
        for k, v in rec.items():
            if k in self.fields:
                if not isinstance(v, self.fields[k]):
                    raise SchemaError(
                        f"field {k!r}: expected {self.fields[k].__name__}, "
                        f"got {type(v).__name__}")
            elif self.strict:
                raise SchemaError(f"unknown field {k!r}")


class SchemaRegistry:
    def __init__(self) -> None:
        self._schemas: Dict[str, Schema] = {}

    def register(self, topic: str, schema: Schema) -> None:
        self._schemas[topic] = schema

    def get(self, topic: str) -> Optional[Schema]:
        return self._schemas.get(topic)


class Topic:
    """A named stream backed by one AgileLog (root or fork)."""

    def __init__(self, name: str, log: AgileLog,
                 registry: Optional[SchemaRegistry] = None) -> None:
        self.name = name
        self.log = log
        self.registry = registry

    @classmethod
    def create(cls, system: BoltSystem, name: str,
               registry: Optional[SchemaRegistry] = None) -> "Topic":
        return cls(name, system.create_log(name), registry)

    # forks of a topic are topics over forks of the log
    def cfork(self, promotable: bool = False, dedicated: bool = False) -> "Topic":
        return Topic(f"{self.name}/cfork", self.log.cfork(promotable, dedicated),
                     self.registry)

    def sfork(self, past: Optional[int] = None, dedicated: bool = False) -> "Topic":
        return Topic(f"{self.name}/sfork", self.log.sfork(past, dedicated),
                     self.registry)

    def speculate(self, **kwargs) -> Speculation:
        """Open a speculative fork transaction on this topic's log
        (DESIGN.md §12); wrap ``spec.log`` in a Topic to run consumers on it."""
        return self.log.speculate(**kwargs)

    @property
    def tail(self) -> int:
        return self.log.tail


class Producer:
    """Validating (optionally) record producer with client-side batching."""

    def __init__(self, topic: Topic, validate: bool = False,
                 linger_records: int = 1) -> None:
        self.topic = topic
        self.validate = validate
        self.linger = max(1, linger_records)
        self._buf: List[bytes] = []
        self.produced = 0

    def produce(self, rec: Dict[str, Any]) -> Optional[AppendReceipt]:
        """Buffer one record; returns the batch's AppendReceipt when this
        record triggered a flush, else None."""
        if self.validate and self.topic.registry:
            schema = self.topic.registry.get(self.topic.name.split("/")[0])
            if schema:
                schema.validate(rec)
        self._buf.append(encode_record(rec))
        self.produced += 1
        if len(self._buf) >= self.linger:
            return self.flush()
        return None

    def flush(self) -> Optional[AppendReceipt]:
        if not self._buf:
            return None
        receipt = self.topic.log.append_batch(self._buf)
        self._buf.clear()
        return receipt


class Consumer:
    """Offset-tracking consumer, built on a tailing Subscription
    (DESIGN.md §12): the subscription owns the cursor, `poll` is one
    cooperative non-blocking step, `stream` iterates decoded batches
    push-style, and `commit` persists the cursor so a restarted consumer
    resumes exactly (the log position IS the resume cursor)."""

    def __init__(self, topic: Topic, group: str = "default",
                 start: int = 0) -> None:
        self.topic = topic
        self.group = group
        self._sub = topic.log.subscribe(from_pos=start, batch=256)
        self.committed = start

    @property
    def offset(self) -> int:
        return self._sub.position

    def poll(self, max_records: int = 256) -> List[Dict[str, Any]]:
        return [decode_record(b) for b in self._sub.poll(max_records)]

    def stream(self, follow: bool = False, max_idle: Optional[int] = None
               ) -> Iterator[List[Dict[str, Any]]]:
        """Iterate decoded batches: drain to the visible tail
        (``follow=False``) or keep tailing with backoff (``follow=True``)."""
        self._sub.follow = follow
        self._sub.max_idle = max_idle
        for batch in self._sub:
            yield [decode_record(b) for b in batch]

    def commit(self) -> None:
        key = f"__offsets/{self.topic.log.log_id}/{self.group}"
        self.topic.log.system.store.put(key, str(self.offset).encode())
        self.committed = self.offset

    @classmethod
    def restore(cls, topic: Topic, group: str = "default") -> "Consumer":
        key = f"__offsets/{topic.log.log_id}/{group}"
        start = 0
        if topic.log.system.store.exists(key):
            start = int(topic.log.system.store.get(key))
        return cls(topic, group, start=start)


@dataclass
class WindowResult:
    window_start: float
    count: int
    aggregate: float


class StreamProcessor:
    """Tumbling-window aggregator (§6.8's processor-under-test).

    Consumes records with (`ts`, `value`) fields, aggregates per window of
    `window_ms`, and appends results to an output topic. Deliberately strict:
    malformed records raise (that is what the Kafka-mode supply-chain
    experiment demonstrates), unless `guard=True`.
    """

    def __init__(self, input_topic: Topic, output_topic: Optional[Topic] = None,
                 window_ms: float = 5.0, agg: Callable[[List[float]], float] = sum,
                 guard: bool = False) -> None:
        self.consumer = Consumer(input_topic, group="proc")
        self.output = Producer(output_topic) if output_topic else None
        self.window_ms = window_ms
        self.agg = agg
        self.guard = guard
        self.windows: Dict[int, List[float]] = {}
        self.results: List[WindowResult] = []
        self.errors: List[str] = []
        self.seen_keys: set = set()

    def _ingest(self, recs: List[Dict[str, Any]]) -> None:
        for rec in recs:
            try:
                ts = rec["ts"]
                value = float(rec["value"])
                if not isinstance(ts, (int, float)):
                    raise TypeError(f"bad ts type {type(ts).__name__}")
                key = rec.get("key")
                if key is not None:
                    if key in self.seen_keys:
                        continue  # dedup (exactly-once-ish semantics)
                    self.seen_keys.add(key)
                w = int(ts // self.window_ms)
                self.windows.setdefault(w, []).append(value)
            except Exception as e:
                if not self.guard:
                    raise
                self.errors.append(f"{type(e).__name__}: {e}")

    def step(self, max_records: int = 256) -> int:
        recs = self.consumer.poll(max_records)
        self._ingest(recs)
        return len(recs)

    def close_windows(self, watermark_ts: float) -> List[WindowResult]:
        """Emit windows fully below the watermark."""
        done = [w for w in self.windows if (w + 1) * self.window_ms <= watermark_ts]
        out = []
        for w in sorted(done):
            vals = self.windows.pop(w)
            res = WindowResult(w * self.window_ms, len(vals), self.agg(vals))
            self.results.append(res)
            out.append(res)
            if self.output:
                self.output.produce({"ts": res.window_start, "count": res.count,
                                     "value": res.aggregate})
        if self.output:
            self.output.flush()
        return out

    def run_to_tail(self) -> None:
        """Drain the input subscription to the visible tail, then close all
        windows (push-shaped: batches arrive from the consumer's stream)."""
        for recs in self.consumer.stream(follow=False):
            self._ingest(recs)
        self.close_windows(float("inf"))
