"""Stateless brokers (§5.2-5.3): the diskless data plane.

A broker owns no durable state: appends batch client records into a single
object, PUT it to shared storage, then sequence the per-record metadata through
the metadata layer (steps a1-a7 of Fig. 3). Reads resolve byte spans at the
metadata layer and ranged-GET them from shared storage through a local object
cache (r1-r7).

Brokers double as DES resources for the isolation benchmarks: when a
:class:`~repro.core.sim.Simulator` is attached, each operation also books
simulated service time on this broker's queue (and the shared store's), which
is how contention (or its absence) is measured without real hardware.
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

from .objectstore import LRUObjectCache, ObjectStore
from .sim import Resource, ServiceTimes, Simulator

_obj_counter = itertools.count()


class Broker:
    def __init__(self, broker_id: int, store: ObjectStore, metadata,
                 cache_bytes: int = 64 << 20,
                 sim: Optional[Simulator] = None,
                 service: Optional[ServiceTimes] = None,
                 store_resource: Optional[Resource] = None) -> None:
        self.broker_id = broker_id
        self.store = store
        self.metadata = metadata
        self.cache = LRUObjectCache(store, cache_bytes)
        # DES hooks
        self.sim = sim
        self.service = service or ServiceTimes()
        self.cpu = Resource(servers=1)
        self.store_resource = store_resource
        self.appends = 0
        self.reads = 0

    # -- data path ----------------------------------------------------------------
    def append(self, log_id: int, records: Sequence[bytes],
               arrival: Optional[float] = None) -> Tuple[Optional[List[int]], float]:
        """Returns (positions-or-None, completion_time). positions is None when
        an active promotable cFork hides them (§4.1)."""
        object_id = f"obj-{self.broker_id}-{next(_obj_counter)}"
        payload = b"".join(records)
        offsets, lengths, off = [], [], 0
        for r in records:
            offsets.append(off)
            lengths.append(len(r))
            off += len(r)
        self.store.put(object_id, payload)
        positions = self.metadata.propose(
            ("append", log_id, object_id, tuple(offsets), tuple(lengths)))
        self.appends += 1
        done = self._book(arrival, write_bytes=len(payload))
        return positions, done

    def read(self, log_id: int, lo: int, hi: int,
             arrival: Optional[float] = None) -> Tuple[List[bytes], float]:
        spans = self.metadata.state.read_spans(log_id, lo, hi)
        blobs = self.cache.get_spans(spans)
        self.reads += 1
        done = self._book(arrival, read_bytes=sum(len(b) for b in blobs))
        return blobs, done

    def read_records(self, log_id: int, lo: int, hi: int) -> List[bytes]:
        """Read and return individual records (one span per record)."""
        spans = self.metadata.state.read_record_spans(log_id, lo, hi)
        return [self.cache.get(obj, off, ln) for (obj, off, ln) in spans]

    # -- DES accounting -----------------------------------------------------------
    def _book(self, arrival: Optional[float], write_bytes: int = 0,
              read_bytes: int = 0) -> float:
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        t = arrival
        cpu_time = s.broker_cpu_per_req + s.broker_cpu_per_kb * (write_bytes + read_bytes) / 1024
        t = self.cpu.submit(t, cpu_time)
        if self.store_resource is not None:
            if write_bytes:
                t = self.store_resource.submit(t, s.store_put_base + s.store_put_per_kb * write_bytes / 1024)
            if read_bytes:
                t = self.store_resource.submit(t, s.store_get_base + s.store_get_per_kb * read_bytes / 1024)
        t += s.metadata_op + s.net_rtt
        return t


class KafkaLikeBroker(Broker):
    """Stateful shared-broker baseline (§6.2): all workloads hit the same broker
    and its local disk, so agentic bulk reads contend with the lc-workload. The
    'disk' is a single DES resource attached to this broker."""

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.disk = Resource(servers=1)

    def _book(self, arrival: Optional[float], write_bytes: int = 0,
              read_bytes: int = 0) -> float:
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        t = arrival
        cpu_time = s.broker_cpu_per_req + s.broker_cpu_per_kb * (write_bytes + read_bytes) / 1024
        t = self.cpu.submit(t, cpu_time)
        nbytes = write_bytes + read_bytes
        if nbytes:
            t = self.disk.submit(t, s.disk_seek + s.disk_read_per_kb * nbytes / 1024)
        t += s.metadata_op + s.net_rtt
        return t
