"""Stateless brokers (§5.2-5.3, DESIGN.md §6): the diskless data plane.

A broker owns no durable state: appends batch client records into a single
object, PUT it to shared storage, then sequence the per-record metadata through
the metadata layer (steps a1-a7 of Fig. 3). Reads resolve byte spans at the
metadata layer and ranged-GET them from shared storage through a local object
cache (r1-r7).

With *group commit* enabled (DESIGN.md §9) the broker additionally amortizes
the data- and metadata-plane round trips across concurrent appenders: records
are staged into a per-broker buffer and flushed — by record-count, byte, or
DES-time policy — as ONE segment object PUT plus ONE batched metadata proposal
covering every staged log. Appenders get a :class:`PendingAppend` that
resolves to their assigned positions when the flush commits.

Brokers double as DES resources for the isolation benchmarks: when a
:class:`~repro.core.sim.Simulator` is attached, each operation also books
simulated service time on this broker's queue (and the shared store's), which
is how contention (or its absence) is measured without real hardware.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from . import errors as _errors
from .errors import AgileLogError, BrokerCrashed, Unavailable
from .objectstore import LRUObjectCache, ObjectStore, SegmentWriter
from .sim import Resource, ServiceTimes, Simulator

_obj_counter = itertools.count()


@dataclass
class GroupCommitConfig:
    """Flush policy for the group-commit staging buffer (DESIGN.md §9).

    A flush is triggered by whichever bound is hit first: staged record count,
    staged payload bytes, or — when appends carry DES arrival times — a record
    arriving more than ``max_delay`` simulated seconds after the oldest staged
    one. Explicit ``flush()`` and reads of a staged log also flush.
    """

    max_records: int = 64
    max_bytes: int = 1 << 20
    max_delay: float = 500e-6


class PendingAppend:
    """Deferred ack for a staged append: resolves at flush commit.

    ``result()`` forces a flush of the owning broker if the batch has not
    committed yet, then returns the assigned positions (or ``None`` when an
    active promotable cFork withholds them, §4.1) or raises the deterministic
    error the metadata layer produced for this log.

    ``segment`` is set when the records become durable: the
    ``(object_id, offsets, lengths)`` triple locating this append's bytes in
    shared storage. The session layer's rebase replay (DESIGN.md §12) re-
    sequences those already-durable records through :meth:`Broker.replay`
    without ever re-PUTting them. This is a broker-internal type — clients
    see :class:`~repro.core.api.AppendReceipt`.
    """

    __slots__ = ("broker", "log_id", "n", "done", "done_time", "segment",
                 "_positions", "_error")

    def __init__(self, broker: "Broker", log_id: int, n: int) -> None:
        self.broker = broker
        self.log_id = log_id
        self.n = n
        self.done = False
        self.done_time = 0.0
        self.segment: Optional[Tuple[str, Tuple[int, ...], Tuple[int, ...]]] = None
        self._positions: Optional[List[int]] = None
        self._error: Optional[Exception] = None

    def _resolve(self, positions: Optional[List[int]], done_time: float) -> None:
        self._positions = positions
        self.done = True
        self.done_time = done_time

    def _fail(self, error: Exception, done_time: float) -> None:
        self._error = error
        self.done = True
        self.done_time = done_time

    def result(self) -> Optional[List[int]]:
        if not self.done:
            self.broker.flush()
        if self._error is not None:
            raise self._error
        return self._positions


class Broker:
    def __init__(self, broker_id: int, store: ObjectStore, metadata,
                 cache_bytes: int = 64 << 20,
                 cache_page_bytes: int = 64 << 10,
                 readahead_bytes: int = 256 << 10,
                 sim: Optional[Simulator] = None,
                 service: Optional[ServiceTimes] = None,
                 store_resource: Optional[Resource] = None,
                 group_commit: Optional[GroupCommitConfig] = None) -> None:
        self.broker_id = broker_id
        self.store = store
        self.metadata = metadata
        self.cache = LRUObjectCache(store, cache_bytes,
                                    page_bytes=cache_page_bytes,
                                    readahead_bytes=readahead_bytes)
        # group-commit staging (DESIGN.md §9)
        self.group_commit = group_commit
        self._staged: List[Tuple[PendingAppend, List[bytes]]] = []
        self._staged_bytes = 0
        self._staged_records = 0
        self._staged_first_arrival: Optional[float] = None
        self.flushes = 0
        # DES hooks
        self.sim = sim
        self.service = service or ServiceTimes()
        self.cpu = Resource(servers=1)
        self.store_resource = store_resource
        self.appends = 0
        self.replays = 0
        self.reads = 0
        # cold-tier hook (DESIGN.md §14): set by the system layer when the
        # store is tiered; scan-shaped reads that touched cold objects are
        # reported so the TierManager can promote them back to hot
        self.tiering = None
        # fault plane + fleet hooks (DESIGN.md §15): `faults` is consulted at
        # the crash windows (between an object PUT and its proposal);
        # `fleet` is the owning BoltSystem, so receipts can route their
        # flush through the retry/failover layer. `_orphan_puts` notes keys
        # this broker PUT but may never have proposed — the §13 reaper's
        # resync() sweeps the ones consensus indeed never saw.
        self.faults = None
        self.fleet = None
        self._orphan_puts: set = set()
        # §18: overlap the segment PUT with the metadata propose in the DES
        # ack model (execution order stays PUT-then-propose — never sequence
        # an object that has not landed; ack = both landed). Set by the
        # system layer; sequential booking is the pre-§18 model.
        self.pipelined_io = False
        # §18: stage-epoch guard for clock-driven deadline flushes — a
        # registered deadline only fires against the batch it was armed for
        self._stage_epoch = 0

    # -- data path ----------------------------------------------------------------
    def append(self, log_id: int, records: Sequence[bytes],
               arrival: Optional[float] = None) -> Tuple[Optional[List[int]], float]:
        """Returns (positions-or-None, completion_time). positions is None when
        an active promotable cFork hides them (§4.1)."""
        positions, done, _segment = self._append_now(log_id, records, arrival)
        return positions, done

    def _append_now(self, log_id: int, records: Sequence[bytes],
                    arrival: Optional[float]):
        """One PUT + one metadata proposal; also returns the durable segment
        reference so receipts can support zero-copy replay (DESIGN.md §12)."""
        object_id = f"obj-{self.broker_id}-{next(_obj_counter)}"
        payload = b"".join(records)
        offsets, lengths, off = [], [], 0
        for r in records:
            offsets.append(off)
            lengths.append(len(r))
            off += len(r)
        segment = (object_id, tuple(offsets), tuple(lengths))
        try:
            self.store.put(object_id, payload)
        except Unavailable:
            # a torn PUT may have landed a prefix under this key; the retry
            # uses a fresh id, so note the carcass for the resync sweep
            self._orphan_puts.add(object_id)
            raise
        if self.faults is not None and self.faults.fire("broker_crash_append"):
            # crash in the PUT->proposal window: the object is durable but
            # never sequenced — an orphan. The fleet layer fails this broker
            # over; the client retries through a survivor (fresh object id).
            self._orphan_puts.add(object_id)
            raise BrokerCrashed(
                f"broker {self.broker_id} crashed after PUT {object_id}, "
                "before its proposal (injected)", broker_id=self.broker_id)
        try:
            positions = self.metadata.propose(("append", log_id) + segment)
        except Unavailable:
            # proposal outcome unknown/failed with the PUT already durable:
            # if consensus never saw the object, resync reclaims it
            self._orphan_puts.add(object_id)
            raise
        self.appends += 1
        done = self._book(arrival, write_bytes=len(payload))
        return positions, done, segment

    def submit(self, log_id: int, records: Sequence[bytes],
               arrival: Optional[float] = None) -> PendingAppend:
        """The ONE staging-aware append entry point (DESIGN.md §12): stages
        under group commit, appends immediately otherwise — either way the
        caller gets a :class:`PendingAppend` (already resolved on the
        immediate path). Deterministic errors on the immediate path raise
        here, at the call site, exactly as the pre-§12 ``append`` did."""
        if self.group_commit is not None:
            return self.stage(log_id, records, arrival)
        positions, done, segment = self._append_now(log_id, records, arrival)
        pending = PendingAppend(self, log_id, len(records))
        pending.segment = segment
        pending._resolve(positions, done)
        return pending

    def replay(self, log_id: int, object_id: str, offsets: Sequence[int],
               lengths: Sequence[int],
               arrival: Optional[float] = None) -> PendingAppend:
        """Zero-copy re-append (DESIGN.md §12): sequence records that are
        ALREADY durable in shared storage — a rebase replays a speculative
        suffix as one metadata proposal per original append, with no object
        PUT and no payload bytes touched. Bypasses the group-commit staging
        deliberately: there is no payload to stage, and replay happens on a
        commit path that needs the positions sequenced now."""
        segment = (object_id, tuple(offsets), tuple(lengths))
        positions = self.metadata.propose(("append", log_id) + segment)
        self.appends += 1
        self.replays += 1
        done = self._book(arrival)
        pending = PendingAppend(self, log_id, len(segment[1]))
        pending.segment = segment
        pending._resolve(positions, done)
        return pending

    # -- group-commit staging (DESIGN.md §9) ---------------------------------------
    def stage(self, log_id: int, records: Sequence[bytes],
              arrival: Optional[float] = None) -> PendingAppend:
        """Stage an append into the group-commit buffer; returns a
        :class:`PendingAppend` acked at flush commit. Requires ``group_commit``."""
        cfg = self.group_commit
        assert cfg is not None, "stage() requires a group_commit config"
        if (arrival is not None and self._staged
                and self._staged_first_arrival is not None
                and arrival - self._staged_first_arrival >= cfg.max_delay):
            # DES-time deadline: the old batch must not wait for this record
            self._auto_flush(arrival)
        fleet = self.fleet
        if fleet is not None and self.broker_id in fleet._dead:
            # THIS broker died during the deadline flush (§15): its staging
            # already failed over — stage the new record on a survivor so it
            # rides live flush paths, not a dead broker's buffer
            return fleet.live_broker(self).stage(log_id, records, arrival)
        pending = PendingAppend(self, log_id, len(records))
        self._staged.append((pending, list(records)))
        self._staged_bytes += sum(len(r) for r in records)
        self._staged_records += len(records)
        if arrival is not None and self._staged_first_arrival is None:
            self._staged_first_arrival = arrival
            self._arm_deadline(arrival)
        self.appends += 1
        if (self._staged_records >= cfg.max_records
                or self._staged_bytes >= cfg.max_bytes):
            self._auto_flush(arrival)
        return pending

    def _arm_deadline(self, first_arrival: float) -> None:
        """Register a clock-driven ``max_delay`` flush (§9 bugfix). The seed
        deadline check lived inside ``stage()``, so it only fired when the
        NEXT record arrived — an idle staged batch could sit past its
        deadline indefinitely. With a fault plane attached, its DES-time
        callback queue fires the flush from ``advance()`` instead; the
        stage-epoch guard makes a callback for an already-flushed (or
        failed-over) batch a no-op."""
        plane = self.faults
        cfg = self.group_commit
        if plane is None or cfg is None:
            return
        epoch = self._stage_epoch
        deadline = first_arrival + cfg.max_delay
        plane.call_at(deadline, lambda: self._deadline_flush(epoch, deadline))

    def _deadline_flush(self, epoch: int, deadline: float) -> None:
        if epoch != self._stage_epoch or not self._staged:
            return
        self._auto_flush(deadline)

    def _auto_flush(self, arrival: Optional[float]) -> None:
        """A threshold/deadline flush from inside ``stage()``. The record is
        already safely staged EXACTLY ONCE by this point (or about to be),
        so a transient flush failure must NOT propagate out of ``submit`` —
        the caller's retry layer would re-submit and commit the record
        twice. With a fleet retry layer attached, transients retry here
        (broker failover included); an exhausted budget leaves the batch
        staged — possibly on a survivor — and the error surfaces at
        ``wait()``/``flush()``, where retrying is duplicate-safe. Without a
        plane, failures propagate exactly as pre-§15."""
        fleet = self.fleet
        if (fleet is None or fleet.faults is None
                or not fleet.faults.enabled):
            self.flush(arrival=arrival)
            return
        try:
            fleet._retrying(
                lambda _a: fleet.live_broker(self).flush(arrival=arrival))
        except Unavailable:
            pass   # batch still staged (here or failed-over); ack deferred

    def flush(self, arrival: Optional[float] = None) -> float:
        """Commit the staging buffer: ONE segment-object PUT + ONE batched
        metadata proposal for all staged logs, then ack every PendingAppend."""
        if not self._staged:
            return arrival if arrival is not None else 0.0
        staged, self._staged = self._staged, []
        self._staged_bytes = 0
        self._staged_records = 0
        self._staged_first_arrival = None
        self._stage_epoch += 1
        writer = SegmentWriter()
        slices = []   # (pending, entry_index, start slot within the entry)
        for pending, records in staged:
            entry_index, start = writer.add(pending.log_id, records)
            slices.append((pending, entry_index, start))
        payload, entries = writer.finish()
        object_id = f"seg-{self.broker_id}-{next(_obj_counter)}"
        try:
            try:
                self.store.put(object_id, payload)
            except Unavailable:
                self._orphan_puts.add(object_id)   # torn prefix, maybe
                raise
            if (self.faults is not None
                    and self.faults.fire("broker_crash_flush")):
                # crash between the segment PUT and the batched proposal
                # (DESIGN.md §15): the segment is an orphan, and the staged
                # records were never acked — put them BACK so the fleet
                # layer's failover re-routes them to a surviving broker
                # (fresh segment, fresh PUT) and the receipts still resolve.
                self._orphan_puts.add(object_id)
                self._restage(staged)
                raise BrokerCrashed(
                    f"broker {self.broker_id} crashed after segment PUT "
                    f"{object_id}, before its proposal (injected)",
                    broker_id=self.broker_id)
            try:
                outcomes = self.metadata.propose(
                    ("append_batch_multi",
                     tuple((lid, object_id, offs, lens)
                           for lid, offs, lens in entries)))
            except Unavailable:
                self._orphan_puts.add(object_id)
                raise
        except Unavailable as e:
            if self.faults is not None and self.faults.enabled:
                # transient under an active fault plane: nothing was acked
                # and nothing failed permanently — re-stage so the retry
                # layer (or a broker failover) can commit the batch on the
                # next attempt with a fresh segment id
                if not isinstance(e, BrokerCrashed):
                    self._restage(staged)
                raise
            # no retry layer attached: surface the loss exactly as pre-§15 —
            # every pending FAILS (None would masquerade as §4.1 "withheld")
            for pending, _entry_index, _start in slices:
                pending._fail(AgileLogError(f"group-commit flush failed: {e}"), 0.0)
            raise
        except Exception as e:
            # a failed flush (store error, lost metadata quorum) must not
            # strand the batch: nothing was acked, so every pending FAILS —
            # result() returning None here would masquerade as the §4.1
            # "committed, positions withheld" success case
            for pending, _entry_index, _start in slices:
                pending._fail(AgileLogError(f"group-commit flush failed: {e}"), 0.0)
            raise
        self.flushes += 1
        done = self._book(arrival, write_bytes=len(payload))
        for pending, entry_index, start in slices:
            _lid, e_offs, e_lens = entries[entry_index]
            pending.segment = (object_id,
                               tuple(e_offs[start:start + pending.n]),
                               tuple(e_lens[start:start + pending.n]))
            outcome = outcomes[entry_index]
            if outcome[0] == "ok":
                pending._resolve(outcome[1][start:start + pending.n], done)
            elif outcome[0] == "hidden":
                pending._resolve(None, done)
            else:
                _, exc_name, msg = outcome
                exc_cls = getattr(_errors, exc_name, AgileLogError)
                pending._fail(exc_cls(msg), done)
        return done

    def _restage(self, staged) -> None:
        """Put a popped staging batch back (front of the buffer, original
        order) after a transient flush failure: nothing was acked, so the
        records are still pending — the next flush attempt recommits them."""
        self._staged = list(staged) + self._staged
        self._staged_bytes += sum(len(r) for _p, recs in staged for r in recs)
        self._staged_records += sum(len(recs) for _p, recs in staged)

    def take_staging(self):
        """Broker failover (DESIGN.md §15): surrender the staging buffer to
        the fleet layer so a surviving broker can adopt it. The pendings stay
        unresolved — they will be acked by the adopter's flush."""
        staged, self._staged = self._staged, []
        self._staged_bytes = 0
        self._staged_records = 0
        self._staged_first_arrival = None
        self._stage_epoch += 1
        return staged

    def adopt_staging(self, staged) -> None:
        """Adopt staged records from a crashed peer: re-point each pending at
        this broker (receipts route their flush here) and append the batch to
        the local buffer. The peer's PUT (if any) is orphaned garbage — the
        adopter re-PUTs everything under a fresh segment id at flush."""
        for pending, _records in staged:
            pending.broker = self
        self._staged.extend(staged)
        self._staged_bytes += sum(len(r) for _p, recs in staged for r in recs)
        self._staged_records += sum(len(recs) for _p, recs in staged)

    def take_orphans(self) -> set:
        """Hand the noted orphan PUT keys (torn/unproposed segments) to the
        caller — the §13 reaper resync path — and forget them locally."""
        orphans, self._orphan_puts = self._orphan_puts, set()
        return orphans

    def discard_staging(self) -> None:
        """Broker failure: staged records were never acked, so they are LOST,
        not committed — each PendingAppend fails instead of resolving."""
        staged, self._staged = self._staged, []
        self._staged_bytes = 0
        self._staged_records = 0
        self._staged_first_arrival = None
        self._stage_epoch += 1
        for pending, _records in staged:
            pending._fail(AgileLogError(
                f"broker {self.broker_id} failed before flush; append not committed"),
                0.0)

    def _flush_if_staged(self, log_id: int) -> None:
        """Read-your-writes: reads of a log with staged records flush first."""
        if self._staged and any(p.log_id == log_id for p, _ in self._staged):
            self.flush()

    def _cached_read(self, spans, arrival: Optional[float],
                     meta_cached: bool = False,
                     lease_read: bool = False) -> Tuple[List[bytes], float]:
        """Scatter-gather the spans through the page cache; book broker CPU on
        the bytes *returned* but store GETs only on what was actually
        *fetched* (ranged GETs, not whole-object fills — DESIGN.md §10)."""
        g0, b0 = self.cache.ranged_gets, self.cache.bytes_fetched
        cg0 = getattr(self.store, "cold_gets", 0)
        cb0 = getattr(self.store, "cold_bytes_read", 0)
        blobs = self.cache.get_spans(spans)
        self.reads += 1
        done = self._book(arrival,
                          read_bytes=sum(len(b) for b in blobs),
                          fetch_bytes=self.cache.bytes_fetched - b0,
                          get_ops=self.cache.ranged_gets - g0,
                          meta_cached=meta_cached,
                          cold_get_ops=getattr(self.store, "cold_gets", 0) - cg0,
                          cold_fetch_bytes=getattr(self.store, "cold_bytes_read", 0) - cb0,
                          lease_read=lease_read)
        return blobs, done

    def _resolve_spans(self, log_id: int, lo: int, hi: int,
                       per_record: bool) -> Tuple[List, bool, bool]:
        """Metadata resolution plus whether the flattened-view fast path
        served it (§11) and whether the lease fast path skipped consensus
        (§18) — the DES model books a cheaper metadata op for each."""
        meta = self.metadata
        l0 = getattr(meta, "lease_reads", 0)
        reader = getattr(meta, "read_state", None)
        state = reader() if reader is not None else meta.state
        lease_read = getattr(meta, "lease_reads", 0) > l0
        c0 = state.stats.cached_reads
        if per_record:
            spans = state.read_record_spans(log_id, lo, hi)
        else:
            spans = state.read_spans(log_id, lo, hi)
        return spans, state.stats.cached_reads > c0, lease_read

    def read(self, log_id: int, lo: int, hi: int,
             arrival: Optional[float] = None) -> Tuple[List[bytes], float]:
        self._flush_if_staged(log_id)
        spans, meta_cached, lease = self._resolve_spans(log_id, lo, hi,
                                                        per_record=False)
        out = self._cached_read(spans, arrival, meta_cached, lease)
        self._note_cold_scan(spans, hi - lo, arrival)
        return out

    def read_records(self, log_id: int, lo: int, hi: int,
                     arrival: Optional[float] = None) -> Tuple[List[bytes], float]:
        """Read and return individual records (one span per record)."""
        self._flush_if_staged(log_id)
        spans, meta_cached, lease = self._resolve_spans(log_id, lo, hi,
                                                        per_record=True)
        out = self._cached_read(spans, arrival, meta_cached, lease)
        self._note_cold_scan(spans, hi - lo, arrival)
        return out

    def _note_cold_scan(self, spans, n_records: int,
                        arrival: Optional[float]) -> None:
        """Readahead-aware promotion trigger (DESIGN.md §14): the read was
        already served (byte-correct through whichever tier held the data);
        if it was scan-shaped and touched cold objects, tell the tier
        manager so the NEXT reads come from the hot class."""
        tiers = self.tiering
        if tiers is None:
            return
        is_cold = getattr(self.store, "is_cold", None)
        if is_cold is None:
            return
        cold = {key for key, _off, _ln in spans if is_cold(key)}
        if cold:
            tiers.note_scan(cold, n_records, arrival)

    # -- DES accounting -----------------------------------------------------------
    def _store_rates(self):
        """Resolve the store cost model (§18): a backend carrying a
        ``StoreProfile`` books its own rates; ``None`` (memory/tiered) means
        the global ``ServiceTimes`` store rates — the pre-§18 model,
        byte-identical for every existing benchmark."""
        prof = getattr(self.store, "profile", None)
        s = self.service
        if prof is None:
            return (s.store_put_base, s.store_put_per_kb,
                    s.store_get_base, s.store_get_per_kb,
                    s.store_delete_base, 0)
        return (prof.put_base, prof.put_per_kb, prof.get_base,
                prof.get_per_kb, prof.delete_base, prof.min_get_bytes)

    def _book(self, arrival: Optional[float], write_bytes: int = 0,
              read_bytes: int = 0, fetch_bytes: Optional[int] = None,
              get_ops: Optional[int] = None,
              meta_cached: bool = False,
              cold_get_ops: int = 0, cold_fetch_bytes: int = 0,
              lease_read: bool = False) -> float:
        """`read_bytes` is what the client receives (broker CPU touches it);
        `fetch_bytes`/`get_ops` are the actual store traffic — cache hits cost
        no store time, and one coalesced ranged GET costs one `store_get_base`,
        however many spans it served. They default to the pre-cache model
        (every read is one whole GET) when not supplied. `meta_cached` books
        the flattened-view lookup cost instead of the chain-walk one (§11);
        `lease_read` books the consensus-free lease-local read (§18), which
        beats both. `cold_get_ops`/`cold_fetch_bytes` split out the GETs the
        cold store class served — those are charged at the archive rates
        (§14). Store rates come from the backend's profile when it has one;
        ``min_get_bytes`` bills every hot ranged GET at least its floor.
        With ``pipelined_io``, a write's metadata propose overlaps the PUT:
        the ack waits for max(PUT completion, propose round) instead of
        their sum (§18 — execution order is still PUT-then-propose)."""
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        put_base, put_per_kb, get_base, get_per_kb, _del_base, min_get = \
            self._store_rates()
        t = arrival
        cpu_time = s.broker_cpu_per_req + s.broker_cpu_per_kb * (write_bytes + read_bytes) / 1024
        t = self.cpu.submit(t, cpu_time)
        if fetch_bytes is None:
            fetch_bytes = read_bytes
        if get_ops is None:
            get_ops = 1 if fetch_bytes else 0
        hot_ops = max(0, get_ops - cold_get_ops)
        hot_bytes = max(0, fetch_bytes - cold_fetch_bytes)
        meta_time = (s.metadata_op_lease if lease_read
                     else s.metadata_op_cached if meta_cached
                     else s.metadata_op)
        if self.store_resource is not None:
            if write_bytes:
                put_done = self.store_resource.submit(
                    t, put_base + put_per_kb * write_bytes / 1024)
                if self.pipelined_io:
                    t = max(put_done, t + meta_time)
                    meta_time = 0.0          # propose overlapped the PUT
                else:
                    t = put_done
            if hot_ops:
                billed = max(hot_bytes, hot_ops * min_get)
                t = self.store_resource.submit(
                    t, hot_ops * get_base + get_per_kb * billed / 1024)
            if cold_get_ops:
                t = self.store_resource.submit(
                    t, cold_get_ops * s.cold_get_base + s.cold_get_per_kb * cold_fetch_bytes / 1024)
        t += meta_time + s.net_rtt
        return t

    def book_reclaim(self, arrival: Optional[float], n_deletes: int) -> float:
        """Book one GC reap quantum on THIS broker (DESIGN.md §13): the `gc`
        sequencing round, per-DELETE request handling on this broker's CPU
        (each object is its own store call), and the DELETEs on the store
        pool. The reaper runs on its own broker precisely so a backlog drain
        is a CPU burst the latency-critical workload never queues behind —
        the isolation benchmark places it both ways to show the difference."""
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        _pb, _pk, _gb, _gk, delete_base, _mg = self._store_rates()
        t = self.cpu.submit(arrival, s.broker_cpu_per_req * max(1, n_deletes))
        if self.store_resource is not None and n_deletes:
            t = self.store_resource.submit(t, n_deletes * delete_base)
        t += s.metadata_op + s.net_rtt
        return t

    def book_compact(self, arrival: Optional[float], read_bytes: int,
                     write_bytes: int, n_gets: int) -> float:
        """Book one compaction quantum on THIS broker (DESIGN.md §14): the
        ranged reads of the live spans, the compacted-object PUT, and the
        ``compact`` sequencing round. Like the GC reaper, the compactor runs
        on its own broker so rewrite I/O never queues in front of the
        latency-critical workload."""
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        put_base, put_per_kb, get_base, get_per_kb, _db, min_get = \
            self._store_rates()
        cpu_time = s.broker_cpu_per_req + s.broker_cpu_per_kb * (read_bytes + write_bytes) / 1024
        t = self.cpu.submit(arrival, cpu_time)
        if self.store_resource is not None:
            if n_gets:
                billed = max(read_bytes, n_gets * min_get)
                t = self.store_resource.submit(
                    t, n_gets * get_base + get_per_kb * billed / 1024)
            if write_bytes:
                t = self.store_resource.submit(
                    t, put_base + put_per_kb * write_bytes / 1024)
        t += s.metadata_op + s.net_rtt
        return t

    def book_tier(self, arrival: Optional[float], cold_put_bytes: int = 0,
                  cold_get_bytes: int = 0, n_objects: int = 1) -> float:
        """Book tier moves (§14): demotions PUT into the cold class at the
        archive rates; rehydrations GET out of it."""
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        t = self.cpu.submit(arrival, s.broker_cpu_per_req * max(1, n_objects))
        if self.store_resource is not None:
            if cold_put_bytes:
                t = self.store_resource.submit(
                    t, n_objects * s.cold_put_base + s.cold_put_per_kb * cold_put_bytes / 1024)
            if cold_get_bytes:
                t = self.store_resource.submit(
                    t, n_objects * s.cold_get_base + s.cold_get_per_kb * cold_get_bytes / 1024)
        t += s.metadata_op + s.net_rtt
        return t


class KafkaLikeBroker(Broker):
    """Stateful shared-broker baseline (§6.2): all workloads hit the same broker
    and its local disk, so agentic bulk reads contend with the lc-workload. The
    'disk' is a single DES resource attached to this broker."""

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.disk = Resource(servers=1)

    def _book(self, arrival: Optional[float], write_bytes: int = 0,
              read_bytes: int = 0, fetch_bytes: Optional[int] = None,
              get_ops: Optional[int] = None,
              meta_cached: bool = False,
              cold_get_ops: int = 0, cold_fetch_bytes: int = 0,
              lease_read: bool = False) -> float:
        # Every read is served from this broker's local disk: the page cache's
        # fetch accounting (fetch_bytes/get_ops) must NOT exempt the baseline
        # — a free RAM cache here would understate the very read contention
        # this baseline exists to measure (§6.2); likewise the metadata op is
        # charged at the uncached rate (the baseline has no §11 fast path),
        # so bytes returned are charged to the disk unconditionally, as in
        # the seed model.
        if self.sim is None or arrival is None:
            return 0.0
        s = self.service
        t = arrival
        cpu_time = s.broker_cpu_per_req + s.broker_cpu_per_kb * (write_bytes + read_bytes) / 1024
        t = self.cpu.submit(t, cpu_time)
        nbytes = write_bytes + read_bytes
        if nbytes:
            t = self.disk.submit(t, s.disk_seek + s.disk_read_per_kb * nbytes / 1024)
        t += s.metadata_op + s.net_rtt
        return t
