"""Bolt metadata layer: the SMR state machine (§5.3-5.6).

This is the deterministic state machine replicated by the Raft-like layer
(:mod:`repro.core.raft`). It owns, per log: the HLI index, the HLI parent
pointer, and membership in the Lazy Tail Tree. Commands (appends, forks,
promote, squash) are applied in consensus order — which is exactly what makes
cFork interleaving *linearizable*: the sequencing order of the single
metadata log is the order every fork observes.

Variant knobs reproduce the paper's ablations:

* ``cf_mode``:   'ltt'   — Bolt   (tail-only updates, lazy via LazyTailTree)
                 'eager' — Bolt-ET (tail-only updates, eager per-descendant)
                 'naive' — BoltNaiveCF (copy index entries into every
                           descendant on each parent append)
* ``fork_mode``: 'zerocopy' — Bolt (HLI; child index starts empty)
                 'metacopy' — BoltMetaCpy (materialize parent's view into the
                              child index at fork time)
* ``promote_mode``: 'copy'   — paper-faithful §5.6 (copy post-fp entries)
                    'splice' — beyond-paper O(1) identity-splice (parent adopts
                               the child's index; old parent index is frozen as
                               an internal HLI ancestor)

Blocking semantics for promotable cForks (§4.1/§5.6) are enforced with a
lazily range-added integer *blocked* counter: while log ``P`` has >=1 active
promotable cFork, +1 is applied over ``subtree(P)`` and -1 over each promotable
child's subtree, so: the parent may still append (positions withheld), the
promotable children operate freely (they must read beyond the fork point to
validate), and every other descendant's appends/deep reads are blocked.
"""

from __future__ import annotations

import bisect
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from . import errors as _errors
from .errors import AgileLogError, ForkBlocked, InvalidOperation, UnknownLog
from .index import NaiveIndex, RunIndex, Span
from .ltt import EagerTailMap, LazyTailTree


def _merge_byte_spans(spans: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Union of (offset, length) byte spans: sorted, overlapping/adjacent
    spans merged. Zero-length spans (empty records) survive as degenerate
    points unless covered, so the compaction mapping can still translate
    their offsets."""
    if not spans:
        return []
    spans = sorted(spans)
    out: List[List[int]] = [list(spans[0])]
    for off, ln in spans[1:]:
        last = out[-1]
        if off <= last[0] + last[1]:
            last[1] = max(last[1], off + ln - last[0])
        else:
            out.append([off, ln])
    return [(o, ln) for o, ln in out]


def _translate_offset(ranges: Tuple, starts: List[int],
                      off: int, ln: int) -> Optional[int]:
    """Map one source byte span into compacted-object coordinates via the
    ``compact`` command's ``(src_off, length, dst_off)`` ranges (sorted by
    ``src_off``; ``starts`` is the precomputed sort key list). Returns None
    if the span is not fully inside one mapped range — the staleness signal."""
    j = bisect.bisect_right(starts, off) - 1
    if j < 0:
        return None
    s, length, d = ranges[j]
    if off + ln > s + length:
        return None
    return d + (off - s)


@dataclass
class LogMeta:
    log_id: int
    name: str
    kind: str                    # 'root' | 'cfork' | 'sfork' | 'frozen'
    parent: Optional[int]        # HLI parent (metadata-lookup chain)
    fork_point: int = 0          # parent position at fork (tail at creation)
    promotable: bool = False
    index: object = None         # RunIndex | NaiveIndex
    hli_children: Set[int] = field(default_factory=set)
    promotable_forks: Dict[int, int] = field(default_factory=dict)  # child -> fp
    ltt_parent: Optional[int] = None   # inheritance-tree parent (None = tree root)
    broker: Optional[int] = None       # broker assignment (set by the system layer)
    stands_for: Optional[int] = None   # frozen splice stand-in: carries the
                                       # original log's promotable-edge exemption

    @property
    def alive(self) -> bool:
        return self.kind != "frozen"


@dataclass(frozen=True)
class ForkInfo:
    """Fork-point diagnostics for one log (DESIGN.md §12).

    The client session layer uses this to decide whether a speculation's
    parent advanced (``advanced > 0``) and to stamp :class:`ConflictError`
    diagnostics. ``holds_epoch`` is the metadata layer's ``holds_version``
    at query time — the same epoch counter that memoizes §11 visibility
    caps — so two observations with equal epochs saw identical hold state.
    """

    log_id: int
    kind: str                    # 'root' | 'cfork' | 'sfork'
    parent: Optional[int]        # promote target (LTT parent for cForks)
    fork_point: int
    promotable: bool
    tail: int
    parent_tail: Optional[int]   # None for roots / severed-from-dead parents
    advanced: int                # parent records sequenced past the fork point
    holds_epoch: int


class _FlatView:
    """Memoized flattened resolution of one log's view (DESIGN.md §10-§11).

    ``entries`` is a position-contiguous list of ``(c_lo, c_hi, run, rel0)``:
    positions ``[c_lo, c_hi)`` of the viewing log resolve into ancestor index
    run ``run`` at run-relative record ``rel0 + (pos - c_lo)``. A lookup is a
    bisect over ``los`` plus a numpy slice of the run — O(log runs), never a
    chain walk. Views build lazily (extended to the highest position read so
    far, ``hi``).

    ``lineage`` is the view's resolution footprint: the set of log ids on the
    owner's HLI parent chain at build time (owner included). It drives the
    two §11 mechanisms: *scoped invalidation* (promote/squash drop only views
    whose lineage intersects the restructured logs, via the reverse map
    ``MetadataState._view_deps``) and *hold awareness* (a view is servable
    under active promotable cForks up to ``cap``, the visibility prefix
    derived from the holds on its lineage; ``cap_key`` memoizes which
    holds-epoch the cap was computed for)."""

    __slots__ = ("version", "hi", "los", "entries", "lineage", "cap", "cap_key")

    def __init__(self, version: int, lineage: frozenset) -> None:
        self.version = version
        self.hi = 0
        self.los: List[int] = []
        self.entries: List[Tuple[int, int, object, int]] = []
        self.lineage = lineage
        self.cap: Optional[int] = None   # meaningful only when cap_key is current
        self.cap_key = -1


@dataclass
class ViewStats:
    """Read-path counters (DESIGN.md §11): how often the metadata fast path
    engages, and what holds / restructures cost it. Brokers snapshot these
    around each resolution to book cached vs uncached metadata service time
    in the DES model."""

    hits: int = 0           # reads served from a flattened view, no hold on lineage
    capped_hits: int = 0    # served from a view while a lineage hold was active
    slow_reads: int = 0     # exact blocking-aware resolver (cache off or hold fallback)
    builds: int = 0         # views built
    extends: int = 0        # lazy view extensions
    cap_computes: int = 0   # visibility-cap computations (then memoized per epoch)
    invalidated: int = 0    # views dropped by scoped invalidation
    full_clears: int = 0    # wholesale clears (belt-and-braces fallback)

    @property
    def cached_reads(self) -> int:
        return self.hits + self.capped_hits


class MetadataState:
    """Deterministic state machine. `apply(cmd)` for writes, plain methods for reads."""

    def __init__(self, cf_mode: str = "ltt", fork_mode: str = "zerocopy",
                 promote_mode: str = "copy", view_cache: bool = True) -> None:
        assert cf_mode in ("ltt", "eager", "naive")
        assert fork_mode in ("zerocopy", "metacopy")
        assert promote_mode in ("copy", "splice")
        self.cf_mode = cf_mode
        self.fork_mode = fork_mode
        self.promote_mode = promote_mode
        self.logs: Dict[int, LogMeta] = {}
        self._next_id = 0
        if cf_mode == "ltt":
            self.tails = LazyTailTree(seed=0xB017)
        else:
            self.tails = EagerTailMap()
        # naive/metacopy variants use per-record NaiveIndex
        self._use_naive_index = cf_mode == "naive" or fork_mode == "metacopy"
        # -- read path (DESIGN.md §10-§11) ---------------------------------
        # Flattened-view cache: per-log memoized (ancestor run, shift) tables.
        # Engagement is lineage-scoped (§11): a view serves reads whenever no
        # active promotable cFork lies on ITS OWN resolution lineage; under a
        # lineage hold it still serves the visibility prefix (up to `cap`),
        # with only beyond-cap reads delegated to the exact blocking-aware
        # resolver. Holds elsewhere in the forest never disengage it.
        self.view_cache = view_cache and not self._use_naive_index
        self.structure_version = 0
        self.holds_version = 0            # bumped whenever hold state changes
        self._views: Dict[int, _FlatView] = {}
        self._view_deps: Dict[int, Set[int]] = {}  # log id -> view owners through it
        self._holders: Set[int] = set()   # log ids with >=1 promotable fork
        self.stats = ViewStats()
        # -- segment GC manifests (DESIGN.md §13) --------------------------
        # `object_refs[obj]` counts index entries (RunIndex runs / NaiveIndex
        # entries) referencing `obj` across EVERY log in `self.logs`, frozen
        # stand-ins included — a refcount over lineages, not ownership: group
        # commit makes one object multi-log, `Broker.replay` makes it
        # multi-lineage, and a frozen pre-promote chain keeps it pinned for
        # severed dependents. Objects whose count hits zero join
        # `_reclaimable` (in consensus death order); the `gc` SMR command
        # pops candidates, re-checks them (a replay may have re-attached
        # references), and moves the survivors to `reclaimed` — so every
        # replica, including snapshot-restored followers, converges on the
        # identical reclaimed set. All three structures are part of the
        # pickled snapshot state (NOT dropped in __getstate__).
        self.object_refs: Dict[str, int] = {}
        self._reclaimable: Deque[str] = deque()
        self.reclaimed: Set[str] = set()
        self.gc_epoch = 0            # gc commands applied
        self.reclaimed_total = 0     # objects ever reclaimed
        # -- compaction + tiering manifests (DESIGN.md §14) ----------------
        # `object_bytes[obj]` is the object's total payload size, learned at
        # append time (every append command carries the byte ranges covering
        # the whole PUT payload, single-log or group-commit) and set exactly
        # by the `compact` command for objects it writes. `object_ref_bytes`
        # is the byte-granular twin of `object_refs`: the multiset sum of
        # referenced bytes over every attached index entry. Their ratio is
        # the per-object live-byte ratio the compactor selects on. Shared
        # runs inflate the multiset (counted once per attached index), which
        # only *raises* the apparent live ratio — compaction gets less eager,
        # never unsafe. `cold_objects` is the replicated record of which
        # objects the `demote_cold` command moved to the cold store class;
        # `object_birth` (op-seq at first sight) drives age-based demotion.
        # All of these replicate + snapshot exactly like the §13 manifests.
        self.object_bytes: Dict[str, int] = {}
        self.object_ref_bytes: Dict[str, int] = {}
        self.cold_objects: Set[str] = set()
        self.object_birth: Dict[str, int] = {}
        self.op_seq = 0              # SMR commands applied (age clock)
        self.compact_epoch = 0       # compact commands applied (incl. stale)
        self.compacted_total = 0     # source objects retired by compaction
        # -- idempotency dedup table (DESIGN.md §15) -----------------------
        # `idem_results[token]` caches the outcome — ("ok", result) or
        # ("err", exc_type_name, message) — of the first application of an
        # `idem`-wrapped command. A client retrying an ambiguous
        # (committed-but-unacked) propose re-submits the SAME token; the
        # replay returns the cached outcome instead of applying twice.
        # Insertion order is consensus order on every replica, so the FIFO
        # bound (`idem_cap`) evicts identically everywhere; the table is
        # part of the pickled snapshot state and the convergence digest.
        self.idem_results: Dict[str, Tuple] = {}
        self.idem_cap = 1024
        self.idem_hits = 0           # retried proposals served from the table
        self.idem_evictions = 0

    def __getstate__(self) -> dict:
        # Raft snapshots pickle the whole state machine; the view cache and
        # its reverse-dependency map are derived data (and may be large), so
        # they are dropped and rebuilt lazily.
        d = self.__dict__.copy()
        d["_views"] = {}
        d["_view_deps"] = {}
        d["stats"] = ViewStats()
        return d

    # ------------------------------------------------------------------ utils
    def _new_index(self):
        return NaiveIndex() if self._use_naive_index else RunIndex()

    def _get(self, log_id: int, allow_frozen: bool = False) -> LogMeta:
        meta = self.logs.get(log_id)
        if meta is None or (not allow_frozen and not meta.alive):
            raise UnknownLog(f"log {log_id} does not exist")
        return meta

    def _holds(self, meta: LogMeta) -> int:
        return len(meta.promotable_forks)

    def _earliest_fp(self, meta: LogMeta) -> int:
        return min(meta.promotable_forks.values())

    def _blocked_for_ops(self, meta: LogMeta) -> bool:
        """Is this log blocked by an *ancestor's* promotable fork?"""
        _tail, blocked = self.tails.get(meta.log_id)
        own = 1 if self._holds(meta) else 0
        return blocked - own > 0

    def _sync_holder(self, meta: LogMeta) -> None:
        """Keep the active-holders set consistent after a promotable_forks
        mutation; bumping ``holds_version`` expires every memoized view cap."""
        if meta.promotable_forks:
            self._holders.add(meta.log_id)
        else:
            self._holders.discard(meta.log_id)
        self.holds_version += 1

    # -- segment-GC manifests (DESIGN.md §13) -------------------------------
    def _register_object(self, object_id: str) -> None:
        """First sight of a PUT object: enters the manifests at zero
        references. NOT enqueued as a candidate here — a successful append
        bumps the count immediately, and enqueueing every object would grow
        the candidate queue with one stale entry per append; only the
        deterministic-failure path in `_apply_append` (an orphaned PUT)
        enqueues, keeping the queue proportional to *dead* objects."""
        if object_id not in self.object_refs and object_id not in self.reclaimed:
            self.object_refs[object_id] = 0
            self.object_birth[object_id] = self.op_seq

    def _ref_add(self, object_id: str, n: int = 1, nbytes: int = 0) -> None:
        self.object_refs[object_id] = self.object_refs.get(object_id, 0) + n
        if nbytes:
            self.object_ref_bytes[object_id] = \
                self.object_ref_bytes.get(object_id, 0) + nbytes

    def _ref_drop(self, object_id: str, n: int = 1, nbytes: int = 0) -> None:
        left = self.object_refs.get(object_id, 0) - n
        assert left >= 0, f"negative refcount for {object_id}"
        self.object_refs[object_id] = left
        if nbytes:
            left_b = self.object_ref_bytes.get(object_id, 0) - nbytes
            assert left_b >= 0, f"negative ref-bytes for {object_id}"
            self.object_ref_bytes[object_id] = left_b
        if left == 0:
            self._reclaimable.append(object_id)

    def _attach_index(self, index) -> None:
        """A whole index became (another) live reference holder — a frozen
        pre-promote snapshot, or a parent adopting the child's index."""
        refbytes = index.object_refbytes()
        for obj, n in index.object_refcounts().items():
            self._ref_add(obj, n, refbytes.get(obj, 0))

    def _detach_index(self, index) -> None:
        """A log left `self.logs` (or had its index replaced): every entry of
        its index releases one reference. Runs may still be *shared* with a
        surviving index object — counting is per attached index, so the
        survivor's contribution keeps the objects alive."""
        refbytes = index.object_refbytes()
        for obj, n in index.object_refcounts().items():
            self._ref_drop(obj, n, refbytes.get(obj, 0))

    def _apply_gc(self, limit: Optional[int] = None,
                  pinned: Tuple[str, ...] = ()) -> List[str]:
        """The reclamation linearization point (DESIGN.md §13): pop up to
        ``limit`` zero-reference candidates (in death order) and move them to
        the reclaimed set, returning their object ids for the broker-side
        reaper. Stale candidates — objects a replay or
        promote re-attached since they hit zero, or duplicates of an already
        reclaimed id — are discarded; ``pinned`` ids (in-flight session
        rebases holding durable segment refs outside any index) are requeued
        untouched. Runs as ONE SMR command, so the reclaimed set is identical
        on every replica and on any snapshot-restored follower."""
        pinned_set = set(pinned)
        out: List[str] = []
        requeue: List[str] = []
        scanned = 0
        budget = len(self._reclaimable)
        while self._reclaimable and scanned < budget \
                and (limit is None or len(out) < limit):
            scanned += 1
            obj = self._reclaimable.popleft()
            if obj in self.reclaimed or self.object_refs.get(obj, 0) > 0:
                continue   # stale candidate: duplicate, or live again
            if obj in pinned_set:
                requeue.append(obj)
                continue
            del self.object_refs[obj]
            self.object_ref_bytes.pop(obj, None)
            self.object_bytes.pop(obj, None)
            self.object_birth.pop(obj, None)
            self.cold_objects.discard(obj)
            self.reclaimed.add(obj)
            out.append(obj)
        self._reclaimable.extend(requeue)
        self.gc_epoch += 1
        self.reclaimed_total += len(out)
        return out

    def gc_pending(self) -> int:
        """Distinct zero-reference objects awaiting a `gc` quantum."""
        seen: Set[str] = set()
        for obj in self._reclaimable:
            if (obj not in seen and obj not in self.reclaimed
                    and self.object_refs.get(obj, 0) == 0):
                seen.add(obj)
        return len(seen)

    def gc_tracked(self) -> int:
        """Objects with at least one live index reference."""
        return sum(1 for v in self.object_refs.values() if v > 0)

    # -- compaction + tiering (DESIGN.md §14) -------------------------------
    def _apply_compact(self, new_object_id: str, new_size: int,
                       mapping: Tuple) -> Tuple:
        """The compaction linearization point: atomically swap every index
        entry (every log, frozen stand-ins included) referencing the mapped
        source objects onto ``new_object_id``, a compacted object the broker
        already PUT. ``mapping`` is ``((source_id, ranges), ...)`` with
        ``ranges = ((src_off, length, dst_off), ...)`` sorted by ``src_off``
        — explicit command arguments, so the swap is deterministic on every
        replica and under snapshot replay.

        Validation runs to completion BEFORE any mutation: if any live entry
        falls outside its source's mapped ranges (the liveness set moved
        between the broker's read and this command — e.g. a replay
        re-attached a span the compactor thought dead), the command mutates
        nothing and returns ``("stale", reason)``; the already-durable
        compacted object is enqueued as a zero-ref orphan for the §13 path.

        On success the swap rewrites each unique shared ``Run`` in place
        (object id + translated offsets), so frozen snapshots and memoized
        flattened views — both of which hold direct Run references — stay
        coherent with no invalidation, and the sources' refcounts drop to
        zero, queueing them for the reaper. Readers observe byte-identical
        content: the compacted object carries the exact live spans.
        """
        self._register_object(new_object_id)
        if new_size > self.object_bytes.get(new_object_id, 0):
            self.object_bytes[new_object_id] = new_size

        def stale(reason: str) -> Tuple:
            # mirror _apply_append's orphan path: the PUT is durable, the
            # swap is not happening — reclaim via the zero-ref candidate path
            if (self.object_refs.get(new_object_id, 0) == 0
                    and new_object_id not in self.reclaimed):
                self._reclaimable.append(new_object_id)
            self.compact_epoch += 1
            return ("stale", reason)

        if new_object_id in self.reclaimed:
            return stale(f"compacted object {new_object_id} was already reclaimed")
        if self.object_refs.get(new_object_id, 0) > 0:
            return stale(f"compacted object {new_object_id} is already referenced")
        sources: Dict[str, Tuple] = {}
        for src, ranges in mapping:
            if src == new_object_id or src in self.reclaimed:
                return stale(f"source {src} is not compactable")
            sources[src] = (ranges, [r[0] for r in ranges])
        # ---- validate + plan (no mutation yet) ----------------------------
        seen_runs: Dict[int, Tuple] = {}   # id(run) -> (run, new_offsets)
        run_refs: List[Tuple[str, int]] = []      # per (index, run) attachment
        naive_moves: List[Tuple] = []             # (index, pos, src, new_off, ln)
        for lid in sorted(self.logs):
            index = self.logs[lid].index
            if isinstance(index, NaiveIndex):
                for pos in sorted(index.entries):
                    obj, off, ln = index.entries[pos]
                    if obj not in sources:
                        continue
                    ranges, starts = sources[obj]
                    new_off = _translate_offset(ranges, starts, off, ln)
                    if new_off is None:
                        return stale(f"entry {lid}:{pos} of {obj} is outside the live map")
                    naive_moves.append((index, pos, obj, new_off, ln))
            else:
                for run in index.runs():
                    obj = run.object_id
                    if obj not in sources:
                        continue
                    if id(run) not in seen_runs:
                        ranges, starts = sources[obj]
                        new_offs = np.empty_like(run.offsets)
                        for i, (off, ln) in enumerate(zip(run.offsets.tolist(),
                                                          run.lengths.tolist())):
                            new_off = _translate_offset(ranges, starts, off, ln)
                            if new_off is None:
                                return stale(f"run at {lid}:{run.start} of {obj} "
                                             "is outside the live map")
                            new_offs[i] = new_off
                        seen_runs[id(run)] = (run, new_offs)
                    run_refs.append((obj, int(run.lengths.sum())))
        if not run_refs and not naive_moves:
            return stale("no live index entries reference the sources")
        # ---- swap (all-or-nothing from here: no failures possible) --------
        for obj, nbytes in run_refs:
            self._ref_drop(obj, 1, nbytes)
            self._ref_add(new_object_id, 1, nbytes)
        for index, pos, obj, new_off, ln in naive_moves:
            index.entries[pos] = (new_object_id, new_off, ln)
            self._ref_drop(obj, 1, ln)
            self._ref_add(new_object_id, 1, ln)
        for run, new_offs in seen_runs.values():
            run.object_id = new_object_id
            run.offsets = new_offs
        retired = sorted({obj for obj, _ in run_refs}
                         | {mv[2] for mv in naive_moves})
        self.compact_epoch += 1
        self.compacted_total += len(retired)
        return ("ok", {"object": new_object_id, "sources": tuple(retired),
                       "entries": len(run_refs) + len(naive_moves),
                       "live_bytes": self.object_ref_bytes.get(new_object_id, 0)})

    def _apply_demote_cold(self, object_ids: Tuple[str, ...]) -> List[str]:
        """Consensus-ordered demotion to the cold store class (§14): record
        which objects belong cold. Objects that died, were reclaimed, or are
        already cold are skipped deterministically; the accepted ids are
        returned so the broker-side tier manager moves exactly those."""
        done: List[str] = []
        for obj in object_ids:
            if (obj in self.reclaimed or obj in self.cold_objects
                    or self.object_refs.get(obj, 0) <= 0):
                continue
            self.cold_objects.add(obj)
            done.append(obj)
        return done

    def _apply_promote_hot(self, object_ids: Tuple[str, ...]) -> List[str]:
        """Promotion back to the hot tier (scan-triggered rehydration)."""
        done: List[str] = []
        for obj in object_ids:
            if obj in self.cold_objects:
                self.cold_objects.discard(obj)
                done.append(obj)
        return done

    def live_byte_ratio(self, object_id: str) -> float:
        """Referenced bytes / total bytes for one object (multiset-inflated
        ratios clamp at 1.0 — shared runs only make objects look MORE live)."""
        total = self.object_bytes.get(object_id, 0)
        if total <= 0:
            return 1.0
        return min(1.0, self.object_ref_bytes.get(object_id, 0) / total)

    def compaction_candidates(self, max_live_ratio: float, min_bytes: int = 1,
                              exclude: Iterable[str] = ()) -> List[str]:
        """Referenced objects whose live-byte ratio is at or below the
        threshold — the compactor's selection input. ``exclude`` carries the
        broker-side pin/session exclusions (same role as ``gc`` pins)."""
        skip = set(exclude)
        out: List[str] = []
        for obj, n in self.object_refs.items():
            if n <= 0 or obj in skip:
                continue
            total = self.object_bytes.get(obj, 0)
            if total < min_bytes:
                continue
            live = self.object_ref_bytes.get(obj, 0)
            if live < total and live / total <= max_live_ratio:
                out.append(obj)
        return out

    def demotion_candidates(self, min_age: int,
                            prefixes: Tuple[str, ...] = ("cmp-",),
                            exclude: Iterable[str] = ()) -> List[str]:
        """Referenced hot objects old enough (in SMR command ticks since
        first sight) to demote to the cold class."""
        skip = set(exclude)
        pfx = tuple(prefixes)
        out: List[str] = []
        for obj, n in self.object_refs.items():
            if n <= 0 or obj in self.cold_objects or obj in skip:
                continue
            if pfx and not obj.startswith(pfx):
                continue
            if self.op_seq - self.object_birth.get(obj, self.op_seq) >= min_age:
                out.append(obj)
        return out

    def object_live_spans(self, object_ids: Iterable[str]
                          ) -> Dict[str, List[Tuple[int, int]]]:
        """Exact per-object union of referenced byte spans over every log's
        index (frozen stand-ins included), merged and sorted — what the
        compactor ranged-reads and what the mapping ranges are built from."""
        want = set(object_ids)
        raw: Dict[str, List[Tuple[int, int]]] = {obj: [] for obj in want}
        for lid in sorted(self.logs):
            index = self.logs[lid].index
            if isinstance(index, NaiveIndex):
                for obj, off, ln in index.entries.values():
                    if obj in want:
                        raw[obj].append((off, ln))
            else:
                for run in index.runs():
                    if run.object_id in want:
                        raw[run.object_id].extend(
                            zip(run.offsets.tolist(), run.lengths.tolist()))
        return {obj: _merge_byte_spans(sp) for obj, sp in raw.items()}

    # -- invalidation (DESIGN.md §11) ---------------------------------------
    def _drop_view(self, owner: int) -> None:
        view = self._views.pop(owner, None)
        if view is None:
            return
        for lid in view.lineage:
            deps = self._view_deps.get(lid)
            if deps is not None:
                deps.discard(owner)
                if not deps:
                    del self._view_deps[lid]
        self.stats.invalidated += 1

    def _invalidate_through(self, log_ids: Iterable[int]) -> None:
        """Scoped invalidation: drop only the views whose lineage resolves
        through any of ``log_ids`` (the restructured subtree). Views on
        unrelated logs — other topics, other branches — survive, which is
        what keeps promote/squash latency independent of how many flattened
        views are live elsewhere."""
        owners: Set[int] = set()
        for lid in log_ids:
            deps = self._view_deps.get(lid)
            if deps:
                owners.update(deps)
        for owner in owners:
            self._drop_view(owner)

    def _invalidate_views(self) -> None:
        """Belt-and-braces fallback: drop EVERY flattened view and expire any
        view object still referenced elsewhere via the version bump. The §11
        scoped path (`_invalidate_through`) supersedes this on the
        promote/squash hot path; this remains for wholesale resets."""
        self.structure_version += 1
        self.holds_version += 1
        if self._views:
            self._views.clear()
        self._view_deps.clear()
        self.stats.full_clears += 1

    # --------------------------------------------------------------- commands
    def apply(self, cmd: Tuple) -> object:
        op = cmd[0]
        # the age clock ticks on every command (success or deterministic
        # failure — both apply identically on every replica)
        self.op_seq += 1
        return getattr(self, "_apply_" + op)(*cmd[1:])

    def _apply_idem(self, token: str, cmd: Tuple) -> object:
        """Exactly-once wrapper (DESIGN.md §15): apply ``cmd`` and cache its
        outcome under ``token``; a token seen before replays the cached
        outcome WITHOUT re-applying. Deterministic command errors are cached
        as values and re-raised equivalently on replay, so a retried
        ambiguous propose observes the identical result either way."""
        hit = self.idem_results.get(token)
        if hit is not None:
            self.idem_hits += 1
            if hit[0] == "err":
                exc_cls = getattr(_errors, hit[1], AgileLogError)
                raise exc_cls(hit[2])
            return hit[1]
        try:
            result = self.apply(cmd)
        except AgileLogError as e:
            self._idem_remember(token, ("err", type(e).__name__, str(e)))
            raise
        self._idem_remember(token, ("ok", result))
        return result

    def _idem_remember(self, token: str, outcome: Tuple) -> None:
        self.idem_results[token] = outcome
        while len(self.idem_results) > self.idem_cap:
            self.idem_results.pop(next(iter(self.idem_results)))
            self.idem_evictions += 1

    def _apply_noop(self) -> None:
        """Current-term barrier entry (DESIGN.md §16): a new leader proposes
        one of these to commit any lingering prior-term suffix under raft's
        commit rule (prior-term entries commit only beneath a current-term
        majority ack). State-machine-wise it only ticks the age clock — which
        ``apply`` already did."""
        return None

    def _apply_create_root(self, name: str) -> int:
        log_id = self._next_id
        self._next_id += 1
        self.logs[log_id] = LogMeta(log_id, name, "root", parent=None,
                                    index=self._new_index())
        self.tails.add_root(log_id, tail0=0, blocked0=0)
        return log_id

    def _apply_append(self, log_id: int, object_id: str,
                      offsets: Tuple[int, ...], lengths: Tuple[int, ...]) -> Optional[List[int]]:
        # register BEFORE any deterministic failure: the broker already PUT
        # the object, so a blocked/unknown-log append leaves an orphan in
        # shared storage that only the zero-ref candidate path can reclaim
        self._register_object(object_id)
        # learn the object's size (§14): every append command covers a suffix
        # of the PUT payload, so the max byte-end over all appends naming the
        # object — group-commit batches issue one per packed log — is exact
        if lengths:
            end = max(o + ln for o, ln in zip(offsets, lengths))
            if end > self.object_bytes.get(object_id, 0):
                self.object_bytes[object_id] = end
        try:
            if object_id in self.reclaimed:
                raise InvalidOperation(
                    f"object {object_id} was already reclaimed by GC; "
                    "sequencing it would index deleted storage")
            meta = self._get(log_id)
            if self._blocked_for_ops(meta):
                raise ForkBlocked(
                    f"appends to log {log_id} are blocked by an ancestor's promotable cFork")
        except Exception:
            # deterministic failure with the PUT already durable: an orphan —
            # enqueue it (still zero-ref unless a batch-mate entry succeeded)
            if (self.object_refs.get(object_id, 0) == 0
                    and object_id not in self.reclaimed):
                self._reclaimable.append(object_id)
            raise
        tail, _blk = self.tails.get(log_id)
        k = len(offsets)
        run_bytes = int(sum(lengths))
        if self._use_naive_index:
            for i in range(k):
                meta.index.add_local(tail + i, (object_id, offsets[i], lengths[i]))
            self._ref_add(object_id, k, run_bytes)
        else:
            meta.index.append_run(tail, object_id,
                                  np.asarray(offsets, dtype=np.int64),
                                  np.asarray(lengths, dtype=np.int64))
            self._ref_add(object_id, 1, run_bytes)
        if self.cf_mode == "naive":
            # BoltNaiveCF: duplicate the new entries into EVERY descendant's
            # index at that descendant's own tail (Fig. 4a), eagerly.
            for d in self.tails.subtree_ids(log_id):
                if d == log_id:
                    continue
                d_tail, _ = self.tails.get(d)
                d_index = self.logs[d].index
                for i in range(k):
                    d_index.add_copy(d_tail + i, (object_id, offsets[i], lengths[i]))
                self._ref_add(object_id, k, run_bytes)
        self.tails.range_add(log_id, d_tail=k)
        if self._holds(meta):
            return None  # §4.1: positions beyond a promotable fork point are withheld
        return list(range(tail, tail + k))

    def _apply_append_batch_multi(self, entries: Tuple) -> List[Tuple]:
        """One SMR command sequencing appends for several logs (group commit,
        DESIGN.md §9). ``entries`` is a tuple of ``(log_id, object_id,
        offsets, lengths)`` — typically all referencing one segment object.

        Entries are applied in order; each commits or fails *independently but
        deterministically* (a blocked log must not veto its batch-mates, and
        every replica reaches the identical state either way). Failures are
        therefore returned as values, not raised: the per-entry outcomes are
        ``("ok", positions)`` | ``("hidden", None)`` (positions withheld by a
        promotable cFork) | ``("error", exc_type_name, message)``.
        """
        outcomes: List[Tuple] = []
        for log_id, object_id, offsets, lengths in entries:
            try:
                positions = self._apply_append(log_id, object_id, offsets, lengths)
            except AgileLogError as e:
                outcomes.append(("error", type(e).__name__, str(e)))
            else:
                if positions is None:
                    outcomes.append(("hidden", None))
                else:
                    outcomes.append(("ok", positions))
        return outcomes

    def _check_forkable(self, meta: LogMeta) -> int:
        if self._blocked_for_ops(meta):
            raise ForkBlocked(f"log {meta.log_id} is blocked by an ancestor's promotable cFork")
        tail, _ = self.tails.get(meta.log_id)
        if self._holds(meta) and tail > self._earliest_fp(meta):
            raise ForkBlocked(
                "cannot fork beyond an active promotable cFork's fork point")
        return tail

    def _materialize_into(self, child_index: NaiveIndex, log_id: int, upto: int) -> None:
        """BoltMetaCpy: copy the parent's fully-resolved view [0, upto) into the
        child's index (this is the expensive O(n) path the paper measures)."""
        for pos in range(upto):
            span = self._lookup_one(log_id, pos)
            child_index.add_copy(pos, span)
            self._ref_add(span[0], 1, span[2])  # the copy is a live reference (§13)

    def _apply_cfork(self, parent_id: int, promotable: bool) -> int:
        parent = self._get(parent_id)
        fp = self._check_forkable(parent)
        child_id = self._next_id
        self._next_id += 1
        child = LogMeta(child_id, f"{parent.name}/cf{child_id}", "cfork",
                        parent=parent_id, fork_point=fp, promotable=promotable,
                        index=self._new_index(), ltt_parent=parent_id)
        self.logs[child_id] = child
        parent.hli_children.add(child_id)
        _t, parent_blocked = self.tails.get(parent_id)
        self.tails.add_child(parent_id, child_id, tail0=fp, blocked0=parent_blocked)
        if self.fork_mode == "metacopy":
            self._materialize_into(child.index, parent_id, fp)
        if promotable:
            if not self._holds(parent):
                self.tails.range_add(parent_id, d_blocked=+1)  # now incl. child
            self.tails.range_add(child_id, d_blocked=-1)       # child exempt
            parent.promotable_forks[child_id] = fp
            self._sync_holder(parent)
        return child_id

    def _apply_sfork(self, parent_id: int, past: Optional[int]) -> int:
        parent = self._get(parent_id)
        tail = self._check_forkable(parent)
        if past is not None:
            if not (0 <= past < tail):
                raise InvalidOperation(f"past offset {past} out of range (tail {tail})")
            fp = past + 1
        else:
            fp = tail
        child_id = self._next_id
        self._next_id += 1
        child = LogMeta(child_id, f"{parent.name}/sf{child_id}", "sfork",
                        parent=parent_id, fork_point=fp, promotable=False,
                        index=self._new_index(), ltt_parent=None)
        self.logs[child_id] = child
        parent.hli_children.add(child_id)
        # severed: its own LTT *tree* — no continuous inheritance (§5.3)
        self.tails.add_root(child_id, tail0=fp, blocked0=0)
        if self.fork_mode == "metacopy":
            self._materialize_into(child.index, parent_id, fp)
        return child_id

    # -- squash ---------------------------------------------------------------
    def _delete_or_freeze(self, removed: List[int]) -> None:
        """Delete removed logs, but *freeze* (keep index of) any removed log
        that an external log (e.g. an sFork in another tree) — or another kept
        frozen log — still depends on through the HLI chain."""
        removed_set = set(removed)
        keep: Set[int] = set()
        changed = True
        while changed:   # fixpoint: freezing a child forces its ancestors frozen
            changed = False
            for d in removed:
                if d in keep:
                    continue
                deps = self.logs[d].hli_children
                if (deps - removed_set) or (deps & keep):
                    keep.add(d)
                    changed = True
        for d in removed:
            meta = self.logs[d]
            if d in self._holders:
                self._holders.discard(d)
                self.holds_version += 1
            if d in keep:
                meta.kind = "frozen"   # index kept alive for dependents
                meta.promotable_forks.clear()
                meta.hli_children = (meta.hli_children - removed_set) | (meta.hli_children & keep)
            else:
                del self.logs[d]
                # dead-lineage event (§13): the log's index entries release
                # their segment references; zero-ref objects queue for gc
                self._detach_index(meta.index)
                if meta.parent is not None and meta.parent in self.logs:
                    self.logs[meta.parent].hli_children.discard(d)
        self._gc_frozen()

    def _gc_frozen(self) -> None:
        """Delete frozen logs whose last HLI dependent vanished (chain GC)."""
        progressed = True
        while progressed:
            progressed = False
            for lid in [k for k, v in self.logs.items()
                        if v.kind == "frozen" and not v.hli_children]:
                meta = self.logs.pop(lid)
                self._holders.discard(lid)
                self._invalidate_through((lid,))
                self._detach_index(meta.index)   # chain-GC dead-lineage event (§13)
                if meta.parent is not None and meta.parent in self.logs:
                    self.logs[meta.parent].hli_children.discard(lid)
                progressed = True

    def _apply_squash(self, log_id: int) -> List[int]:
        meta = self._get(log_id)
        if meta.kind == "root":
            raise InvalidOperation("cannot squash the root log (§4.1)")
        parent = self.logs.get(meta.ltt_parent) if meta.ltt_parent is not None else None
        was_promotable = (parent is not None and log_id in parent.promotable_forks)
        removed = self.tails.remove_subtree(log_id)
        # scoped invalidation (§11): only views resolving through the removed
        # subtree can go stale — the surviving logs' indexes, tails, and HLI
        # edges are untouched by a squash, so their views stay live (releasing
        # a hold is a visibility change, handled by the holds_version bump)
        self._invalidate_through(removed)
        if was_promotable:
            del parent.promotable_forks[log_id]
            self._sync_holder(parent)
            if not parent.promotable_forks:
                self.tails.range_add(parent.log_id, d_blocked=-1)
        self._delete_or_freeze(removed)
        return removed

    # -- promote ----------------------------------------------------------------
    def _apply_promote(self, child_id: int, mode: Optional[str] = None) -> bool:
        mode = mode or self.promote_mode
        child = self._get(child_id)
        if not child.promotable or child.kind != "cfork":
            raise InvalidOperation("only promotable cForks can be promoted (§4.1)")
        parent = self._get(child.ltt_parent)
        if self._blocked_for_ops(parent):
            # the parent is capped by an ancestor's promotable cFork; promoting
            # into it would mutate content beyond that outer fork point, which
            # the outer hold forbids until it resolves (DESIGN.md §4)
            raise ForkBlocked(
                "cannot promote into a log blocked by an ancestor's promotable cFork")
        assert child_id in parent.promotable_forks
        # scoped invalidation (§11): a promote rewrites the parent's index
        # (copy) or replaces it behind a frozen stand-in (splice), deletes the
        # child, and re-binds the child's HLI dependents — every affected view
        # resolves through `parent` or `child`, so only their dependents drop
        # (sibling squashes below invalidate their own subtrees)
        self._invalidate_through((parent.log_id, child_id))
        # 1. first promote wins: squash other promotable siblings (§4.1)
        for sib in [c for c in parent.promotable_forks if c != child_id]:
            self._apply_squash(sib)
        # 2. tails: parent's lineage absorbs the child's local appends.
        # Inheritance invariant: child_tail = parent_tail + child-lineage locals
        # (the lineage may span frozen splice stand-ins, so count via tails).
        lc = self.tails.get(child_id)[0] - self.tails.get(parent.log_id)[0]
        self.tails.range_add(parent.log_id, d_tail=+lc)
        self.tails.range_add(child_id, d_tail=-lc)
        # 3. blocking. Two cases:
        #    (a) child has its own promotable forks: they TRANSFER to the
        #        parent (the grandchild's promise now applies to the promoted
        #        lineage; child positions == new parent positions). The
        #        counters are already correct: the child's hold-bit (+1 over
        #        its subtree) and its exemption (-1 over its subtree) cancel,
        #        and the parent's bit stays because it still holds forks.
        #    (b) no transfer: reverse the child's exemption, then drop the
        #        parent's hold bit.
        del parent.promotable_forks[child_id]
        assert not parent.promotable_forks
        if child.promotable_forks:
            parent.promotable_forks.update(child.promotable_forks)
        else:
            self.tails.range_add(child_id, d_blocked=+1)
            self.tails.range_add(parent.log_id, d_blocked=-1)
        self._sync_holder(parent)
        # 4. index restructure
        if mode == "splice":
            self._promote_splice(parent, child)
        else:
            self._promote_copy(parent, child)
        # 5. child's HLI dependents re-bind to the parent (same positions)
        for dep in child.hli_children:
            self.logs[dep].parent = parent.log_id
            parent.hli_children.add(dep)
        # 6. child's LTT children re-parent to parent; child's markers vanish
        # (direct_children, not an O(subtree) tour: only the immediate
        # children carry an ltt_parent pointer at the promoted node)
        for d in self.tails.direct_children(child_id):
            if self.logs[d].ltt_parent == child_id:
                self.logs[d].ltt_parent = parent.log_id
        self.tails.remove_node_keep_children(child_id)
        if child.parent is not None and child.parent in self.logs:
            self.logs[child.parent].hli_children.discard(child_id)
        parent.hli_children.discard(child_id)
        del self.logs[child_id]
        # release the child's manifest contribution (§13). Splice mode
        # attached one extra reference when the parent adopted the child's
        # index object, so its entries stay counted exactly once; copy mode
        # re-referenced the child-lineage runs inside the parent's new index.
        self._detach_index(child.index)
        self._holders.discard(child_id)
        self._gc_frozen()
        return True

    def _apply_promote_if(self, child_id: int, expected_parent_tail: int,
                          mode: Optional[str] = None) -> Tuple:
        """Conditional promote — the linearization point of a speculative
        commit (DESIGN.md §12). Promotes ``child_id`` only if its parent's
        tail is still ``<= expected_parent_tail`` (i.e. nothing was sequenced
        into the parent past what the speculation validated); otherwise it
        mutates NOTHING and returns the conflict diagnostics as a value.

        Because this runs as one SMR command, check and promote are atomic in
        consensus order — the hand-rolled tail-check-then-promote loop cannot
        close this race (records sequenced between its two proposals are
        merged unvalidated). Outcomes, deterministic on every replica:

        * ``("ok", (base, count))`` — promoted; the speculative suffix landed
          at parent positions ``[base, base + count)``.
        * ``("conflict", {..})``    — parent advanced; diagnostics carry the
          fork point, observed/expected tails, and the holds epoch.

        Ineligible children (non-promotable, unknown — e.g. squashed by a
        sibling's winning promote) raise the usual deterministic errors.
        """
        child = self._get(child_id)
        if not child.promotable or child.kind != "cfork":
            raise InvalidOperation("only promotable cForks can be committed (§4.1)")
        parent = self._get(child.ltt_parent)
        p_tail = self.tails.get(parent.log_id)[0]
        if p_tail > expected_parent_tail:
            return ("conflict", {
                "log_id": parent.log_id, "fork_id": child_id,
                "fork_point": child.fork_point, "parent_tail": p_tail,
                "expected": expected_parent_tail,
                "advanced": p_tail - expected_parent_tail,
                "holds_epoch": self.holds_version,
            })
        count = self.tails.get(child_id)[0] - p_tail
        self._apply_promote(child_id, mode)
        return ("ok", (p_tail, count))

    def _promote_splice(self, parent: LogMeta, child: LogMeta) -> None:
        """O(1)-metadata: parent adopts child's index; the old parent index is
        frozen as an internal HLI stand-in (beyond-paper; DESIGN.md §4.2).

        Existing forks of the parent keep pointing at the (live) parent: every
        other fork's fork point is <= fp, and the parent's new index only has
        entries >= fp, so their sub-fp lookups fall through into the frozen
        stand-in transparently (local counts below fp are zero in the adopted
        index). Only the bottom of the promoted child's own frozen chain —
        which references *old-parent positions >= fp* — re-binds to F.
        """
        frozen_id = self._next_id
        self._next_id += 1
        frozen = LogMeta(frozen_id, f"{parent.name}@pre-promote", "frozen",
                         parent=parent.parent, index=parent.index,
                         stands_for=parent.log_id)
        self.logs[frozen_id] = frozen
        if parent.parent is not None:
            gp = self.logs[parent.parent]
            gp.hli_children.discard(parent.log_id)
            gp.hli_children.add(frozen_id)
        self._rebind_snapshot_deps(parent, frozen, child)
        # splice: parent continues the child's lineage. Manifests (§13): the
        # old parent index merely moves (parent -> frozen stand-in), but the
        # child's index is now held TWICE (child until its deletion below,
        # plus the parent) — attach the parent's adoption; _apply_promote
        # releases the child's own contribution when it deletes the log.
        self._attach_index(child.index)
        parent.index = child.index
        if child.parent == parent.log_id:
            parent.parent = frozen_id
            frozen.hli_children.add(parent.log_id)
        else:
            # the child had its own frozen chain; its bottom link (a frozen
            # stand-in whose parent was this log) was already re-bound to
            # `frozen` by _rebind_snapshot_deps above
            parent.parent = child.parent
            self.logs[child.parent].hli_children.discard(child.log_id)
            self.logs[child.parent].hli_children.add(parent.log_id)

    def _snapshot_movable_deps(self, parent: LogMeta, child: LogMeta) -> List[int]:
        """Dependents of `parent` that must re-bind to a frozen pre-promote
        copy: severed forks and frozen chains holding *positional* snapshots
        of the old parent content, which a promote rewrites beyond the fork
        point. A frozen splice stand-in that is the chain bottom of a live
        cFork OTHER than the promoted child must NOT move: that fork inherits
        continuously, so its future positions resolve through the live
        parent's post-promote index (its sub-fp lookups are position-stable
        either way). Only the promoted child's own chain bottom — which
        references old-parent positions >= fp, exactly the ones being
        re-sequenced — carries the old order."""
        out = []
        for d in parent.hli_children:
            dm = self.logs[d]
            if dm.kind == "sfork":
                out.append(d)
            elif dm.kind == "frozen":
                sf = dm.stands_for
                live_other = (sf is not None and sf != child.log_id
                              and sf in self.logs and self.logs[sf].alive)
                if not live_other:
                    out.append(d)
        return out

    def _rebind_snapshot_deps(self, parent: LogMeta, frozen: LogMeta,
                              child: LogMeta) -> None:
        for dep in self._snapshot_movable_deps(parent, child):
            self.logs[dep].parent = frozen.log_id
            frozen.hli_children.add(dep)
            parent.hli_children.discard(dep)

    def _collect_lineage_runs(self, child: LogMeta, stop_id: int,
                              lo: int, hi: int):
        """All index runs contributing to child positions [lo, hi) that are NOT
        derived from log `stop_id`'s own index (i.e. the child lineage's local
        records, possibly spread over a frozen splice chain), re-keyed into
        child positions. Returns [(child_start, object_id, offsets, lengths)]
        sorted by child_start."""
        out = []

        def rec(meta: LogMeta, a: int, b: int, shift: int) -> None:
            for seg in meta.index.segments(a, b):
                if seg[0] == "local":
                    _, s_lo, s_hi, run = seg
                    i, j = s_lo - run.start, s_hi - run.start
                    out.append((s_lo + shift, run.object_id,
                                run.offsets[i:j], run.lengths[i:j]))
                else:
                    _, g_lo, g_hi, lcount = seg
                    parent = self.logs[meta.parent]
                    if parent.log_id == stop_id:
                        continue  # stop-log-derived: handled by the merge
                    rec(parent, g_lo - lcount, g_hi - lcount, shift + lcount)

        rec(child, lo, hi, 0)
        out.sort(key=lambda t: t[0])
        return out

    def _promote_copy(self, parent: LogMeta, child: LogMeta) -> None:
        """Paper-faithful §5.6: copy the child's post-fp entries into the
        parent's index; the parent's own post-fp entries are re-sequenced to
        their positions in the child's (= the new) order. O(entries after fp)."""
        fp = child.fork_point
        if self._use_naive_index:
            raise InvalidOperation("promote not supported for naive-index variants")
        child_tail = self.tails.get(child.log_id)[0]
        # collect the child lineage's local runs FIRST (the walk must still see
        # the pre-rebind chain ending at this parent)
        c_runs = self._collect_lineage_runs(child, parent.log_id, fp, child_tail)
        # severed/frozen dependents keep the old positional content: freeze a
        # zero-copy snapshot of the old index for them (copy mode rewrites
        # positions beyond fp in place); live forks' chain bottoms stay on
        # the live parent (continuous inheritance, see _snapshot_movable_deps)
        snapshot_deps = self._snapshot_movable_deps(parent, child)
        if snapshot_deps:
            frozen_id = self._next_id
            self._next_id += 1
            frozen = LogMeta(frozen_id, f"{parent.name}@pre-promote", "frozen",
                             parent=parent.parent, index=parent.index.snapshot(),
                             stands_for=parent.log_id)
            self.logs[frozen_id] = frozen
            # the snapshot shares Run objects but is a second attached index:
            # its entries hold their segments for the severed dependents (§13)
            self._attach_index(frozen.index)
            if parent.parent is not None:
                self.logs[parent.parent].hli_children.add(frozen_id)
            self._rebind_snapshot_deps(parent, frozen, child)
        old_runs = parent.index.runs()
        new_index = RunIndex()
        for r in old_runs:
            if r.end <= fp:
                new_index.append_run(r.start, r.object_id, r.offsets, r.lengths)
        p_runs = [r for r in old_runs if r.start >= fp]
        ci = pi = 0
        c_cum = 0  # child-lineage records emitted so far
        while ci < len(c_runs) or pi < len(p_runs):
            c_start = c_runs[ci][0] if ci < len(c_runs) else None
            # a parent run at parent-position s lands at child-position s + c_cum
            p_start = (p_runs[pi].start + c_cum) if pi < len(p_runs) else None
            if p_start is None or (c_start is not None and c_start <= p_start):
                start, obj, offs, lens = c_runs[ci]
                new_index.append_run(start, obj, offs, lens)
                c_cum += len(offs)
                ci += 1
            else:
                r = p_runs[pi]
                new_index.append_run(p_start, r.object_id, r.offsets, r.lengths)
                pi += 1
        # manifest swap (§13): the rebuilt index re-references the surviving
        # segments (child-lineage runs included), the replaced one releases —
        # only segments that appear in NEITHER can drop toward zero here
        self._attach_index(new_index)
        self._detach_index(parent.index)
        parent.index = new_index

    # ---------------------------------------------------------------- queries
    def tail(self, log_id: int) -> int:
        self._get(log_id)
        return self.tails.get(log_id)[0]

    def visible_tail(self, log_id: int) -> int:
        """Tail capped at the earliest promotable fork point (readable range)."""
        meta = self._get(log_id)
        tail = self.tails.get(log_id)[0]
        if self._holds(meta):
            return min(tail, self._earliest_fp(meta))
        return tail

    def fork_info(self, log_id: int) -> ForkInfo:
        """Fork-point epoch exposure (DESIGN.md §12): where this log forked,
        how far its promote target has run ahead, and the holds epoch."""
        meta = self._get(log_id)
        tail = self.tails.get(log_id)[0]
        target = meta.ltt_parent if meta.kind == "cfork" else meta.parent
        p_tail: Optional[int] = None
        advanced = 0
        if target is not None:
            pm = self.logs.get(target)
            if pm is not None and pm.alive:
                p_tail = self.tails.get(target)[0]
                advanced = max(0, p_tail - meta.fork_point)
        return ForkInfo(log_id=log_id, kind=meta.kind, parent=target,
                        fork_point=meta.fork_point, promotable=meta.promotable,
                        tail=tail, parent_tail=p_tail, advanced=advanced,
                        holds_epoch=self.holds_version)

    def _lookup_one(self, log_id: int, pos: int) -> Span:
        spans = self.read_spans(log_id, pos, pos + 1, _skip_checks=True)
        assert len(spans) == 1
        return spans[0]

    def read_record_spans(self, log_id: int, lo: int, hi: int) -> List[Span]:
        """One span per record (no coalescing) — for record-oriented reads."""
        return self.read_spans(log_id, lo, hi, per_record=True)

    def read_spans(self, log_id: int, lo: int, hi: int,
                   _skip_checks: bool = False, per_record: bool = False) -> List[Span]:
        """Resolve [lo, hi) to byte spans through the HLI chain.
        Contiguous byte ranges are coalesced unless ``per_record``.

        Fast path (DESIGN.md §10-§11): positions resolve through the
        memoized flattened view — O(log runs) per lookup regardless of fork
        depth — whenever no active promotable cFork lies on THIS log's
        resolution lineage. Under a lineage hold, the view still serves the
        §4.1-visible prefix (up to the memoized visibility ``cap``); only a
        read crossing the cap is delegated to the exact blocking-aware chain
        resolver, which raises the precise error in DFS order.

        Raises ForkBlocked if the range crosses an active promotable fork point
        that the reader is not entitled to see (§4.1).
        """
        meta = self._get(log_id)
        tail = self.tails.get(log_id)[0]
        if not (0 <= lo <= hi <= tail):
            # explicit even when a view already covers `hi`: a restructure
            # that shrank this log's range must never serve stale spans
            raise InvalidOperation(f"read [{lo},{hi}) out of range (tail {tail})")
        if lo >= hi:
            return []   # empty reads never resolve, block, or extend a view
        if self.view_cache:
            view = self._views.get(log_id)
            if view is not None and view.version != self.structure_version:
                self._drop_view(log_id)   # belt-and-braces vs wholesale clears
                view = None
            if view is None:
                view = self._build_view(meta)   # one chain walk; cap memoizes on it
            # note: sfork origin exempts only *ancestor* holds (_view_cap
            # breaks its walk at the severed edge); the log's OWN promotable
            # forks still cap it, exactly like the uncached viewing-log check
            if (self._holders and not _skip_checks
                    and not self._holders.isdisjoint(view.lineage)):
                cap = self._view_cap(meta, view)
                if cap is not None and hi > cap:
                    # beyond the visible prefix: the exact resolver owns
                    # the §4.1 error (or the rare allowed corner)
                    return self._slow_spans(meta, lo, hi, _skip_checks,
                                            per_record)
                self.stats.capped_hits += 1
            else:
                self.stats.hits += 1
            if hi > view.hi:
                self._extend_view(meta, view, hi)
            return self._view_spans(view, lo, hi, per_record)
        return self._slow_spans(meta, lo, hi, _skip_checks, per_record)

    def _slow_spans(self, meta: LogMeta, lo: int, hi: int,
                    _skip_checks: bool, per_record: bool) -> List[Span]:
        """The exact blocking-aware resolution (bounds already validated)."""
        self.stats.slow_reads += 1
        if (not _skip_checks and hi > lo and self._holds(meta)
                and hi > self._earliest_fp(meta)):
            raise ForkBlocked(
                f"reads on log {meta.log_id} beyond position {self._earliest_fp(meta)} "
                "are blocked while a promotable cFork exists")
        out: List[Span] = []
        # reads originating on a severed fork reference positionally-committed
        # content (their view was fixed at fork time), so the beyond-fp block —
        # which protects *provisional* positions a promote may rewrite — does
        # not apply to them (the oracle materializes their content at creation)
        origin_snapshot = meta.kind == "sfork"
        self._resolve(meta, lo, hi, out, via_promotable=_skip_checks or origin_snapshot,
                      per_record=per_record)
        return out

    # -- flattened-view fast path (DESIGN.md §10-§11) -----------------------
    def _lineage(self, meta: LogMeta) -> frozenset:
        """The HLI parent chain of `meta` (inclusive): every log its reads can
        resolve through. Stable for a view's lifetime — appends and new forks
        never rebind existing parent pointers; promote/squash do, and they
        drop the dependent views."""
        chain = []
        m: Optional[LogMeta] = meta
        while m is not None:
            chain.append(m.log_id)
            m = self.logs.get(m.parent) if m.parent is not None else None
        return frozenset(chain)

    def _view_cap(self, meta: LogMeta, view: Optional[_FlatView]) -> Optional[int]:
        """Largest ``hi`` this log may read without tripping a §4.1 check,
        given the active holds on its lineage; ``None`` = unbounded (every
        hold on the lineage is exempt for this reader — e.g. the promotable
        child itself, which must read beyond the fork point to validate).

        O(chain) metadata hops plus O(log n) tail queries, and *exact*: while
        a hold on ancestor ``H`` (fork point ``fp``) is active, no log on a
        non-exempt inheritance path below ``H`` can append, so the forbidden
        positions of this log are precisely its top ``H.tail - fp`` ones —
        ``cap = tail - (H.tail - fp)`` — an invariant under any ancestor's
        concurrent appends (they advance both tails equally). Memoized on the
        view per holds-epoch."""
        if view is not None and view.cap_key == self.holds_version:
            return view.cap
        self.stats.cap_computes += 1
        cap: Optional[int] = None
        if self._holds(meta):
            cap = self._earliest_fp(meta)
        m = meta
        while m.parent is not None:
            if m.kind == "sfork":
                if m is meta:
                    # origin snapshot: the exact resolver exempts every edge
                    # of a severed-fork-origin read (`via`), so ancestor
                    # holds never apply — only the own-hold cap above does
                    break
                # severed edge mid-chain: the log above appends freely, so
                # the tail-difference invariant breaks; and a hold whose fork
                # point was transferred down by a promote CAN sit below this
                # fork's inherited reach. If any live holder remains above,
                # be conservative: delegate every non-empty read
                a = m
                holder_above = False
                while a.parent is not None:
                    pa = self.logs.get(a.parent)
                    if pa is None:
                        break
                    if pa.alive and self._holds(pa):
                        holder_above = True
                        break
                    a = pa
                if holder_above:
                    cap = 0
                break
            parent = self.logs.get(m.parent)
            if parent is None:
                break
            exempt = (m.log_id in parent.promotable_forks
                      or (m.stands_for is not None
                          and m.stands_for in parent.promotable_forks))
            if not exempt and parent.alive and self._holds(parent):
                # meta is on a continuous-inheritance path below `parent`
                # (severed edges are handled above): while the hold is
                # active nothing on the path can append, so meta's forbidden
                # positions are exactly its top `parent_tail - fp` ones —
                # and concurrent appends by higher ancestors advance both
                # tails equally, keeping `t` invariant
                p_tail = self.tails.get(parent.log_id)[0]
                t = (self.tails.get(meta.log_id)[0]
                     - (p_tail - self._earliest_fp(parent)))
                cap = t if cap is None else min(cap, t)
            m = parent
        if view is not None:
            view.cap = cap
            view.cap_key = self.holds_version
        return cap

    def _build_view(self, meta: LogMeta) -> _FlatView:
        lineage = self._lineage(meta)
        view = _FlatView(self.structure_version, lineage)
        self._views[meta.log_id] = view
        for lid in lineage:
            self._view_deps.setdefault(lid, set()).add(meta.log_id)
        self.stats.builds += 1
        return view

    def _extend_view(self, meta: LogMeta, view: _FlatView, hi: int) -> None:
        """Lazily extend the flattened view to cover [0, hi)."""
        if hi > view.hi:
            self.stats.extends += 1
            new = self._flatten_range(meta, view.hi, hi)
            # extension seam: if the first new entry continues the last
            # entry's run, merge them so span coalescing granularity is
            # identical to a from-scratch resolution
            if new and view.entries:
                c_lo, c_hi, run, rel0 = new[0]
                p_lo, p_hi, p_run, p_rel0 = view.entries[-1]
                if p_run is run and p_hi == c_lo and p_rel0 + (p_hi - p_lo) == rel0:
                    view.entries[-1] = (p_lo, c_hi, run, p_rel0)
                    new = new[1:]
            for entry in new:
                view.los.append(entry[0])
                view.entries.append(entry)
            view.hi = hi

    def _flatten_range(self, meta: LogMeta, lo: int, hi: int
                       ) -> List[Tuple[int, int, object, int]]:
        """Resolve [lo, hi) of `meta` (viewer coordinates) into position-
        contiguous ``(c_lo, c_hi, run, rel0)`` entries, iteratively. `shift`
        is viewer_pos - current_log_pos along the chain walk."""
        out: List[Tuple[int, int, object, int]] = []
        stack: List[Tuple] = [("log", meta, lo, hi, 0)]
        while stack:
            item = stack.pop()
            if item[0] == "emit":
                out.append(item[1])
                continue
            _, m, a, b, shift = item
            if a >= b:
                continue
            pushes: List[Tuple] = []
            for seg in m.index.segments(a, b):
                if seg[0] == "local":
                    _, s_lo, s_hi, run = seg
                    c_lo = s_lo + shift
                    pushes.append(("emit", (c_lo, c_lo + (s_hi - s_lo), run,
                                            s_lo - run.start)))
                else:
                    _, g_lo, g_hi, lcount = seg
                    parent = self.logs.get(m.parent, None)
                    if parent is None:
                        raise UnknownLog(
                            f"positions [{g_lo},{g_hi}) unresolvable in log {m.log_id}")
                    pushes.append(("log", parent, g_lo - lcount, g_hi - lcount,
                                   shift + lcount))
            # LIFO stack + reversed pushes = emits arrive in position order
            stack.extend(reversed(pushes))
        return out

    def _view_spans(self, view: _FlatView, lo: int, hi: int,
                    per_record: bool) -> List[Span]:
        if lo >= hi:
            return []
        out: List[Span] = []
        entries = view.entries
        i = bisect.bisect_right(view.los, lo) - 1
        pos = lo
        while pos < hi:
            c_lo, c_hi, run, rel0 = entries[i]
            a = rel0 + (pos - c_lo)
            b = rel0 + (min(hi, c_hi) - c_lo)
            if per_record:
                out.extend(run.record_spans(a, b))
            else:
                out.extend(run.span(a, b))
            pos = c_hi
            i += 1
        return out

    # -- exact (blocking-aware) chain resolver ------------------------------
    def _resolve(self, meta: LogMeta, lo: int, hi: int, out: List[Span],
                 via_promotable: bool, per_record: bool = False) -> None:
        """Iterative HLI resolution (explicit work stack, no Python recursion)
        with the §4.1 per-edge blocking checks, in exact DFS order: blocking
        and unresolvable-position errors are raised when their work item is
        *reached*, matching the recursive formulation's error order."""
        # work items: ("log", meta, lo, hi, via, blocked)  expand a chain level
        #             ("run", run, a, b)                   emit run records
        #             ("span", span)                       emit one naive span
        #             ("missing", log_id, a, b)            deferred UnknownLog
        stack: List[Tuple] = [("log", meta, lo, hi, via_promotable, False)]
        while stack:
            item = stack.pop()
            kind = item[0]
            if kind == "run":
                _, run, a, b = item
                if per_record:
                    out.extend(run.record_spans(a, b))
                else:
                    out.extend(run.span(a, b))
                continue
            if kind == "span":
                out.append(item[1])
                continue
            if kind == "missing":
                _, mid, a, b = item
                raise UnknownLog(f"positions [{a},{b}) unresolvable in log {mid}")
            _, m, a, b, via, blocked = item
            if blocked:
                raise ForkBlocked(
                    f"reads resolving into log {m.log_id} beyond its "
                    "promotable fork point are blocked")
            if a >= b:
                continue
            pushes: List[Tuple] = []
            if isinstance(m.index, NaiveIndex):
                for pos in range(a, b):
                    span = m.index.get(pos)
                    if span is not None:
                        pushes.append(("span", span))
                    else:
                        parent = self.logs.get(m.parent, None)
                        if parent is None:
                            pushes.append(("missing", m.log_id, pos, pos + 1))
                        else:
                            pushes.append(("log", parent, pos, pos + 1, True, False))
                stack.extend(reversed(pushes))
                continue
            for seg in m.index.segments(a, b):
                if seg[0] == "local":
                    _, s_lo, s_hi, run = seg
                    pushes.append(("run", run, s_lo - run.start, s_hi - run.start))
                else:
                    _, g_lo, g_hi, lcount = seg
                    parent = self.logs.get(m.parent, None)
                    if parent is None:
                        pushes.append(("missing", m.log_id, g_lo, g_hi))
                        continue
                    # per-edge exemption: the promotable child itself (or a
                    # frozen stand-in for it) may see the parent beyond the
                    # fork point — it must, to validate. (`via` also carries
                    # the snapshot-origin exemption set in read_spans.)
                    edge_exempt = (via
                                   or m.log_id in parent.promotable_forks
                                   or (m.stands_for is not None
                                       and m.stands_for in parent.promotable_forks))
                    edge_blocked = (not edge_exempt and parent.alive
                                    and self._holds(parent)
                                    and (g_hi - lcount) > self._earliest_fp(parent))
                    pushes.append(("log", parent, g_lo - lcount, g_hi - lcount,
                                   via, edge_blocked))
            stack.extend(reversed(pushes))

    # -------------------------------------------------------------- accounting
    def metadata_bytes(self) -> int:
        return sum(m.index.nbytes() for m in self.logs.values())

    def live_log_ids(self) -> List[int]:
        return sorted(k for k, v in self.logs.items() if v.alive)
