"""Segment compaction + cold tiering, broker side (DESIGN.md §14).

PR 5's GC reclaims whole dead objects, but group commit (§9) makes *partial*
liveness the steady state: one ``seg-*`` object packs records for several
logs, and it stays fully resident while any one log references a slice. The
post-churn amplification the benchmarks measure (~2.33x) is exactly those
dead bytes inside shared segments.

Like §13, the work splits across the two planes:

* **Metadata (consensus) decides.** The SMR's §14 manifests track per-object
  total bytes and referenced bytes; the ``compact`` command atomically swaps
  every referencing index entry (every log, frozen stand-ins included) from
  the sparsely-live sources onto a compacted object the broker already PUT —
  or mutates nothing and reports ``stale`` if liveness moved underneath the
  broker, leaving the new object as a zero-ref orphan for the §13 path.

* **A broker-side compactor executes.** :class:`Compactor` selects candidates
  below a live-byte-ratio threshold, ranged-reads ONLY the live spans, writes
  the compacted object, proposes the swap, and hands the (now zero-ref)
  sources to the §13 reaper. Crashing at any step is safe: before the PUT,
  nothing happened; after the PUT but before the swap, ``resync()`` sweeps
  the unknown ``cmp-*`` key; after the swap but before the reap, the sources
  sit in the reclaim queue and any later ``gc`` quantum (or reaper resync)
  finishes the job.

Safety interactions with in-flight work mirror the ``gc`` pin machinery: the
compactor's candidate selection EXCLUDES the reaper's pinned ids and every
open speculation session's durable receipt segments — a rebase replay
re-proposes those ``(object, offsets)`` tuples verbatim, so rewriting the
object underneath the receipt would replay against reclaimed storage.
Mid-scan readers are safe without exclusion: scans re-resolve spans per
batch, and sources stay physically present until the reaper (which *does*
honor pins) deletes them after the swap committed.

:class:`TierManager` adds the age-based cold tier on top: consensus-ordered
demotion of cold (by default compacted) objects into the compressed store
class of :class:`~repro.core.objectstore.TieredObjectStore`, and
scan-triggered promotion back. Placement routing is by physical presence, so
every crash window between the copy/drop halves of a move reads correctly;
``resync()`` converges placement to the replicated ``cold_objects`` set.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .broker import _obj_counter
from .objectstore import TieredObjectStore


@dataclass
class CompactionConfig:
    """Compactor policy (DESIGN.md §14).

    An object is a candidate when ``referenced_bytes / total_bytes <=
    max_live_ratio`` (and at least ``min_bytes`` big). 0.85 bounds the
    steady-state residual amplification at ~1/0.85 = 1.18x, under the 1.2x
    CI gate. ``batch`` caps source objects per ``compact`` proposal;
    ``auto`` runs a quantum at the same churn hand-off points as GC
    (session abort, close, explicit squash/promote)."""

    max_live_ratio: float = 0.85
    min_bytes: int = 1
    batch: int = 8
    auto: bool = False
    reap: bool = True           # run a gc quantum right after a swap commits
    broker: Optional[int] = None


@dataclass
class CompactStats:
    """Compaction counters + point-in-time snapshots."""

    runs: int = 0               # explicit compact() drains
    quanta: int = 0             # compact proposals issued
    compacted_objects: int = 0  # cmp-* objects written and swapped in
    sources_retired: int = 0    # source objects whose entries were swapped out
    stale: int = 0              # proposals rejected (liveness moved)
    bytes_read: int = 0         # live bytes ranged-read from sources
    bytes_written: int = 0      # compacted payload bytes PUT
    orphans_swept: int = 0      # unknown cmp-* keys deleted by resync
    resyncs: int = 0
    candidates: int = 0         # snapshot: objects under the ratio threshold


@dataclass
class TieringConfig:
    """Tier policy (DESIGN.md §14): objects whose age (SMR command ticks
    since first sight) reaches ``min_age`` — restricted to ``prefixes``,
    by default compacted objects only — demote to the cold class, at most
    ``batch`` per quantum. A read of ``promote_scan_records`` or more
    records that touches cold objects is scan-shaped: those objects promote
    back to hot (the §10 readahead heuristic, applied to tiers)."""

    min_age: int = 64
    prefixes: Tuple[str, ...] = ("cmp-",)
    batch: int = 8
    promote_scan_records: int = 4
    auto: bool = False
    broker: Optional[int] = None


@dataclass
class TierStats:
    demotions: int = 0          # objects moved hot -> cold
    rehydrations: int = 0       # objects moved cold -> hot
    bytes_demoted: int = 0      # compressed bytes stored cold
    bytes_rehydrated: int = 0   # logical bytes restored hot
    resyncs: int = 0
    cold_objects: int = 0       # snapshot: consensus cold set size
    cold_stored_bytes: int = 0  # snapshot: compressed bytes resident cold


class Compactor:
    """The broker-side rewriter: plans, PUTs, proposes ``compact``, reaps."""

    def __init__(self, system, config: Optional[CompactionConfig] = None) -> None:
        self.system = system
        self.config = config or CompactionConfig()
        self._stats = CompactStats()

    def _broker(self):
        brokers = self.system.brokers
        i = self.config.broker
        return brokers[i if i is not None else len(brokers) - 1]

    def _excluded(self) -> Set[str]:
        """Objects the compactor must not rewrite: ids pinned by in-flight
        session rebases (§13) plus every open speculation's durable receipt
        segments — either way, ``(object, offsets)`` tuples held outside any
        index that a replay will re-propose verbatim."""
        out: Set[str] = set()
        collector = getattr(self.system, "collector", None)
        if collector is not None:
            out.update(collector._pins)
        session_segments = getattr(self.system, "_session_segments", None)
        if session_segments is not None:
            out.update(session_segments())
        return out

    def candidates(self) -> List[str]:
        cfg = self.config
        return self.system.metadata.state.compaction_candidates(
            cfg.max_live_ratio, cfg.min_bytes, exclude=self._excluded())

    def _plan(self, sources: Optional[List[str]] = None):
        """Select sources and build (new_object_id, payload, mapping) from
        ranged reads of exactly the live spans. Returns None when there is
        nothing to compact. Split from ``quantum`` so crash tests can stop
        between the PUT and the proposal."""
        if sources is None:
            sources = self.candidates()[:self.config.batch]
        if not sources:
            return None
        state = self.system.metadata.state
        live = state.object_live_spans(sources)
        store = self.system.store
        chunks: List[bytes] = []
        mapping: List[Tuple[str, Tuple]] = []
        dst = 0
        n_gets = 0
        for src in sources:
            spans = live.get(src, [])
            if not spans:
                continue   # died since selection; gc will take it whole
            ranges = []
            for off, ln in spans:
                if ln:
                    chunks.append(store.get(src, off, ln))
                    n_gets += 1
                ranges.append((off, ln, dst))
                dst += ln
            mapping.append((src, tuple(ranges)))
        if not mapping:
            return None
        new_object_id = f"cmp-{self._broker().broker_id}-{next(_obj_counter)}"
        return new_object_id, b"".join(chunks), tuple(mapping), n_gets

    def quantum(self, arrival: Optional[float] = None) -> List[str]:
        """One incremental compaction step: plan, PUT the compacted object,
        propose the swap, then (by default) run a gc quantum so the retired
        sources reach the reaper. Returns the retired source ids ([] when
        idle or when the proposal came back stale)."""
        plan = self._plan()
        if plan is None:
            return []
        new_object_id, payload, mapping, n_gets = plan
        store = self.system.store
        store.put(new_object_id, payload)
        outcome = self.system.metadata.propose(
            ("compact", new_object_id, len(payload), mapping))
        self._stats.quanta += 1
        self._stats.bytes_read += len(payload)
        self._stats.bytes_written += len(payload)
        self._broker().book_compact(arrival, read_bytes=len(payload),
                                    write_bytes=len(payload), n_gets=n_gets)
        if outcome[0] != "ok":
            # liveness moved under us: the swap did not happen and the PUT
            # is an orphan, already queued on the §13 zero-ref path
            self._stats.stale += 1
            if self.config.reap:
                self.system.collector.quantum(arrival=arrival)
            return []
        retired = list(outcome[1]["sources"])
        self._stats.compacted_objects += 1
        self._stats.sources_retired += len(retired)
        if self.config.reap:
            self.system.collector.quantum(arrival=arrival)
        return retired

    def compact(self, arrival: Optional[float] = None) -> CompactStats:
        """Drain: run quanta until no candidate remains (or the only ones
        left keep coming back stale)."""
        self._stats.runs += 1
        while self.quantum(arrival):
            pass
        return self.stats()

    def resync(self, arrival: Optional[float] = None) -> List[str]:
        """Crash recovery for a compactor that died between the PUT and the
        ``compact`` proposal: a ``cmp-*`` key the consensus manifests have
        never seen (not referenced, not reclaimed) is unreachable garbage —
        delete it. Idempotent; run when the compactor's broker restarts."""
        state = self.system.metadata.state
        store = self.system.store
        swept = [key for key in store.list("cmp-")
                 if key not in state.object_refs and key not in state.reclaimed]
        for key in swept:
            store.delete(key)
            for b in self.system.brokers:
                b.cache.invalidate_object(key)
        self._stats.orphans_swept += len(swept)
        self._stats.resyncs += 1
        if swept:
            self._broker().book_reclaim(arrival, len(swept))
        return swept

    def stats(self) -> CompactStats:
        s = self._stats
        return CompactStats(runs=s.runs, quanta=s.quanta,
                            compacted_objects=s.compacted_objects,
                            sources_retired=s.sources_retired,
                            stale=s.stale,
                            bytes_read=s.bytes_read,
                            bytes_written=s.bytes_written,
                            orphans_swept=s.orphans_swept,
                            resyncs=s.resyncs,
                            candidates=len(self.candidates()))


class TierManager:
    """Executes consensus tier decisions against a tiered store."""

    def __init__(self, system, config: Optional[TieringConfig] = None) -> None:
        self.system = system
        self.config = config or TieringConfig()
        self._stats = TierStats()

    def _broker(self):
        brokers = self.system.brokers
        i = self.config.broker
        return brokers[i if i is not None else len(brokers) - 1]

    def _store(self) -> Optional[TieredObjectStore]:
        store = self.system.store
        return store if isinstance(store, TieredObjectStore) else None

    def demote_quantum(self, arrival: Optional[float] = None) -> List[str]:
        """One demotion step. Order is crash-safe: compress a cold copy
        FIRST (hot copy still serving reads), then propose ``demote_cold``,
        then drop the hot copies of exactly the accepted ids — a crash
        anywhere leaves at worst a double-resident key for ``resync``."""
        store = self._store()
        if store is None:
            return []
        cfg = self.config
        state = self.system.metadata.state
        cands = state.demotion_candidates(cfg.min_age, cfg.prefixes)[:cfg.batch]
        cands = [obj for obj in cands if store.exists(obj) and not store.is_cold(obj)]
        if not cands:
            return []
        packed = 0
        for obj in cands:
            packed += store.copy_to_cold(obj)
        accepted = self.system.metadata.propose(("demote_cold", tuple(cands)))
        for obj in accepted:
            store.drop_hot(obj)
        for obj in set(cands) - set(accepted):
            store.drop_cold(obj)   # consensus said no (died/raced): undo
        self._stats.demotions += len(accepted)
        self._stats.bytes_demoted += packed
        self._broker().book_tier(arrival, cold_put_bytes=packed,
                                 n_objects=len(cands))
        return list(accepted)

    def demote(self, arrival: Optional[float] = None) -> TierStats:
        """Drain every currently-eligible demotion."""
        while self.demote_quantum(arrival):
            pass
        return self.stats()

    def note_scan(self, cold_keys: Iterable[str], n_records: int,
                  arrival: Optional[float] = None) -> List[str]:
        """Broker read-path hook: a read of ``n_records`` touched physically
        cold objects. Scan-shaped reads promote them back to hot — propose
        first (the consensus record moves), then rehydrate and drop the cold
        copies. Keys consensus no longer considers cold (placement drift)
        are rehydrated anyway: routing is by presence, so this only
        converges placement."""
        store = self._store()
        if store is None or n_records < self.config.promote_scan_records:
            return []
        keys = sorted(set(cold_keys))
        accepted = self.system.metadata.propose(("promote_hot", tuple(keys)))
        restored = 0
        moved: List[str] = []
        for obj in keys:
            if store.is_cold(obj):
                restored += store.rehydrate(obj)
                store.drop_cold(obj)
                moved.append(obj)
        self._stats.rehydrations += len(moved)
        self._stats.bytes_rehydrated += restored
        if moved:
            self._broker().book_tier(arrival, cold_get_bytes=restored,
                                     n_objects=len(moved))
        return list(accepted)

    def resync(self, arrival: Optional[float] = None) -> int:
        """Converge physical placement to the replicated ``cold_objects``
        set after a crash mid-move (idempotent): consensus-cold keys lose
        their hot copy (compressing one first if the drop never happened);
        physically-cold keys consensus thinks are hot rehydrate."""
        store = self._store()
        if store is None:
            return 0
        state = self.system.metadata.state
        fixed = 0
        for obj in sorted(state.cold_objects):
            if store.exists(obj) and not store.is_cold(obj):
                store.copy_to_cold(obj)
                store.drop_hot(obj)
                fixed += 1
        for obj in store.list():
            if (store.is_cold(obj) and obj not in state.cold_objects
                    and obj in state.object_refs):
                store.rehydrate(obj)
                store.drop_cold(obj)
                fixed += 1
        self._stats.resyncs += 1
        return fixed

    def stats(self) -> TierStats:
        s = self._stats
        store = self._store()
        state = self.system.metadata.state
        return TierStats(demotions=s.demotions, rehydrations=s.rehydrations,
                         bytes_demoted=s.bytes_demoted,
                         bytes_rehydrated=s.bytes_rehydrated,
                         resyncs=s.resyncs,
                         cold_objects=len(state.cold_objects),
                         cold_stored_bytes=(store.cold_stored_bytes
                                            if store is not None else 0))
