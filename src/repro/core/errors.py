"""Error types for the AgileLog abstraction."""


class AgileLogError(Exception):
    """Base class for AgileLog errors."""


class UnknownLog(AgileLogError):
    """Operation on a log id that does not exist (or was squashed/promoted away)."""


class ForkBlocked(AgileLogError):
    """Operation blocked because an active promotable cFork restricts it (§4.1)."""


class InvalidOperation(AgileLogError):
    """Semantically invalid call (e.g. squash of a root log, promote of an sFork)."""


class NotLeader(AgileLogError):
    """Metadata proposal sent to a non-leader replica."""
