"""Error types for the AgileLog abstraction."""


class AgileLogError(Exception):
    """Base class for AgileLog errors."""


class UnknownLog(AgileLogError):
    """Operation on a log id that does not exist (or was squashed/promoted away)."""


class ForkBlocked(AgileLogError):
    """Operation blocked because an active promotable cFork restricts it (§4.1)."""


class InvalidOperation(AgileLogError):
    """Semantically invalid call (e.g. squash of a root log, promote of an sFork)."""


class ConflictError(AgileLogError):
    """A speculative commit could not be sequenced (DESIGN.md §12).

    Raised by ``Speculation.commit()`` when the bounded auto-rebase budget is
    exhausted (the parent kept advancing, or a sibling speculation won the
    promote race), or when an ``on_rebase`` validation hook rejects the
    rebased state. Carries the metadata layer's fork-point/tail diagnostics
    so the caller can see exactly how far the parent ran ahead.
    """

    def __init__(self, msg: str, *, log_id=None, fork_id=None, fork_point=None,
                 parent_tail=None, expected=None, advanced=0, attempts=0,
                 holds_epoch=None) -> None:
        super().__init__(msg)
        self.log_id = log_id            # the parent (commit target)
        self.fork_id = fork_id          # the speculative cFork
        self.fork_point = fork_point    # fork point of the last attempt
        self.parent_tail = parent_tail  # parent tail the metadata layer saw
        self.expected = expected        # parent tail the speculation validated
        self.advanced = advanced        # records sequenced past `expected`
        self.attempts = attempts        # promote attempts (1 + rebases)
        self.holds_epoch = holds_epoch  # metadata holds_version at the check


class ObjectMissing(AgileLogError, KeyError):
    """A GET/ranged-GET named an object key the store does not hold.

    Every backend raises this one type (DESIGN.md §18) — the seed backends
    leaked their implementation's native miss (`KeyError` from the dict-backed
    stores, `FileNotFoundError` from the file store), so a caller that caught
    one silently missed the other. Deterministic, not transient: the key is
    gone (reaped, never written, or torn and swept) and retrying will not
    bring it back. Subclasses ``KeyError`` so pre-§18 external callers that
    caught the memory backend's miss keep working.
    """

    def __init__(self, key=None) -> None:
        super().__init__(f"object missing: {key!r}")
        self.key = key

    def __str__(self) -> str:        # KeyError.__str__ repr()s the arg
        return self.args[0]


class Unavailable(AgileLogError):
    """A layer of the system cannot serve the request *right now* (DESIGN.md
    §15). Unlike the deterministic command errors above, unavailability is
    transient-by-contract: the client retry policy treats every subclass as
    retryable (replicas recover, brokers fail over, leaders get re-elected).
    """


class NoQuorum(Unavailable):
    """The metadata layer lost its majority: proposals cannot commit and a
    leader cannot be elected until enough replicas recover."""


class NotLeader(Unavailable):
    """Metadata proposal handled by a replica that is not (or no longer) the
    leader (DESIGN.md §16). Under the message-level network plane this is the
    term fence: a partitioned stale leader's AppendEntries are rejected by the
    higher term of the majority-side quorum, so its proposals raise this
    instead of acking. Retryable — the client's :class:`RetryPolicy` fails
    over to the current leader."""


class LeaseExpired(Unavailable):
    """A lease-fenced local read was attempted on a replica whose leader
    lease has lapsed (DESIGN.md §16). A partitioned stale leader stops
    winning majority ack rounds, its lease stops being extended, and once the
    DES clock passes the lease horizon its local reads are fenced — they
    raise this instead of returning stale state. Retryable: the client fails
    over and re-reads through the current leader."""


class NoLiveBrokers(Unavailable):
    """Every broker in the fleet is marked dead; there is nowhere to route
    the data-plane request."""


class StoreFault(Unavailable):
    """An injected (or, with a real backend, observed) object-store failure:
    a PUT/GET/DELETE that did not complete. A *torn* PUT raises this after
    durably writing a prefix of the payload — the caller must treat the key
    as garbage until a full re-PUT succeeds (DESIGN.md §15)."""


class BrokerCrashed(Unavailable):
    """A broker died mid-operation (injected, DESIGN.md §15) — typically in
    the window after an object PUT and before its metadata proposal. The
    fleet layer fails the broker over on sight: staged group-commit records
    move to a surviving broker, the orphaned PUT goes to the §13 reaper."""

    def __init__(self, msg: str, broker_id=None) -> None:
        super().__init__(msg)
        self.broker_id = broker_id


class AmbiguousProposal(Unavailable):
    """A propose() timed out after the entry may have committed (DESIGN.md
    §15): the command is possibly applied, possibly not. Safe to retry ONLY
    with the same idempotency token — the replicated dedup table makes the
    retry apply-at-most-once."""

    def __init__(self, msg: str, token=None) -> None:
        super().__init__(msg)
        self.token = token          # the idempotency token to retry with


class RetryBudgetExhausted(Unavailable):
    """The client retry policy gave up: every attempt hit an Unavailable
    error and the bounded backoff budget ran out. Carries the last cause."""

    def __init__(self, msg: str, attempts: int = 0,
                 last_error: Exception = None) -> None:
        super().__init__(msg)
        self.attempts = attempts
        self.last_error = last_error
