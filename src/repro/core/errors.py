"""Error types for the AgileLog abstraction."""


class AgileLogError(Exception):
    """Base class for AgileLog errors."""


class UnknownLog(AgileLogError):
    """Operation on a log id that does not exist (or was squashed/promoted away)."""


class ForkBlocked(AgileLogError):
    """Operation blocked because an active promotable cFork restricts it (§4.1)."""


class InvalidOperation(AgileLogError):
    """Semantically invalid call (e.g. squash of a root log, promote of an sFork)."""


class ConflictError(AgileLogError):
    """A speculative commit could not be sequenced (DESIGN.md §12).

    Raised by ``Speculation.commit()`` when the bounded auto-rebase budget is
    exhausted (the parent kept advancing, or a sibling speculation won the
    promote race), or when an ``on_rebase`` validation hook rejects the
    rebased state. Carries the metadata layer's fork-point/tail diagnostics
    so the caller can see exactly how far the parent ran ahead.
    """

    def __init__(self, msg: str, *, log_id=None, fork_id=None, fork_point=None,
                 parent_tail=None, expected=None, advanced=0, attempts=0,
                 holds_epoch=None) -> None:
        super().__init__(msg)
        self.log_id = log_id            # the parent (commit target)
        self.fork_id = fork_id          # the speculative cFork
        self.fork_point = fork_point    # fork point of the last attempt
        self.parent_tail = parent_tail  # parent tail the metadata layer saw
        self.expected = expected        # parent tail the speculation validated
        self.advanced = advanced        # records sequenced past `expected`
        self.attempts = attempts        # promote attempts (1 + rebases)
        self.holds_epoch = holds_epoch  # metadata holds_version at the check


class NotLeader(AgileLogError):
    """Metadata proposal sent to a non-leader replica."""
