"""Deterministic discrete-event simulation of contended resources.

The paper's isolation experiments (Figs 6-8, 12-14) measure queueing delay when
agentic load shares (or does not share) broker/disk resources with a
latency-critical workload. This container has one CPU core, so wall-clock
contention cannot be reproduced honestly; instead we model each broker (and the
Kafka-like baseline's shared broker+disk) as an M/D/c-style service queue under
a simulated clock. Metadata-layer costs (the paper's novel part) are measured
as *real* CPU time elsewhere; only data-plane contention is modeled here, and
EXPERIMENTS.md labels the two sources explicitly.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple


class SimClock:
    def __init__(self) -> None:
        self.now = 0.0


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = field(compare=False)


class Simulator:
    """Minimal event loop."""

    def __init__(self) -> None:
        self.clock = SimClock()
        self._queue: List[_Event] = []
        self._seq = 0

    def at(self, time: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._queue, _Event(time, self._seq, fn))
        self._seq += 1

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.clock.now + delay, fn)

    def run(self, until: Optional[float] = None) -> None:
        while self._queue:
            if until is not None and self._queue[0].time > until:
                break
            ev = heapq.heappop(self._queue)
            self.clock.now = ev.time
            ev.fn()
        if until is not None:
            self.clock.now = max(self.clock.now, until)


class Resource:
    """A FIFO server with `servers` parallel units and deterministic service times.

    `submit(arrival, service_time)` returns the completion time; latency is
    completion - arrival. This is what models a broker NIC/CPU or a disk: when
    an analytics agent floods the same Resource the lc-workload queues behind
    it; on a separate Resource it does not.
    """

    def __init__(self, servers: int = 1) -> None:
        self.servers = servers
        self._free_at: List[float] = [0.0] * servers  # heap of next-free times
        heapq.heapify(self._free_at)
        self.busy_time = 0.0
        self.jobs = 0

    def submit(self, arrival: float, service_time: float) -> float:
        start = max(arrival, heapq.heappop(self._free_at))
        done = start + service_time
        heapq.heappush(self._free_at, done)
        self.busy_time += service_time
        self.jobs += 1
        return done

    def utilization(self, window: float) -> float:
        """Fraction of server-time busy over a `window` of simulated seconds."""
        if window <= 0:
            return float("nan")
        return self.busy_time / (window * self.servers)


@dataclass
class SpecStats:
    """Speculation-session counters (DESIGN.md §12), one instance per
    BoltSystem. The session layer bumps these as it runs; ``OpTally.capture``
    snapshots them alongside the data-/metadata-plane counters so benchmarks
    can report commit/conflict/rebase rates and replay amortization (a rebase
    replays its suffix as metadata-only re-appends — zero object PUTs)."""

    sessions: int = 0          # Speculation handles opened
    commits: int = 0           # successful commit() calls
    aborts: int = 0            # abort() calls (explicit, implicit, or failed)
    conflicts: int = 0         # promote_if conflicts (incl. lost promote races)
    rebases: int = 0           # auto-rebases performed
    replayed_records: int = 0  # suffix records re-sequenced by rebases


@dataclass
class ServeStats:
    """Serving-on-the-log counters (DESIGN.md §17), one per BoltSystem.
    The serve engine and speculative-decode driver bump these; benchmarks
    read accepted-token throughput and rollout economics out of the same
    ``OpTally.capture`` snapshot that reports PUT/proposal amortization —
    the point being that tokens/s and commits/s are the SAME budget when
    responses ride the log."""

    requests: int = 0          # request records consumed from a request log
    responses: int = 0         # response streams completed (EOS committed)
    model_steps: int = 0       # target-model invocations (prefill/decode/verify)
    draft_steps: int = 0       # draft-model invocations (speculative only)
    tokens_out: int = 0        # tokens durably committed to response streams
    tokens_drafted: int = 0    # draft tokens proposed by rollout sessions
    tokens_accepted: int = 0   # draft tokens verification accepted
    tokens_rejected: int = 0   # draft tokens squashed with their rollout
    rollouts: int = 0          # speculate() rollout sessions opened
    rollouts_rejected: int = 0 # rollouts aborted wholesale (no trace, §12)
    reanchors: int = 0         # rollout commits re-anchored past a moved tail

    @property
    def acceptance(self) -> float:
        """Fraction of drafted tokens the target model accepted."""
        return self.tokens_accepted / max(1, self.tokens_drafted)


def _fault_count(system, key: str) -> int:
    """Read one fault-plane counter off a system (0 without a plane)."""
    plane = getattr(system, "faults", None)
    if plane is None:
        return 0
    return plane.counters.get(key, 0)


@dataclass
class OpTally:
    """Cross-plane operation counters for amortization accounting (DESIGN.md §9).

    Group commit's whole point is fewer metadata proposals and object PUTs
    *per appended record*; this tally snapshots both planes around a workload
    so benchmarks report the ratio directly. The §12 session fields measure
    the speculative-commit path the same way: ``replays`` counts zero-copy
    re-appends (metadata-only — if rebases show up in ``puts`` instead,
    replay stopped being zero-copy)."""

    records: int = 0
    proposals: int = 0
    puts: int = 0
    bytes_put: int = 0
    gets: int = 0        # store GETs (ranged; post-cache, DESIGN.md §10)
    bytes_get: int = 0   # bytes actually fetched from the store
    meta_cached: int = 0  # metadata resolutions served by a flattened view (§11)
    meta_slow: int = 0    # resolutions through the exact chain resolver
    deletes: int = 0          # store object deletes (GC reaper, §13)
    bytes_reclaimed: int = 0  # bytes those deletes freed in shared storage
    replays: int = 0      # zero-copy re-appends (rebase replay, §12)
    spec_conflicts: int = 0   # speculative commit conflicts (§12)
    spec_rebases: int = 0     # auto-rebases (§12)
    spec_replayed: int = 0    # suffix records re-sequenced by rebases (§12)
    cold_gets: int = 0        # GETs served by the cold store class (§14)
    bytes_get_cold: int = 0   # logical bytes those cold GETs returned (§14)
    cold_demotions: int = 0   # hot->cold tier moves (§14)
    bytes_demoted: int = 0    # compressed bytes demotions stored cold (§14)
    retries: int = 0          # client retry attempts after Unavailable (§15)
    faults_injected: int = 0  # fault-plane draws that fired (§15)
    dedup_hits: int = 0       # idempotent re-proposals deduplicated (§15)
    failovers: int = 0        # broker failovers + leader elections (§15)
    msgs_dropped: int = 0     # consensus messages the network lost (§16)
    msgs_delayed: int = 0     # consensus messages held for later delivery (§16)
    msgs_duplicated: int = 0  # consensus messages delivered twice (§16)
    fenced_rejections: int = 0  # stale-term appends/reads fenced (§16)
    serve_steps: int = 0        # target-model invocations (§17)
    serve_draft_steps: int = 0  # draft-model invocations (§17)
    serve_tokens_out: int = 0   # tokens committed to response streams (§17)
    serve_tokens_accepted: int = 0  # draft tokens verification accepted (§17)
    serve_tokens_rejected: int = 0  # draft tokens squashed, no trace (§17)
    serve_reanchors: int = 0    # rollout commits re-anchored over a moved tail
    lease_reads: int = 0        # reads served by the lease fast path (§18)
    lease_fallbacks: int = 0    # lease reads that fell back to the barrier (§18)

    @classmethod
    def capture(cls, system, records: int = 0) -> "OpTally":
        """Snapshot a BoltSystem's counters (records is caller-supplied).
        Store backends without counters (e.g. FileObjectStore) report 0."""
        view_stats = system.metadata.state.stats
        spec = getattr(system, "spec_stats", None) or SpecStats()
        serve = getattr(system, "serve_stats", None) or ServeStats()
        return cls(records=records,
                   proposals=system.metadata.proposals,
                   puts=getattr(system.store, "put_count", 0),
                   bytes_put=getattr(system.store, "bytes_written", 0),
                   gets=getattr(system.store, "get_count", 0),
                   bytes_get=getattr(system.store, "bytes_read", 0),
                   meta_cached=view_stats.cached_reads,
                   meta_slow=view_stats.slow_reads,
                   deletes=getattr(system.store, "delete_count", 0),
                   bytes_reclaimed=getattr(system.store, "bytes_deleted", 0),
                   replays=sum(getattr(b, "replays", 0)
                               for b in getattr(system, "brokers", [])),
                   spec_conflicts=spec.conflicts,
                   spec_rebases=spec.rebases,
                   spec_replayed=spec.replayed_records,
                   cold_gets=getattr(system.store, "cold_gets", 0),
                   bytes_get_cold=getattr(system.store, "cold_bytes_read", 0),
                   cold_demotions=getattr(system.store, "cold_puts", 0),
                   bytes_demoted=getattr(system.store, "cold_bytes_written", 0),
                   retries=getattr(getattr(system, "retry_stats", None),
                                   "retries", 0),
                   faults_injected=getattr(getattr(system, "faults", None),
                                           "total_injected", 0) or 0,
                   dedup_hits=getattr(system.metadata.state, "idem_hits", 0),
                   failovers=(getattr(system, "broker_failovers", 0)
                              + getattr(system.metadata, "elections", 0)),
                   msgs_dropped=_fault_count(system, "msgs_dropped"),
                   msgs_delayed=_fault_count(system, "msgs_delayed"),
                   msgs_duplicated=_fault_count(system, "msgs_duplicated"),
                   fenced_rejections=_fault_count(system, "fenced_rejections"),
                   serve_steps=serve.model_steps,
                   serve_draft_steps=serve.draft_steps,
                   serve_tokens_out=serve.tokens_out,
                   serve_tokens_accepted=serve.tokens_accepted,
                   serve_tokens_rejected=serve.tokens_rejected,
                   serve_reanchors=serve.reanchors,
                   lease_reads=getattr(system.metadata, "lease_reads", 0),
                   lease_fallbacks=getattr(system.metadata,
                                           "lease_fallbacks", 0))

    def delta(self, since: "OpTally") -> "OpTally":
        return OpTally(records=self.records - since.records,
                       proposals=self.proposals - since.proposals,
                       puts=self.puts - since.puts,
                       bytes_put=self.bytes_put - since.bytes_put,
                       gets=self.gets - since.gets,
                       bytes_get=self.bytes_get - since.bytes_get,
                       meta_cached=self.meta_cached - since.meta_cached,
                       meta_slow=self.meta_slow - since.meta_slow,
                       deletes=self.deletes - since.deletes,
                       bytes_reclaimed=self.bytes_reclaimed - since.bytes_reclaimed,
                       replays=self.replays - since.replays,
                       spec_conflicts=self.spec_conflicts - since.spec_conflicts,
                       spec_rebases=self.spec_rebases - since.spec_rebases,
                       spec_replayed=self.spec_replayed - since.spec_replayed,
                       cold_gets=self.cold_gets - since.cold_gets,
                       bytes_get_cold=self.bytes_get_cold - since.bytes_get_cold,
                       cold_demotions=self.cold_demotions - since.cold_demotions,
                       bytes_demoted=self.bytes_demoted - since.bytes_demoted,
                       retries=self.retries - since.retries,
                       faults_injected=self.faults_injected - since.faults_injected,
                       dedup_hits=self.dedup_hits - since.dedup_hits,
                       failovers=self.failovers - since.failovers,
                       msgs_dropped=self.msgs_dropped - since.msgs_dropped,
                       msgs_delayed=self.msgs_delayed - since.msgs_delayed,
                       msgs_duplicated=self.msgs_duplicated - since.msgs_duplicated,
                       fenced_rejections=(self.fenced_rejections
                                          - since.fenced_rejections),
                       serve_steps=self.serve_steps - since.serve_steps,
                       serve_draft_steps=(self.serve_draft_steps
                                          - since.serve_draft_steps),
                       serve_tokens_out=(self.serve_tokens_out
                                         - since.serve_tokens_out),
                       serve_tokens_accepted=(self.serve_tokens_accepted
                                              - since.serve_tokens_accepted),
                       serve_tokens_rejected=(self.serve_tokens_rejected
                                              - since.serve_tokens_rejected),
                       serve_reanchors=self.serve_reanchors - since.serve_reanchors,
                       lease_reads=self.lease_reads - since.lease_reads,
                       lease_fallbacks=(self.lease_fallbacks
                                        - since.lease_fallbacks))

    @property
    def proposals_per_record(self) -> float:
        return self.proposals / max(1, self.records)

    @property
    def puts_per_record(self) -> float:
        return self.puts / max(1, self.records)


@dataclass
class ServiceTimes:
    """Per-operation service-time model (seconds). Defaults are loosely sized
    from the paper's CloudLab x1170 numbers (4KB records, ~ms-scale e2e)."""

    broker_cpu_per_req: float = 8e-6       # request handling on a broker
    broker_cpu_per_kb: float = 0.4e-6      # payload touch cost
    store_put_base: float = 1.5e-3         # S3-like object PUT
    store_put_per_kb: float = 2e-6
    store_get_base: float = 0.6e-3         # S3-like ranged GET (charged PER GET:
    store_get_per_kb: float = 1e-6         # Broker._book books each coalesced
                                           # ranged GET, not whole-object fills)
    store_delete_base: float = 0.5e-3      # S3-like object DELETE (GC reaper,
                                           # §13; size-independent like real
                                           # object stores)
    disk_read_per_kb: float = 3e-6         # Kafka-like local disk
    disk_seek: float = 80e-6
    metadata_op: float = 12e-6             # sequencing round at metadata layer
    metadata_op_cached: float = 4e-6       # lookup served by a flattened view
                                           # (§11: bisect + slice, no chain walk)
    metadata_op_lease: float = 1.5e-6      # lease-fenced local read (§18): no
                                           # consensus round, no barrier — a
                                           # clock check + local state apply
    net_rtt: float = 60e-6
    cold_get_base: float = 5e-3            # archive-class ranged GET (§14):
    cold_get_per_kb: float = 8e-6          # slower first byte + decompression
    cold_put_base: float = 3e-3            # demotion PUT into the cold class
    cold_put_per_kb: float = 4e-6
    serve_dispatch: float = 25e-6          # host-side model-step dispatch (§17:
                                           # kernel launch + batch marshaling,
                                           # charged per model invocation on
                                           # top of the roofline step time)


def percentile(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, max(0, int(round(p / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def summarize(latencies: List[float]) -> Tuple[float, float, float]:
    """mean, p50, p99 (seconds)."""
    if not latencies:
        return (float("nan"),) * 3
    s = sorted(latencies)
    return (sum(s) / len(s), percentile(s, 50), percentile(s, 99))
