"""Executable specification of AgileLog semantics (§4.1), by brute force.

Every log's content is fully materialized; cForks eagerly copy and inherit.
O(everything) — test-only. Property tests replay random operation traces
against both this model and Bolt and require identical observable behavior
(tails, reads, returned positions, and which operations error).

The bottom half is the **byte-liveness oracle** for segment GC
(DESIGN.md §13): an independent, from-scratch recount of the metadata
layer's manifests plus the two storage-safety predicates the
``tests/test_gc_safety.py`` harness asserts under arbitrary interleavings —
*safety* (every position readable through any live log resolves to bytes
actually present in shared storage) and *liveness* (once GC drains, the
store holds exactly the referenced objects: reclaimed == dead).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .errors import ForkBlocked, InvalidOperation, UnknownLog


@dataclass
class _Hold:
    """An active promotable cFork: parent, child, and per-log read/append caps."""
    parent: int
    child: int
    fp: int
    caps: Dict[int, int] = field(default_factory=dict)  # log -> cap position


@dataclass
class _OLog:
    log_id: int
    kind: str
    parent: Optional[int]          # cfork inheritance edge (None for roots/sforks)
    promotable: bool
    records: List[bytes] = field(default_factory=list)
    children: List[int] = field(default_factory=list)  # cfork children


class OracleModel:
    def __init__(self) -> None:
        self.logs: Dict[int, _OLog] = {}
        self.holds: List[_Hold] = []
        self._next = 0

    # -- helpers -------------------------------------------------------------------
    def _get(self, lid: int) -> _OLog:
        if lid not in self.logs:
            raise UnknownLog(str(lid))
        return self.logs[lid]

    def _subtree(self, lid: int) -> List[int]:
        out, stack = [], [lid]
        while stack:
            x = stack.pop()
            out.append(x)
            stack.extend(self.logs[x].children)
        return out

    def _holds_on(self, lid: int) -> List[_Hold]:
        return [h for h in self.holds if h.parent == lid]

    def _append_blocked(self, lid: int) -> bool:
        """Blocked iff some hold caps this log and lid is not the hold's parent."""
        return any(lid in h.caps and h.parent != lid for h in self.holds)

    def _read_cap(self, lid: int) -> float:
        cap: float = float("inf")
        for h in self.holds:
            if lid in h.caps:
                cap = min(cap, h.caps[lid])
        return cap

    # -- ops ----------------------------------------------------------------------
    def create_root(self, name: str = "") -> int:
        lid = self._next
        self._next += 1
        self.logs[lid] = _OLog(lid, "root", None, False)
        return lid

    def append(self, lid: int, recs: List[bytes]) -> Optional[List[int]]:
        log = self._get(lid)
        if self._append_blocked(lid):
            raise ForkBlocked("append blocked")
        start = len(log.records)
        # propagate to the whole cfork subtree (continuous inheritance)
        for d in self._subtree(lid):
            self.logs[d].records.extend(recs)
        if self._holds_on(lid):
            return None  # positions withheld (§4.1)
        return list(range(start, start + len(recs)))

    def _check_forkable(self, log: _OLog) -> None:
        if self._append_blocked(log.log_id):
            raise ForkBlocked("fork creation blocked")
        own = self._holds_on(log.log_id)
        if own and len(log.records) > min(h.fp for h in own):
            raise ForkBlocked("cannot fork beyond an active promotable fork point")

    def cfork(self, parent_id: int, promotable: bool) -> int:
        parent = self._get(parent_id)
        self._check_forkable(parent)
        lid = self._next
        self._next += 1
        child = _OLog(lid, "cfork", parent_id, promotable,
                      records=list(parent.records))
        self.logs[lid] = child
        parent.children.append(lid)
        if promotable:
            hold = _Hold(parent_id, lid, fp=len(parent.records))
            # cap every log in parent's subtree except promotable branches
            stack = [parent_id]
            while stack:
                x = stack.pop()
                xl = self.logs[x]
                hold.caps[x] = len(xl.records)
                for c in xl.children:
                    if x == parent_id and self.logs[c].promotable and \
                            (c == lid or any(h.child == c for h in self.holds)):
                        continue  # promotable children of the parent are exempt
                    stack.append(c)
            self.holds.append(hold)
        else:
            # new non-promotable child inherits existing caps of its parent
            for h in self.holds:
                if parent_id in h.caps:
                    h.caps[lid] = len(child.records)
        return lid

    def sfork(self, parent_id: int, past: Optional[int]) -> int:
        parent = self._get(parent_id)
        self._check_forkable(parent)
        n = len(parent.records)
        if past is not None:
            if not (0 <= past < n):
                raise InvalidOperation("past offset out of range")
            fp = past + 1
        else:
            fp = n
        lid = self._next
        self._next += 1
        self.logs[lid] = _OLog(lid, "sfork", None, False,
                               records=list(parent.records[:fp]))
        return lid

    def read(self, lid: int, lo: int, hi: int) -> List[bytes]:
        log = self._get(lid)
        if not (0 <= lo <= hi <= len(log.records)):
            raise InvalidOperation("read out of range")
        if hi > lo and hi > self._read_cap(lid):
            raise ForkBlocked("read beyond promotable fork point")
        return log.records[lo:hi]

    def tail(self, lid: int) -> int:
        return len(self._get(lid).records)

    def visible_tail(self, lid: int) -> int:
        """Tail capped by *own* holds (matches Bolt's convenience API; caps
        induced by ancestors are surfaced as ForkBlocked on read instead)."""
        n = len(self._get(lid).records)
        own = [h.fp for h in self.holds if h.parent == lid]
        return min([n] + own)

    def squash(self, lid: int) -> List[int]:
        log = self._get(lid)
        if log.kind == "root":
            raise InvalidOperation("cannot squash root")
        removed = self._subtree(lid)
        if log.kind == "cfork":
            self.logs[log.parent].children.remove(lid)
        removed_set = set(removed)
        self.holds = [h for h in self.holds if h.child not in removed_set
                      and h.parent not in removed_set]
        for h in self.holds:
            for d in removed_set:
                h.caps.pop(d, None)
        for d in removed:
            del self.logs[d]
        return removed

    def promote(self, lid: int) -> bool:
        child = self._get(lid)
        if not child.promotable or child.kind != "cfork":
            raise InvalidOperation("only promotable cForks can be promoted")
        parent = self._get(child.parent)
        if self._append_blocked(parent.log_id):
            raise ForkBlocked(
                "cannot promote into a log blocked by an ancestor's promotable cFork")
        my_hold = next(h for h in self.holds if h.child == lid)
        fp = my_hold.fp
        # squash other promotable siblings
        for h in [h for h in self.holds if h.parent == parent.log_id and h.child != lid]:
            self.squash(h.child)
        # splice the child's post-fp view into the parent and every surviving
        # non-promotable descendant (at its own cap)
        suffix = child.records[fp:]
        for d in self._subtree(parent.log_id):
            if d == lid or d in self._subtree(lid):
                continue
            cap = my_hold.caps.get(d)
            if cap is None:
                continue
            dl = self.logs[d]
            dl.records = dl.records[:cap] + suffix
        # child's children re-parent; child vanishes
        parent.children.remove(lid)
        for c in child.children:
            self.logs[c].parent = parent.log_id
            parent.children.append(c)
        self.holds.remove(my_hold)
        # the child's own holds TRANSFER to the parent: the grandchild's
        # promise now applies to the promoted lineage. Every log that was
        # capped by my_hold becomes capped by the transferred hold at the
        # translated position (its old cap + the transferred hold's offset
        # past the old fork point).
        for h in self.holds:
            if h.parent != lid:
                continue
            h.parent = parent.log_id
            for d, cap in my_hold.caps.items():
                if d in self.logs and d not in h.caps:
                    h.caps[d] = cap + (h.fp - my_hold.fp)
        del self.logs[lid]
        return True


# ---------------------------------------------------------------------------
# Byte-liveness oracle for segment GC (DESIGN.md §13)
# ---------------------------------------------------------------------------

#: Object-id prefixes the brokers use for data-plane PUTs — per-append objects,
#: group-commit segments, and compacted objects (§14). The liveness predicate
#: only judges these: a store shared with e.g. the checkpoint substrate holds
#: other keys.
DATA_OBJECT_PREFIXES = ("obj-", "seg-", "cmp-")


def recount_object_refs(state) -> Dict[str, int]:
    """Brute-force manifest recount: per object, the number of index entries
    referencing it across EVERY log in ``state.logs`` (frozen stand-ins
    included). This is the ground truth the metadata layer's incremental
    ``object_refs`` accounting must equal at every consensus point."""
    refs: Dict[str, int] = {}
    for meta in state.logs.values():
        for obj, n in meta.index.object_refcounts().items():
            refs[obj] = refs.get(obj, 0) + n
    return refs


def recount_object_ref_bytes(state) -> Dict[str, int]:
    """Brute-force §14 twin of :func:`recount_object_refs`: per object, the
    MULTISET sum of referenced byte lengths across every log's index entries
    (a byte referenced by two logs counts twice — matching the incremental
    ``object_ref_bytes`` accounting exactly)."""
    refs: Dict[str, int] = {}
    for meta in state.logs.values():
        for obj, n in meta.index.object_refbytes().items():
            refs[obj] = refs.get(obj, 0) + n
    return refs


def check_manifest_audit(state) -> None:
    """Incremental accounting == from-scratch recount (positive counts; the
    zero entries are candidates awaiting a `gc` command). Covers both the
    §13 entry-count manifests and the §14 byte-granular manifests."""
    want = recount_object_refs(state)
    got = {k: v for k, v in state.object_refs.items() if v > 0}
    assert got == want, (
        f"manifest drift: incremental {got} != recount {want}")
    dead = set(want) & state.reclaimed
    assert not dead, f"reclaimed objects still referenced: {dead}"
    want_b = recount_object_ref_bytes(state)
    got_b = {k: v for k, v in state.object_ref_bytes.items() if v > 0}
    assert got_b == want_b, (
        f"byte-manifest drift: incremental {got_b} != recount {want_b}")
    unsized = set(want_b) - set(state.object_bytes)
    assert not unsized, (
        f"referenced objects with no learned size (§14): {sorted(unsized)}")
    cold_dead = state.cold_objects - set(state.object_refs)
    assert not cold_dead, (
        f"cold-placement records for unknown objects: {sorted(cold_dead)}")


def check_storage_safety(system) -> None:
    """*Safety*: every position readable via any live log's flattened view
    maps to a live object — resolve [0, tail) of every live log (blocking
    checks skipped: withheld positions become readable once holds resolve,
    so GC must already preserve them) and fetch each span from the store."""
    state = system.metadata.state
    for lid in state.live_log_ids():
        tail = state.tails.get(lid)[0]
        try:
            spans = state.read_spans(lid, 0, tail, _skip_checks=True)
        except UnknownLog as e:
            raise AssertionError(
                f"live log {lid} has unresolvable positions: {e}") from e
        for obj, off, ln in spans:
            assert obj not in state.reclaimed, (
                f"log {lid} resolves into reclaimed object {obj}")
            try:
                blob = system.store.get(obj, off, ln)
            except Exception as e:
                raise AssertionError(
                    f"log {lid} span ({obj},{off},{ln}) unreadable: {e}") from e
            assert len(blob) == ln, (
                f"log {lid} span ({obj},{off},{ln}) truncated to {len(blob)}")


def _index_spans(index):
    """Every (object, offset, length) byte span an index references —
    introspected from scratch (RunIndex runs or NaiveIndex entries), not via
    the manifests under audit."""
    runs = getattr(index, "_runs", None)
    if runs is not None:
        for r in runs:
            for i in range(r.n):
                yield r.object_id, int(r.offsets[i]), int(r.lengths[i])
        return
    for obj, off, ln in getattr(index, "entries", {}).values():
        yield obj, off, ln


def live_byte_union(state) -> Dict[str, int]:
    """Per object: the size of the UNION of all referenced byte spans across
    every log (frozen stand-ins included). Unlike the multiset
    ``object_ref_bytes``, a byte shared by N logs counts once — this is the
    floor of what storage must physically hold, so it is the denominator of
    the §14 amplification bound."""
    spans_by_obj: Dict[str, List[Tuple[int, int]]] = {}
    for meta in state.logs.values():
        for obj, off, ln in _index_spans(meta.index):
            if ln > 0:
                spans_by_obj.setdefault(obj, []).append((off, off + ln))
    out: Dict[str, int] = {}
    for obj, spans in spans_by_obj.items():
        spans.sort()
        total = 0
        cur_lo, cur_hi = spans[0]
        for lo, hi in spans[1:]:
            if lo <= cur_hi:
                cur_hi = max(cur_hi, hi)
            else:
                total += cur_hi - cur_lo
                cur_lo, cur_hi = lo, hi
        total += cur_hi - cur_lo
        out[obj] = total
    return out


def check_storage_liveness(system,
                           max_byte_amplification: Optional[float] = None) -> None:
    """*Liveness* (call after GC drains with no pins): reclaimed == dead —
    the store holds exactly the data objects some log still references, and
    nothing with zero references survived the drain.

    With ``max_byte_amplification`` set, additionally asserts the §14 bound
    at BYTE granularity: total logical data bytes resident in the store may
    exceed the live-byte union (dead bytes inside partially-live shared
    segments) by at most that factor. The §13 object-level predicate alone
    cannot see this leak — a group-commit segment with one live record is
    fully "live" to it."""
    state = system.metadata.state
    pending = state.gc_pending()
    assert pending == 0, f"{pending} dead objects not reclaimed after drain"
    live = {obj for obj, n in recount_object_refs(state).items() if n > 0}
    in_store = {k for k in system.store.list()
                if k.startswith(DATA_OBJECT_PREFIXES)}
    leaked = in_store - live
    assert not leaked, f"unreferenced objects survived GC: {sorted(leaked)}"
    lost = live - in_store
    assert not lost, f"referenced objects missing from store: {sorted(lost)}"
    if max_byte_amplification is None:
        return
    union = live_byte_union(state)
    live_bytes = sum(n for obj, n in union.items()
                     if obj.startswith(DATA_OBJECT_PREFIXES))
    stored_bytes = sum(system.store.size(k) or 0 for k in in_store)
    if live_bytes == 0:
        assert stored_bytes == 0, (
            f"no live bytes but {stored_bytes} data bytes resident")
        return
    amplification = stored_bytes / live_bytes
    assert amplification <= max_byte_amplification, (
        f"storage amplification {amplification:.3f}x exceeds the "
        f"{max_byte_amplification:.3f}x bound: {stored_bytes} resident data "
        f"bytes over {live_bytes} live (union) bytes")
