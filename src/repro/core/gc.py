"""Lineage-aware segment garbage collection (DESIGN.md §13).

Bolt's cheap forks share immutable segment objects, so nothing at append or
fork time ever owns an object — and nothing ever deleted one. Agentic churn
(speculate → conflict → squash → re-fork, §12) therefore stranded dead
segments in shared storage forever. The subsystem splits reclamation into the
two planes the rest of Bolt already uses:

* **Metadata (consensus) decides.** :class:`~repro.core.metadata.MetadataState`
  maintains per-object *manifests* — a refcount over every index entry in
  every log, frozen stand-ins included. Dead-lineage events (squash, promote,
  frozen-chain GC) decrement them in consensus order; the ``gc`` SMR command
  pops zero-reference candidates into the replicated ``reclaimed`` set. Every
  replica — including a follower restored from a snapshot — converges on the
  identical reclaimed set.

* **A broker-side reaper executes.** :class:`GarbageCollector` proposes ``gc``
  quanta, applies the returned deletes to the shared :class:`ObjectStore`,
  invalidates the affected pages in every broker's
  :class:`~repro.core.objectstore.LRUObjectCache`, and books DES time on its
  own broker (``book_reclaim``) so isolation benchmarks can show reclamation
  does not perturb the latency-critical path.

The **pin registry** closes the one liveness gap refcounts cannot see: a
session rebase (§12) squashes its stale fork — dropping the suffix segments'
refcounts, possibly to zero — *before* replaying them into the fresh fork.
The receipts' durable segment references live outside any index during that
window, so the session pins the object ids; pins ride INTO the ``gc``
proposal as command arguments (hence deterministic across replicas) and
pinned candidates are requeued, not reclaimed.

Crash safety: metadata commits the reclaimed set first, then the reaper
deletes. A reaper that dies mid-reap leaves already-reclaimed objects in the
store; ``resync()`` replays ``reclaimed ∩ store`` (deletes are idempotent),
so a restarted broker converges the store to the consensus decision.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional


@dataclass
class GCConfig:
    """Reaper policy (DESIGN.md §13).

    ``batch`` bounds the objects reclaimed per quantum (one ``gc`` proposal);
    ``auto`` runs a quantum on churn hand-off points — session abort,
    ``AgileLog.close()``, explicit squash/promote — so reclamation keeps pace
    with speculation without a caller ever draining manually. ``broker``
    selects which broker books the reap time (default: the last one, which
    placement never assigns a root log to)."""

    batch: int = 64
    auto: bool = False
    broker: Optional[int] = None


@dataclass
class GCStats:
    """Reclamation counters + a point-in-time backlog snapshot."""

    runs: int = 0                # explicit collect() drains
    quanta: int = 0              # gc proposals issued
    objects_reclaimed: int = 0
    bytes_reclaimed: int = 0
    pages_invalidated: int = 0   # broker cache pages dropped by reaps
    resyncs: int = 0             # crash-recovery store replays
    pending: int = 0             # zero-ref candidates awaiting a quantum (snapshot)
    tracked: int = 0             # objects with live references (snapshot)
    pinned: int = 0              # object ids pinned by in-flight rebases (snapshot)


class GarbageCollector:
    """The broker-side reaper: proposes ``gc`` quanta, applies the deletes."""

    def __init__(self, system, config: Optional[GCConfig] = None) -> None:
        self.system = system
        self.config = config or GCConfig()
        self._pins: Dict[str, int] = {}   # object id -> pin count
        self._orphans: set = set()        # crashed-broker PUT carcasses (§15)
        self._stats = GCStats()

    # -- pins (session rebase protection, §12/§13) --------------------------
    def pin(self, object_ids: Iterable[str]) -> None:
        for obj in object_ids:
            self._pins[obj] = self._pins.get(obj, 0) + 1

    def unpin(self, object_ids: Iterable[str]) -> None:
        for obj in object_ids:
            left = self._pins.get(obj, 0) - 1
            if left <= 0:
                self._pins.pop(obj, None)
            else:
                self._pins[obj] = left

    # -- reclamation --------------------------------------------------------
    def _reaper_broker(self):
        brokers = self.system.brokers
        i = self.config.broker
        return brokers[i if i is not None else len(brokers) - 1]

    def _reap(self, dead: List[str], arrival: Optional[float]) -> int:
        """Apply consensus-decided deletes to the store + broker caches."""
        store = self.system.store
        freed = 0
        pages = 0
        for obj in dead:
            size = store.size(obj)
            freed += size or 0
            store.delete(obj)
            for b in self.system.brokers:
                pages += b.cache.invalidate_object(obj)
        self._stats.objects_reclaimed += len(dead)
        self._stats.bytes_reclaimed += freed
        self._stats.pages_invalidated += pages
        self._reaper_broker().book_reclaim(arrival, len(dead))
        return freed

    def _propose_and_reap(self, limit: Optional[int],
                          arrival: Optional[float]) -> List[str]:
        dead = self.system.metadata.propose(
            ("gc", limit, tuple(sorted(self._pins))))
        self._stats.quanta += 1
        self._reap(dead, arrival)
        return dead

    def quantum(self, limit: Optional[int] = None,
                arrival: Optional[float] = None) -> List[str]:
        """One incremental GC step: propose a ``gc`` command reclaiming up to
        ``limit`` (default ``config.batch``) objects, then reap them. Returns
        the reclaimed object ids (possibly empty)."""
        return self._propose_and_reap(
            self.config.batch if limit is None else limit, arrival)

    def collect(self, arrival: Optional[float] = None) -> GCStats:
        """Drain: reclaim every currently-dead object in one UNBOUNDED
        quantum — ``config.batch`` only paces incremental ``quantum()``
        steps, never a drain (pinned candidates stay queued either way)."""
        self._stats.runs += 1
        self._propose_and_reap(None, arrival)
        return self.stats()

    def note_orphans(self, object_ids: Iterable[str]) -> None:
        """Record PUT carcasses from a crashed broker (DESIGN.md §15): keys
        written (possibly torn) to the store whose metadata proposal never
        committed. ``resync`` deletes the ones consensus never registered."""
        self._orphans.update(object_ids)

    def resync(self, arrival: Optional[float] = None) -> List[str]:
        """Crash recovery for a reaper that died between the ``gc`` commit
        and the store deletes: re-apply the replicated reclaimed set to the
        store (idempotent), and sweep crashed-broker orphan PUTs (§15) —
        noted keys that consensus never registered (not in ``object_refs``,
        not already reclaimed) are garbage by definition: no index entry can
        ever reference them. Run this when a broker restarts."""
        state = self.system.metadata.state
        stale = [obj for obj in sorted(state.reclaimed)
                 if self.system.store.exists(obj)]
        for b in self.system.brokers:   # live brokers note torn PUTs too
            self._orphans.update(b.take_orphans())
        swept = [obj for obj in sorted(self._orphans)
                 if obj not in state.object_refs
                 and obj not in state.reclaimed
                 and self.system.store.exists(obj)]
        self._orphans.clear()
        self._stats.resyncs += 1
        self._reap(stale + swept, arrival)
        return stale + swept

    def stats(self) -> GCStats:
        s = self._stats
        state = self.system.metadata.state
        return GCStats(runs=s.runs, quanta=s.quanta,
                       objects_reclaimed=s.objects_reclaimed,
                       bytes_reclaimed=s.bytes_reclaimed,
                       pages_invalidated=s.pages_invalidated,
                       resyncs=s.resyncs,
                       pending=state.gc_pending(),
                       tracked=state.gc_tracked(),
                       pinned=len(self._pins))
