"""The AgileLog abstraction (Fig. 1) and the Bolt system wiring it together.

``BoltSystem`` owns the shared object store, a broker pool, and the replicated
metadata service. ``AgileLog`` is the client handle implementing the paper's
interface verbatim::

    interface AgileLog:
      Position append(Record r);
      List<Record> read(Position from, Position to);
      AgileLog cFork(promotable = false);
      AgileLog sFork(optional Position past);
      bool promote();
      void squash();

Fork placement policy (§5.7): a fork is served by a broker *different from its
parent's* (performance isolation) but forks of the same parent are co-located
(cache reuse, less metadata-layer load) unless ``dedicated=True``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .broker import Broker
from .errors import InvalidOperation
from .objectstore import MemoryObjectStore, ObjectStore
from .raft import MetadataService


class BoltSystem:
    def __init__(self, n_brokers: int = 4, store: Optional[ObjectStore] = None,
                 n_meta_replicas: int = 3, snapshot_every: int = 0,
                 cf_mode: str = "ltt", fork_mode: str = "zerocopy",
                 promote_mode: str = "copy") -> None:
        self.store = store if store is not None else MemoryObjectStore()
        self.metadata = MetadataService(
            n_replicas=n_meta_replicas, snapshot_every=snapshot_every,
            cf_mode=cf_mode, fork_mode=fork_mode, promote_mode=promote_mode)
        self.brokers = [Broker(i, self.store, self.metadata)
                        for i in range(max(2, n_brokers))]
        self._fork_broker: Dict[int, int] = {}   # parent log -> broker for its forks
        self._next_broker = 1

    # -- placement ----------------------------------------------------------------
    def _broker_for_root(self) -> Broker:
        return self.brokers[0]

    def _broker_for_fork(self, parent_log: int, parent_broker: int,
                         dedicated: bool) -> Broker:
        if dedicated:
            b = self._next_broker
            self._next_broker = (self._next_broker % (len(self.brokers) - 1)) + 1
            if b == parent_broker:
                b = (b % (len(self.brokers) - 1)) + 1
            return self.brokers[b]
        b = self._fork_broker.get(parent_log)
        if b is None or b == parent_broker:
            b = self._next_broker
            self._next_broker = (self._next_broker % (len(self.brokers) - 1)) + 1
            if b == parent_broker:
                b = (b % (len(self.brokers) - 1)) + 1
            self._fork_broker[parent_log] = b
        return self.brokers[b]

    # -- entry point ----------------------------------------------------------------
    def create_log(self, name: str) -> "AgileLog":
        log_id = self.metadata.propose(("create_root", name))
        return AgileLog(self, log_id, self._broker_for_root())

    # -- broker failover (straggler mitigation, DESIGN.md §6) -----------------------
    def fail_broker(self, broker_id: int) -> None:
        """Mark a broker dead; clients transparently re-route (brokers are
        stateless — §5.2 — so reassignment is metadata-free; the object cache
        is the only loss)."""
        self._dead = getattr(self, "_dead", set())
        self._dead.add(broker_id)
        for parent, b in list(self._fork_broker.items()):
            if b == broker_id:
                del self._fork_broker[parent]

    def live_broker(self, preferred: Broker) -> Broker:
        dead = getattr(self, "_dead", set())
        if preferred.broker_id not in dead:
            return preferred
        for b in self.brokers:
            if b.broker_id not in dead:
                return b
        raise RuntimeError("no live brokers")


class AgileLog:
    """Client handle for one log (root or fork). Figure 1's interface."""

    def __init__(self, system: BoltSystem, log_id: int, broker: Broker) -> None:
        self.system = system
        self.log_id = log_id
        self.broker = broker

    # -- traditional shared-log API --------------------------------------------------
    def _b(self) -> Broker:
        """Current broker, re-routed if ours failed (stateless brokers)."""
        b = self.system.live_broker(self.broker)
        if b is not self.broker:
            self.broker = b
        return b

    def append(self, record: bytes) -> Optional[int]:
        positions, _ = self._b().append(self.log_id, [record])
        return None if positions is None else positions[0]

    def append_batch(self, records: Sequence[bytes]) -> Optional[List[int]]:
        positions, _ = self._b().append(self.log_id, list(records))
        return positions

    def read(self, lo: int, hi: int) -> List[bytes]:
        return self._b().read_records(self.log_id, lo, hi)

    @property
    def tail(self) -> int:
        return self.system.metadata.state.tail(self.log_id)

    @property
    def visible_tail(self) -> int:
        return self.system.metadata.state.visible_tail(self.log_id)

    # -- forking -----------------------------------------------------------------------
    def cfork(self, promotable: bool = False, dedicated: bool = False) -> "AgileLog":
        child_id = self.system.metadata.propose(("cfork", self.log_id, promotable))
        broker = self.system._broker_for_fork(self.log_id, self.broker.broker_id,
                                              dedicated)
        return AgileLog(self.system, child_id, broker)

    def sfork(self, past: Optional[int] = None, dedicated: bool = False) -> "AgileLog":
        child_id = self.system.metadata.propose(("sfork", self.log_id, past))
        broker = self.system._broker_for_fork(self.log_id, self.broker.broker_id,
                                              dedicated)
        return AgileLog(self.system, child_id, broker)

    def promote(self, mode: Optional[str] = None) -> bool:
        return self.system.metadata.propose(("promote", self.log_id, mode))

    def squash(self) -> None:
        self.system.metadata.propose(("squash", self.log_id))

    def __repr__(self) -> str:
        return f"AgileLog(id={self.log_id}, broker={self.broker.broker_id})"
