"""The AgileLog abstraction (Fig. 1) and the Bolt system wiring it together.

``BoltSystem`` owns the shared object store, a broker pool, and the replicated
metadata service. ``AgileLog`` is the client handle implementing the paper's
interface verbatim::

    interface AgileLog:
      Position append(Record r);
      List<Record> read(Position from, Position to);
      AgileLog cFork(promotable = false);
      AgileLog sFork(optional Position past);
      bool promote();
      void squash();

Fork placement policy (§5.7): a fork is served by a broker *different from its
parent's* (performance isolation) but forks of the same parent are co-located
(cache reuse, less metadata-layer load) unless ``dedicated=True``.

Group commit (DESIGN.md §9) is opt-in via ``BoltSystem(group_commit=...)``:
``True`` for defaults, an int for a record-count flush threshold, or a full
:class:`~repro.core.broker.GroupCommitConfig`. With it on, ``append`` /
``append_batch`` return :class:`~repro.core.broker.PendingAppend` handles that
resolve at flush commit; ``BoltSystem.flush()`` (or leaving the system's
``with`` block) commits all staged records, and reads of a staged log flush
first, so read-your-writes is preserved. Default-off callers are unchanged.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Union

from .broker import Broker, GroupCommitConfig, PendingAppend
from .errors import InvalidOperation
from .objectstore import MemoryObjectStore, ObjectStore
from .raft import MetadataService


class BoltSystem:
    def __init__(self, n_brokers: int = 4, store: Optional[ObjectStore] = None,
                 n_meta_replicas: int = 3, snapshot_every: int = 0,
                 cf_mode: str = "ltt", fork_mode: str = "zerocopy",
                 promote_mode: str = "copy",
                 group_commit: Union[None, bool, int, GroupCommitConfig] = None,
                 cache_bytes: int = 64 << 20,
                 cache_page_bytes: int = 64 << 10,
                 readahead_bytes: int = 256 << 10,
                 view_cache: bool = True,
                 pipeline_apply: bool = True) -> None:
        if group_commit is True:
            group_commit = GroupCommitConfig()
        elif group_commit is False or group_commit == 0:
            group_commit = None   # falsy: group commit off
        elif isinstance(group_commit, int):
            if group_commit < 0:
                raise ValueError(f"group_commit batch size must be >= 0, got {group_commit}")
            group_commit = GroupCommitConfig(max_records=group_commit)
        elif group_commit is not None and not isinstance(group_commit, GroupCommitConfig):
            raise TypeError(f"group_commit must be None, bool, int, or "
                            f"GroupCommitConfig, got {type(group_commit).__name__}")
        self.group_commit: Optional[GroupCommitConfig] = group_commit
        self.store = store if store is not None else MemoryObjectStore()
        self.metadata = MetadataService(
            n_replicas=n_meta_replicas, snapshot_every=snapshot_every,
            pipeline_apply=pipeline_apply,
            cf_mode=cf_mode, fork_mode=fork_mode, promote_mode=promote_mode,
            view_cache=view_cache)
        self.brokers = [Broker(i, self.store, self.metadata,
                               cache_bytes=cache_bytes,
                               cache_page_bytes=cache_page_bytes,
                               readahead_bytes=readahead_bytes,
                               group_commit=group_commit)
                        for i in range(max(2, n_brokers))]
        self._fork_broker: Dict[int, int] = {}   # parent log -> broker for its forks
        self._next_broker = 1

    # -- group commit (DESIGN.md §9) ------------------------------------------------
    def flush(self) -> None:
        """Commit every broker's staging buffer (no-op when group commit is off)."""
        for b in self.brokers:
            b.flush()

    def __enter__(self) -> "BoltSystem":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # only flush on clean exit: a failing flush must not mask the body's
        # in-flight exception (staged records were never acked; the caller can
        # still flush() manually after handling the error)
        if exc_type is None:
            self.flush()

    # -- placement ----------------------------------------------------------------
    def _broker_for_root(self) -> Broker:
        return self.brokers[0]

    def _pick_fork_broker(self, parent_broker: int) -> int:
        """Next round-robin broker that is NOT the parent's and is live.

        The seed's re-map ``(b % (len-1)) + 1`` could land back on
        ``parent_broker`` (e.g. 2 brokers, parent on broker 1), silently
        violating the isolation placement rule — so after the round-robin
        pass, fall back to an explicit search over every other live broker
        (including broker 0) before giving up and co-locating."""
        n = len(self.brokers)
        dead = getattr(self, "_dead", set())
        for _ in range(max(1, n - 1)):
            b = self._next_broker
            self._next_broker = (self._next_broker % (n - 1)) + 1
            if b != parent_broker and b not in dead:
                return b
        for b in range(n):
            if b != parent_broker and b not in dead:
                return b
        return parent_broker   # degenerate: no other live broker exists

    def _broker_for_fork(self, parent_log: int, parent_broker: int,
                         dedicated: bool) -> Broker:
        if dedicated:
            return self.brokers[self._pick_fork_broker(parent_broker)]
        b = self._fork_broker.get(parent_log)
        if b is None or b == parent_broker:
            b = self._pick_fork_broker(parent_broker)
            self._fork_broker[parent_log] = b
        return self.brokers[b]

    # -- entry point ----------------------------------------------------------------
    def create_log(self, name: str) -> "AgileLog":
        log_id = self.metadata.propose(("create_root", name))
        return AgileLog(self, log_id, self._broker_for_root())

    # -- broker failover (straggler mitigation, DESIGN.md §6) -----------------------
    def fail_broker(self, broker_id: int) -> None:
        """Mark a broker dead; clients transparently re-route (brokers are
        stateless — §5.2 — so reassignment is metadata-free; the object cache
        and any *unflushed* group-commit staging — records that were never
        acked — are the only loss)."""
        self._dead = getattr(self, "_dead", set())
        self._dead.add(broker_id)
        self.brokers[broker_id].discard_staging()
        for parent, b in list(self._fork_broker.items()):
            if b == broker_id:
                del self._fork_broker[parent]

    def live_broker(self, preferred: Broker) -> Broker:
        dead = getattr(self, "_dead", set())
        if preferred.broker_id not in dead:
            return preferred
        for b in self.brokers:
            if b.broker_id not in dead:
                return b
        raise RuntimeError("no live brokers")


class AgileLog:
    """Client handle for one log (root or fork). Figure 1's interface."""

    def __init__(self, system: BoltSystem, log_id: int, broker: Broker) -> None:
        self.system = system
        self.log_id = log_id
        self.broker = broker

    # -- traditional shared-log API --------------------------------------------------
    def _b(self) -> Broker:
        """Current broker, re-routed if ours failed (stateless brokers)."""
        b = self.system.live_broker(self.broker)
        if b is not self.broker:
            self.broker = b
        return b

    def _sync(self) -> Broker:
        """Broker handle with this log's staged records committed: metadata
        operations (tails, forks, promote, squash) must observe the caller's
        own prior appends (read-your-writes, DESIGN.md §9), so they flush a
        staging buffer holding records of this log first."""
        b = self._b()
        b._flush_if_staged(self.log_id)
        return b

    def append(self, record: bytes) -> Union[Optional[int], PendingAppend]:
        """Per-call mode: returns the assigned position (None when withheld,
        §4.1). Group-commit mode: stages the record and returns a
        :class:`PendingAppend` — ``result()[0]`` after flush is the position."""
        if self.system.group_commit is not None:
            return self._b().stage(self.log_id, [record])
        positions, _ = self._b().append(self.log_id, [record])
        return None if positions is None else positions[0]

    def append_batch(self, records: Sequence[bytes]
                     ) -> Union[Optional[List[int]], PendingAppend]:
        if self.system.group_commit is not None:
            return self._b().stage(self.log_id, list(records))
        positions, _ = self._b().append(self.log_id, list(records))
        return positions

    def flush(self) -> None:
        """Commit this log's broker staging buffer (group commit, DESIGN.md §9)."""
        self._b().flush()

    def read(self, lo: int, hi: int) -> List[bytes]:
        records, _ = self._b().read_records(self.log_id, lo, hi)
        return records

    def scan(self, lo: int = 0, hi: Optional[int] = None,
             batch: int = 1024) -> Iterator[bytes]:
        """Stream records [lo, hi) in position order (DESIGN.md §10).

        The agent catch-up pattern: one metadata resolution + one
        scatter-gather ranged-GET round per ``batch`` positions, with the
        broker cache's sequential readahead prefetching ahead of the cursor —
        instead of a chain walk and a GET per record. ``hi=None`` snapshots
        the visible tail when ``scan`` is called; records appended afterwards
        are not included. Validation is eager (this returns a generator, but
        bad ``batch``/bounds raise here, at the call site, exactly as
        ``read`` would)."""
        if batch <= 0:
            raise InvalidOperation(f"scan batch must be positive, got {batch}")
        self._sync()
        state = self.system.metadata.state
        if hi is None:
            hi = state.visible_tail(self.log_id)
        tail = state.tail(self.log_id)
        if not (0 <= lo <= hi <= tail):
            raise InvalidOperation(f"scan [{lo},{hi}) out of range (tail {tail})")
        return self._scan_iter(lo, hi, batch)

    def _scan_iter(self, lo: int, hi: int, batch: int) -> Iterator[bytes]:
        pos = lo
        while pos < hi:
            chunk_hi = min(pos + batch, hi)
            records, _ = self._b().read_records(self.log_id, pos, chunk_hi)
            yield from records
            pos = chunk_hi

    @property
    def tail(self) -> int:
        self._sync()
        return self.system.metadata.state.tail(self.log_id)

    @property
    def visible_tail(self) -> int:
        self._sync()
        return self.system.metadata.state.visible_tail(self.log_id)

    # -- forking -----------------------------------------------------------------------
    def cfork(self, promotable: bool = False, dedicated: bool = False) -> "AgileLog":
        self._sync()
        child_id = self.system.metadata.propose(("cfork", self.log_id, promotable))
        broker = self.system._broker_for_fork(self.log_id, self.broker.broker_id,
                                              dedicated)
        return AgileLog(self.system, child_id, broker)

    def sfork(self, past: Optional[int] = None, dedicated: bool = False) -> "AgileLog":
        self._sync()
        child_id = self.system.metadata.propose(("sfork", self.log_id, past))
        broker = self.system._broker_for_fork(self.log_id, self.broker.broker_id,
                                              dedicated)
        return AgileLog(self.system, child_id, broker)

    def promote(self, mode: Optional[str] = None) -> bool:
        self._sync()
        return self.system.metadata.propose(("promote", self.log_id, mode))

    def squash(self) -> None:
        self._sync()
        self.system.metadata.propose(("squash", self.log_id))

    def __repr__(self) -> str:
        return f"AgileLog(id={self.log_id}, broker={self.broker.broker_id})"
