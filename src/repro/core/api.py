"""The agent-session client API (DESIGN.md §12) over the Bolt system.

``BoltSystem`` owns the shared object store, a broker pool, and the replicated
metadata service. ``AgileLog`` is the client handle; it keeps the paper's
Fig. 1 surface (append / read / cFork / sFork / promote / squash) but layers
the three session primitives agents actually program against:

* **Unified receipts** — ``append``/``append_batch`` ALWAYS return an
  :class:`AppendReceipt`: resolved immediately in per-call mode, at flush in
  group-commit mode, with ``position()``/``positions()``/``wait()`` and the
  §4.1 ``withheld`` state. The old mode-dependent
  ``Union[Optional[int], PendingAppend]`` is gone; ``PendingAppend`` is a
  broker-internal detail. A thin legacy shim (``result()``, ``==``/indexing
  against raw positions) keeps pre-§12 callers running, with a
  ``DeprecationWarning`` so CI can ban it in-tree.

* **Speculation sessions** — ``log.speculate()`` wraps the paper's agentic
  validate-then-commit loop (cFork → validate → promote-or-squash) into one
  context-managed transaction: ``commit()`` promotes atomically via the
  metadata layer's conditional ``promote_if`` and auto-rebases onto a fresh
  cFork when the parent advanced, replaying the speculative suffix zero-copy
  (the bytes are already durable — only metadata is re-sequenced); bounded
  retries raise :class:`~repro.core.errors.ConflictError` with fork-point
  diagnostics. ``abort()`` squashes (implicit on exception or unclosed exit).

* **Tailing subscriptions** — ``log.subscribe(from_pos=...)`` yields record
  batches as the visible tail advances: a cooperative poll-with-backoff
  inside, push-shaped iteration outside. The streams layer's consumers are
  built on it.

Fork placement policy (§5.7): a fork is served by a broker *different from its
parent's* (performance isolation) but forks of the same parent are co-located
(cache reuse, less metadata-layer load) unless ``dedicated=True``.

Group commit (DESIGN.md §9) is opt-in via ``BoltSystem(group_commit=...)``:
``True`` for defaults, an int for a record-count flush threshold, or a full
:class:`~repro.core.broker.GroupCommitConfig`. ``BoltSystem.flush()`` (or
leaving the system's ``with`` block) commits all staged records;
``AgileLog.flush()`` commits only this log's staged records; reads of a staged
log flush first, so read-your-writes is preserved.
"""

from __future__ import annotations

import tempfile
import time
import warnings
from dataclasses import dataclass
from typing import (Callable, Dict, Iterator, List, Optional, Sequence, Set,
                    Union)

from .broker import Broker, GroupCommitConfig, PendingAppend
from .compact import (Compactor, CompactionConfig, CompactStats, TierManager,
                      TieringConfig, TierStats)
from .errors import (AgileLogError, BrokerCrashed, ConflictError,
                     InvalidOperation, NoLiveBrokers, UnknownLog)
from .faults import (FaultConfig, FaultPlane, RetryPolicy, RetryStats,
                     run_with_retries)
from .gc import GarbageCollector, GCConfig, GCStats
from .objectstore import (FileObjectStore, MemoryObjectStore, ObjectStore,
                          RangedStore, TieredObjectStore)
from .raft import MetadataService
from .sim import ServeStats, SpecStats


def _legacy(old: str, new: str) -> None:
    warnings.warn(
        f"AppendReceipt.{old} is a pre-§12 compatibility shim; use {new} "
        "instead (DESIGN.md §12, README migration table)",
        DeprecationWarning, stacklevel=3)


class AppendReceipt:
    """Unified ack for one ``append``/``append_batch`` call (DESIGN.md §12).

    One type for both append modes: in per-call mode the receipt is born
    resolved; in group-commit mode it resolves when the owning broker's
    staging buffer flushes. ``wait()`` forces resolution (flushing if
    needed) and raises the append's deterministic error, if any — in
    per-call mode that error already raised at the append call site.

    ``positions()`` is ``None`` when the records committed but an active
    promotable cFork withholds their positions (§4.1) — ``withheld`` spells
    that state out.
    """

    __slots__ = ("_pending",)

    def __init__(self, pending: PendingAppend) -> None:
        self._pending = pending

    # -- the new surface -----------------------------------------------------
    @property
    def count(self) -> int:
        """How many records this receipt acknowledges."""
        return self._pending.n

    @property
    def done(self) -> bool:
        """Resolved yet? (Never forces a flush.)"""
        return self._pending.done

    def wait(self) -> "AppendReceipt":
        """Force resolution: flush the owning broker if still staged, raise
        the deterministic append error if there was one, return self."""
        p = self._pending
        if not p.done:
            fleet = p.broker.fleet
            if fleet is not None:
                # route through the fleet's retry layer (§15): if the owning
                # broker crashes mid-flush, failover re-points p.broker at
                # the adopter and the retry flushes THERE — the receipt
                # resolves with the surviving positions
                fleet._retrying(
                    lambda _a: None if p.done else p.broker.flush())
            else:
                p.broker.flush()
        if p._error is not None:
            raise p._error
        return self

    def positions(self) -> Optional[List[int]]:
        """All assigned positions (waits), or ``None`` when withheld (§4.1)."""
        self.wait()
        p = self._pending._positions
        return None if p is None else list(p)

    def position(self) -> Optional[int]:
        """Position of the first record (waits); ``None`` when withheld."""
        p = self.positions()
        return None if p is None else p[0]

    @property
    def withheld(self) -> bool:
        """True iff committed but positions are hidden by an active
        promotable cFork on the appended log (§4.1). Waits."""
        self.wait()
        return self._pending._positions is None

    def __repr__(self) -> str:
        p = self._pending
        state = ("staged" if not p.done
                 else "failed" if p._error is not None
                 else "withheld" if p._positions is None
                 else f"positions={p._positions}")
        return f"AppendReceipt(log={p.log_id}, n={p.n}, {state})"

    # -- legacy shim (pre-§12 call sites; DeprecationWarning) ----------------
    def result(self) -> Optional[List[int]]:
        _legacy("result()", "wait()/positions()")
        return self.positions()

    def __eq__(self, other: object):
        if isinstance(other, AppendReceipt):
            return self is other
        _legacy("== <raw positions>", "position()/positions()")
        if other is None or isinstance(other, (list, tuple)):
            p = self.positions()
            return (p is None) if other is None else p == list(other)
        if isinstance(other, int):
            return self.position() == other
        return NotImplemented

    __hash__ = object.__hash__

    def __getitem__(self, i: int) -> int:
        _legacy("[...] indexing", "positions()")
        p = self.positions()
        if p is None:
            raise TypeError("positions withheld by a promotable cFork (§4.1)")
        return p[i]

    def __iter__(self) -> Iterator[int]:
        _legacy("iteration", "positions()")
        p = self.positions()
        return iter(p if p is not None else ())


class Subscription:
    """Tailing subscription over one log (DESIGN.md §12).

    Push-shaped on the outside — ``for batch in log.subscribe(...)`` yields
    lists of records in position order as the visible tail advances — and a
    cooperative poll with exponential backoff on the inside. ``poll()`` is
    the non-blocking single step (the streams-layer ``Consumer`` builds on
    it); iteration wraps it:

    * ``follow=False`` — drain mode: stop at the first poll that finds the
      subscription caught up with the visible tail.
    * ``follow=True``  — tail mode: on an idle poll call ``backoff(n_idle)``
      (default: bounded exponential ``time.sleep``) and retry; an optional
      ``max_idle`` bounds consecutive idle polls before stopping.

    The cursor (``position``) only moves on delivery, so a subscription is
    also an exact resume token; reads beyond a promotable hold surface the
    usual §4.1 ``ForkBlocked`` rather than silently stalling.
    """

    def __init__(self, log: "AgileLog", from_pos: int = 0, batch: int = 1024,
                 follow: bool = True, max_idle: Optional[int] = None,
                 backoff: Optional[Callable[[int], None]] = None) -> None:
        if batch <= 0:
            raise InvalidOperation(f"subscribe batch must be positive, got {batch}")
        if from_pos < 0:
            raise InvalidOperation(f"subscribe from_pos must be >= 0, got {from_pos}")
        self.log = log
        self.position = from_pos
        self.batch = batch
        self.follow = follow
        self.max_idle = max_idle
        self._backoff = backoff if backoff is not None else self._default_backoff
        self._idle = 0
        self.polls = 0
        self.idle_polls = 0
        self.delivered = 0

    @staticmethod
    def _default_backoff(idle: int) -> None:
        time.sleep(min(0.0005 * (1 << min(idle, 7)), 0.05))

    def poll(self, max_records: Optional[int] = None) -> List[bytes]:
        """One cooperative poll: up to ``max_records`` (default: ``batch``)
        records at/after the cursor, ``[]`` when caught up. Never blocks."""
        limit = self.batch if max_records is None else max_records
        self.polls += 1
        hi = min(self.log.visible_tail, self.position + limit)
        if hi <= self.position:
            self.idle_polls += 1
            return []
        records = self.log.read(self.position, hi)
        self.position = hi
        self.delivered += len(records)
        return records

    def __iter__(self) -> "Subscription":
        self._idle = 0      # each iteration round gets a fresh idle budget
        return self

    def __next__(self) -> List[bytes]:
        while True:
            records = self.poll()
            if records:
                self._idle = 0
                return records
            if not self.follow:
                raise StopIteration
            self._idle += 1
            if self.max_idle is not None and self._idle >= self.max_idle:
                # reset so a resumed round (the cursor is a resume token)
                # polls max_idle times again instead of stopping instantly
                self._idle = 0
                raise StopIteration
            self._backoff(self._idle)


@dataclass(frozen=True)
class CommitResult:
    """Outcome of a successful ``Speculation.commit()`` (DESIGN.md §12)."""

    log_id: int          # the parent the suffix was committed into
    base: int            # parent position the suffix starts at
    count: int           # suffix records committed
    attempts: int        # promote_if proposals issued (1 + rebases survived)
    rebases: int         # auto-rebases performed over the session's lifetime
    replayed: int        # records re-sequenced by those rebases (zero-copy)

    @property
    def positions(self) -> range:
        """Final positions of the speculative suffix in the parent."""
        return range(self.base, self.base + self.count)


class Speculation:
    """A speculative fork transaction (DESIGN.md §12) — the paper's agentic
    validate-then-commit loop as one primitive.

    Opening a speculation cForks the parent (promotable by default, which
    holds the parent per §4.1: producers keep appending but positions are
    withheld and non-exempt readers cap at the fork point). The handle
    proxies ``append``/``append_batch`` (recording the speculative suffix),
    ``read``/``scan``/``subscribe``/tails onto the fork, then:

    * ``commit()`` proposes the metadata layer's atomic ``promote_if``. If
      the parent advanced past what this session validated, the commit
      **auto-rebases**: squash the stale fork, cFork afresh (the new fork
      point now covers the parent's new records), replay the suffix
      zero-copy (metadata-only re-appends of the already-durable bytes), and
      re-propose — at most ``max_rebases`` times before raising
      :class:`ConflictError` with the metadata layer's fork-point/tail
      diagnostics. An optional ``on_rebase(spec, lo, hi)`` hook sees each
      rebase with the parent's delta at fork positions ``[lo, hi)`` — return
      ``False`` to veto (abort + ``ConflictError``). Losing a promote race
      to a sibling speculation (the first promote squashes us) is handled
      as a conflict too.
    * ``abort()`` squashes the fork. Exiting the ``with`` block on an
      exception — or without having committed — aborts implicitly: an
      uncommitted speculation must not keep holding its parent.

    Non-promotable speculations (``promotable=False``) are read/what-if
    sandboxes: they never hold the parent and cannot ``commit()``.
    """

    def __init__(self, parent: "AgileLog", promotable: bool = True,
                 dedicated: bool = False, max_rebases: int = 3,
                 on_rebase: Optional[Callable[["Speculation", int, int],
                                              Optional[bool]]] = None,
                 mode: Optional[str] = None) -> None:
        self.parent = parent
        self.promotable = promotable
        self.max_rebases = max_rebases
        self.on_rebase = on_rebase
        self._dedicated = dedicated
        self._mode = mode
        self._stats: SpecStats = parent.system.spec_stats
        self._stats.sessions += 1
        self.log: AgileLog = parent.cfork(promotable=promotable,
                                          dedicated=dedicated)
        self._base = self._info().fork_point
        self._suffix: List[AppendReceipt] = []
        self._state = "open"          # open | committed | aborted
        self.rebases = 0
        self.replayed = 0
        # registered while open so the §14 compactor can exclude this
        # session's durable receipt segments from rewrite candidates: a
        # rebase replays those (object, offsets) tuples verbatim
        parent.system._live_specs.add(self)

    # -- proxied log surface -------------------------------------------------
    def _info(self):
        return self.parent.system.metadata.read_state().fork_info(
            self.log.log_id)

    def _require_open(self) -> None:
        if self._state != "open":
            raise InvalidOperation(f"speculation already {self._state}")

    def append(self, record: bytes) -> AppendReceipt:
        self._require_open()
        receipt = self.log.append(record)
        self._suffix.append(receipt)
        return receipt

    def append_batch(self, records: Sequence[bytes]) -> AppendReceipt:
        self._require_open()
        receipt = self.log.append_batch(records)
        self._suffix.append(receipt)
        return receipt

    def read(self, lo: int, hi: int) -> List[bytes]:
        return self.log.read(lo, hi)

    def scan(self, lo: int = 0, hi: Optional[int] = None,
             batch: int = 1024) -> Iterator[bytes]:
        return self.log.scan(lo, hi, batch)

    def subscribe(self, **kwargs) -> Subscription:
        return self.log.subscribe(**kwargs)

    @property
    def tail(self) -> int:
        return self.log.tail

    @property
    def fork_point(self) -> int:
        """Parent position the CURRENT fork branched at (moves on rebase)."""
        return self._base

    @property
    def parent_advanced(self) -> int:
        """Parent records sequenced since the current fork point — what a
        ``commit()`` right now would have to rebase over."""
        self.parent._sync()
        return self._info().advanced

    @property
    def suffix_len(self) -> int:
        return sum(r.count for r in self._suffix)

    # -- transaction ---------------------------------------------------------
    def commit(self, mode: Optional[str] = None) -> CommitResult:
        """Promote the speculation atomically; auto-rebase on conflict."""
        self._require_open()
        mode = mode if mode is not None else self._mode
        system = self.parent.system
        for receipt in self._suffix:
            receipt.wait()           # surface deferred append errors first
        attempts = 0
        while True:
            attempts += 1
            self.log._sync()         # sequence any still-staged suffix records
            try:
                outcome = system.metadata.propose(
                    ("promote_if", self.log.log_id, self._base, mode))
            except UnknownLog:
                # a sibling speculation promoted first and squashed us (§4.1
                # first-promote-wins): same client-visible story as a
                # parent-advanced conflict — rebase onto the merged parent
                outcome = ("conflict", None)
            if outcome[0] == "ok":
                base, count = outcome[1]
                self._state = "committed"
                system._live_specs.discard(self)
                self._stats.commits += 1
                system._gc_nudge()   # promote may have squashed rivals (§13)
                return CommitResult(log_id=self.parent.log_id, base=base,
                                    count=count, attempts=attempts,
                                    rebases=self.rebases,
                                    replayed=self.replayed)
            diag = outcome[1] or {}
            self._stats.conflicts += 1
            if attempts > self.max_rebases:
                self._abort(squash=True)
                why = (f"parent {diag['log_id']} advanced {diag['advanced']} "
                       f"records past the validated tail {self._base}"
                       if diag else
                       f"a sibling speculation promoted first into parent "
                       f"{self.parent.log_id}")
                raise ConflictError(
                    f"speculative commit lost to {attempts} conflict(s) "
                    f"(max_rebases={self.max_rebases}): {why}",
                    log_id=diag.get("log_id", self.parent.log_id),
                    fork_id=diag.get("fork_id"),
                    fork_point=diag.get("fork_point", self._base),
                    parent_tail=diag.get("parent_tail"),
                    expected=diag.get("expected", self._base),
                    advanced=diag.get("advanced", 0),
                    attempts=attempts,
                    holds_epoch=diag.get("holds_epoch"))
            old_base = self._base
            self._rebase()
            if self.on_rebase is not None:
                if self.on_rebase(self, old_base, self._base) is False:
                    self._abort(squash=True)
                    raise ConflictError(
                        "on_rebase validation rejected the parent's delta "
                        f"[{old_base},{self._base})",
                        log_id=self.parent.log_id, fork_point=self._base,
                        expected=old_base, advanced=self._base - old_base,
                        attempts=attempts)

    def _rebase(self) -> None:
        """Squash the stale fork, cFork at the parent's new tail, and replay
        the speculative suffix ZERO-COPY: the records are already durable in
        shared storage (each receipt carries its segment reference), so the
        replay is one metadata proposal per original append — no object PUT,
        no payload bytes moved (DESIGN.md §12)."""
        segments = [r._pending.segment for r in self._suffix
                    if r._pending.segment is not None and r.count > 0]
        # pin the suffix segments for the squash -> replay window (§13): the
        # squash drops their manifest refcounts — possibly to zero when this
        # fork was their only lineage — and a GC quantum sequenced between
        # the squash and the replay would otherwise reclaim bytes the replay
        # is about to re-index. Pins ride into the `gc` command, so the skip
        # is consensus-ordered too.
        collector = self.parent.system.collector
        pin_ids = {object_id for object_id, _offs, _lens in segments}
        collector.pin(pin_ids)
        try:
            try:
                self.log.squash()
            except AgileLogError:
                pass                  # already squashed by the winning sibling
            self.log = self.parent.cfork(promotable=self.promotable,
                                         dedicated=self._dedicated)
            self._base = self._info().fork_point
            replayed: List[AppendReceipt] = []
            n = 0
            for object_id, offsets, lengths in segments:
                pending = self.log._b().replay(self.log.log_id, object_id,
                                               offsets, lengths)
                replayed.append(AppendReceipt(pending))
                n += len(offsets)
        finally:
            collector.unpin(pin_ids)
        self._suffix = replayed
        self.rebases += 1
        self.replayed += n
        self._stats.rebases += 1
        self._stats.replayed_records += n

    def abort(self) -> None:
        """Squash the speculation; idempotent once the session is closed."""
        if self._state == "open":
            self._abort(squash=True)

    def _abort(self, squash: bool) -> None:
        self._state = "aborted"
        self.parent.system._live_specs.discard(self)
        self._stats.aborts += 1
        if squash:
            try:
                self.log.squash()
            except AgileLogError:
                pass                  # fork already gone (lost promote race)
        # eager hand-off (§13): the squash just released this session's
        # private suffix segments — don't leave them for a later sweep
        self.parent.system._gc_nudge()

    # -- context manager -----------------------------------------------------
    def __enter__(self) -> "Speculation":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an uncommitted speculation must not outlive its block: it would
        # keep holding the parent (§4.1) with nobody left to resolve it
        if self._state == "open":
            self.abort()

    def __repr__(self) -> str:
        return (f"Speculation(parent={self.parent.log_id}, "
                f"fork={self.log.log_id}, base={self._base}, "
                f"suffix={self.suffix_len}, state={self._state})")


class BoltSystem:
    def __init__(self, n_brokers: int = 4, store: Optional[ObjectStore] = None,
                 n_meta_replicas: int = 3, snapshot_every: int = 0,
                 cf_mode: str = "ltt", fork_mode: str = "zerocopy",
                 promote_mode: str = "copy",
                 group_commit: Union[None, bool, int, GroupCommitConfig] = None,
                 cache_bytes: int = 64 << 20,
                 cache_page_bytes: int = 64 << 10,
                 readahead_bytes: int = 256 << 10,
                 view_cache: bool = True,
                 pipeline_apply: bool = True,
                 gc: Union[None, bool, int, GCConfig] = None,
                 compaction: Union[None, bool, int, CompactionConfig] = None,
                 tiering: Union[None, bool, int, TieringConfig] = None,
                 faults: Union[None, bool, FaultConfig, FaultPlane] = None,
                 retry: Optional[RetryPolicy] = None,
                 store_backend: Optional[str] = None,
                 store_root: Optional[str] = None,
                 pipelined_io: bool = False) -> None:
        if group_commit is True:
            group_commit = GroupCommitConfig()
        elif group_commit is False or group_commit == 0:
            group_commit = None   # falsy: group commit off
        elif isinstance(group_commit, int):
            if group_commit < 0:
                raise ValueError(f"group_commit batch size must be >= 0, got {group_commit}")
            group_commit = GroupCommitConfig(max_records=group_commit)
        elif group_commit is not None and not isinstance(group_commit, GroupCommitConfig):
            raise TypeError(f"group_commit must be None, bool, int, or "
                            f"GroupCommitConfig, got {type(group_commit).__name__}")
        self.group_commit: Optional[GroupCommitConfig] = group_commit
        # -- cold tiering (DESIGN.md §14). Same shape as `gc`: None/False ->
        # tiering off (plain store, TierManager quanta are no-ops), True ->
        # tiered store + background demotion quanta, int -> auto with that
        # min demotion age, TieringConfig -> as given (store is tiered even
        # when auto is off, for explicit demote()/resync() driving).
        if tiering is True:
            tiering = TieringConfig(auto=True)
        elif isinstance(tiering, bool) or tiering is None:   # False or None
            tiering = None
        elif isinstance(tiering, int):
            if tiering <= 0:
                raise ValueError(f"tiering min_age must be positive, got {tiering}")
            tiering = TieringConfig(min_age=tiering, auto=True)
        elif not isinstance(tiering, TieringConfig):
            raise TypeError(f"tiering must be None, bool, int, or TieringConfig, "
                            f"got {type(tiering).__name__}")
        # -- store backend selection (DESIGN.md §18). `store_backend` names
        # one of the protocol backends; `store=` passes an instance directly
        # (mutually exclusive). "file" roots at `store_root` (a fresh
        # tempdir when omitted); "tiered" composes with `tiering=`.
        if store_backend is not None:
            if store is not None:
                raise TypeError("pass store= or store_backend=, not both")
            if store_backend == "memory":
                store = MemoryObjectStore()
            elif store_backend == "file":
                if store_root is None:
                    store_root = tempfile.mkdtemp(prefix="agilelog-store-")
                store = FileObjectStore(store_root)
            elif store_backend == "ranged":
                store = RangedStore()
            elif store_backend == "tiered":
                store = TieredObjectStore()
            else:
                raise ValueError(
                    f"unknown store_backend {store_backend!r}: expected "
                    f"'memory', 'file', 'ranged', or 'tiered'")
        if store is None:
            store = TieredObjectStore() if tiering is not None else MemoryObjectStore()
        elif tiering is not None and not isinstance(store, TieredObjectStore):
            raise TypeError(
                f"tiering requires a TieredObjectStore (two store classes, "
                f"§14), got {type(store).__name__}")
        self.store = store
        self.metadata = MetadataService(
            n_replicas=n_meta_replicas, snapshot_every=snapshot_every,
            pipeline_apply=pipeline_apply,
            cf_mode=cf_mode, fork_mode=fork_mode, promote_mode=promote_mode,
            view_cache=view_cache)
        self.brokers = [Broker(i, self.store, self.metadata,
                               cache_bytes=cache_bytes,
                               cache_page_bytes=cache_page_bytes,
                               readahead_bytes=readahead_bytes,
                               group_commit=group_commit)
                        for i in range(max(2, n_brokers))]
        for b in self.brokers:
            b.pipelined_io = pipelined_io   # PUT ∥ propose ack overlap (§18)
        self._fork_broker: Dict[int, int] = {}   # parent log -> broker for its forks
        self._next_broker = 1
        self._dead: Set[int] = set()             # failed broker ids
        self.spec_stats = SpecStats()            # session counters (§12)
        self.serve_stats = ServeStats()          # serving counters (§17)
        # -- segment GC (DESIGN.md §13). Manifest accounting in the metadata
        # layer is always on; `gc` only shapes the reaper: None -> manual
        # (explicit system.gc()/gc_quantum()), True -> background quanta on
        # churn hand-off points (abort/close/squash/promote), int -> auto
        # with that per-quantum batch, or a full GCConfig.
        if gc is True:
            gc = GCConfig(auto=True)
        elif gc is False or gc is None:
            gc = GCConfig()
        elif isinstance(gc, int):
            if gc <= 0:
                raise ValueError(f"gc batch size must be positive, got {gc}")
            gc = GCConfig(batch=gc, auto=True)
        elif not isinstance(gc, GCConfig):
            raise TypeError(f"gc must be None, bool, int, or GCConfig, "
                            f"got {type(gc).__name__}")
        self.collector = GarbageCollector(self, gc)
        # -- segment compaction (DESIGN.md §14). Same shape as `gc`: None ->
        # manual (explicit system.compact()/compact_quantum()), True -> auto
        # quanta on churn hand-off points, int -> auto with that per-quantum
        # source batch, or a full CompactionConfig.
        if compaction is True:
            compaction = CompactionConfig(auto=True)
        elif compaction is False or compaction is None:
            compaction = CompactionConfig()
        elif isinstance(compaction, int):
            if compaction <= 0:
                raise ValueError(
                    f"compaction batch size must be positive, got {compaction}")
            compaction = CompactionConfig(batch=compaction, auto=True)
        elif not isinstance(compaction, CompactionConfig):
            raise TypeError(f"compaction must be None, bool, int, or "
                            f"CompactionConfig, got {type(compaction).__name__}")
        self.compactor = Compactor(self, compaction)
        self.tiers = TierManager(self, tiering or TieringConfig())
        self._tiering_auto = tiering is not None and tiering.auto
        self._live_specs: Set[Speculation] = set()   # open sessions (§14 exclusion)
        if isinstance(self.store, TieredObjectStore):
            for b in self.brokers:
                b.tiering = self.tiers   # read-path promotion hook (§14)
        # -- fault plane + retry policy (DESIGN.md §15). Same config shape:
        # None/False -> no plane (every path below is byte-identical to the
        # pre-§15 system: no retries, no token wrapping, no fault draws),
        # True -> a plane with the default seed and all probabilities zero
        # (deterministic schedules can still be driven via plane.advance()),
        # FaultConfig -> a fresh plane over it, FaultPlane -> as given.
        if faults is True:
            faults = FaultPlane(FaultConfig())
        elif faults is False or faults is None:
            faults = None
        elif isinstance(faults, FaultConfig):
            faults = FaultPlane(faults)
        elif not isinstance(faults, FaultPlane):
            raise TypeError(f"faults must be None, bool, FaultConfig, or "
                            f"FaultPlane, got {type(faults).__name__}")
        self.faults: Optional[FaultPlane] = faults
        self.retry = retry if retry is not None else RetryPolicy()
        self.retry_stats = RetryStats()   # shared with the metadata layer
        self.broker_failovers = 0
        if faults is not None:
            faults.bind(self)
            self.store.attach_faults(faults)
            self.metadata.faults = faults
            self.metadata.retry = self.retry
            self.metadata.retry_stats = self.retry_stats
            for b in self.brokers:
                b.faults = faults
        for b in self.brokers:
            b.fleet = self   # receipts route flush through retry/failover

    # -- group commit (DESIGN.md §9) ------------------------------------------------
    def flush(self) -> None:
        """Commit every broker's staging buffer (no-op when group commit is
        off). With a fault plane active, each flush runs under the retry
        policy: a broker that crashes mid-flush fails over and the re-routed
        staging commits through its survivor."""
        for b in self.brokers:
            if b.broker_id in self._dead:
                continue
            self._retrying(lambda _a, b=b: self.live_broker(b).flush())

    # -- segment GC (DESIGN.md §13) -------------------------------------------------
    def gc(self, arrival: Optional[float] = None) -> GCStats:
        """Drain reclamation: one unbounded consensus-ordered ``gc`` command
        reclaims every currently-dead segment object, the reaper deletes them
        from shared storage and invalidates broker cache pages. Returns
        :class:`GCStats` (``pending`` > 0 afterwards only for pinned ids)."""
        return self.collector.collect(arrival=arrival)

    def gc_quantum(self, limit: Optional[int] = None,
                   arrival: Optional[float] = None) -> List[str]:
        """One incremental background GC step (up to the configured batch);
        returns the object ids reclaimed this quantum."""
        return self.collector.quantum(limit=limit, arrival=arrival)

    @property
    def gc_stats(self) -> GCStats:
        return self.collector.stats()

    def _gc_nudge(self) -> None:
        """Churn hand-off point (abort/close/squash/promote): in auto mode,
        run a quantum so dead suffixes are reclaimed as they die rather than
        at the next explicit drain. The pending check keeps no-op nudges from
        spending a consensus round. Auto compaction and tier demotion ride
        the same hand-off points (§14)."""
        if (self.collector.config.auto
                and self.metadata.state.gc_pending() > 0):
            self.collector.quantum()
        if self.compactor.config.auto and self.compactor.candidates():
            self.compactor.quantum()
        if self._tiering_auto:
            self.tiers.demote_quantum()

    # -- segment compaction + cold tiering (DESIGN.md §14) --------------------------
    def compact(self, arrival: Optional[float] = None) -> CompactStats:
        """Drain compaction: rewrite every object under the live-byte-ratio
        threshold onto fresh compacted objects (one consensus-ordered
        ``compact`` swap per batch) and hand the retired sources to the §13
        reaper. Returns :class:`CompactStats`."""
        return self.compactor.compact(arrival=arrival)

    def compact_quantum(self, arrival: Optional[float] = None) -> List[str]:
        """One incremental compaction step; returns the source object ids
        retired by this quantum's swap ([] when idle or stale)."""
        return self.compactor.quantum(arrival=arrival)

    @property
    def compact_stats(self) -> CompactStats:
        return self.compactor.stats()

    def demote(self, arrival: Optional[float] = None) -> TierStats:
        """Drain tier demotion: move every age-eligible compacted object to
        the cold store class (consensus-ordered). No-op on untiered stores."""
        return self.tiers.demote(arrival=arrival)

    def demote_quantum(self, arrival: Optional[float] = None) -> List[str]:
        """One incremental demotion step; returns the object ids demoted."""
        return self.tiers.demote_quantum(arrival=arrival)

    @property
    def tier_stats(self) -> TierStats:
        return self.tiers.stats()

    def _session_segments(self) -> Set[str]:
        """Durable segment objects referenced by open speculation sessions'
        receipts (§14): a rebase replay re-proposes these verbatim, so the
        compactor must not rewrite them out from under the receipts."""
        out: Set[str] = set()
        for spec in self._live_specs:
            for receipt in spec._suffix:
                segment = receipt._pending.segment
                if segment is not None:
                    out.add(segment[0])
        return out

    def __enter__(self) -> "BoltSystem":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # only flush on clean exit: a failing flush must not mask the body's
        # in-flight exception (staged records were never acked; the caller can
        # still flush() manually after handling the error)
        if exc_type is None:
            self.flush()

    # -- placement ----------------------------------------------------------------
    def _broker_for_root(self) -> Broker:
        return self.brokers[0]

    def _pick_fork_broker(self, parent_broker: int) -> int:
        """Next round-robin broker that is NOT the parent's and is live.

        The seed's re-map ``(b % (len-1)) + 1`` could land back on
        ``parent_broker`` (e.g. 2 brokers, parent on broker 1), silently
        violating the isolation placement rule — so after the round-robin
        pass, fall back to an explicit search over every other live broker
        (including broker 0) before giving up and co-locating."""
        n = len(self.brokers)
        for _ in range(max(1, n - 1)):
            b = self._next_broker
            self._next_broker = (self._next_broker % (n - 1)) + 1
            if b != parent_broker and b not in self._dead:
                return b
        for b in range(n):
            if b != parent_broker and b not in self._dead:
                return b
        return parent_broker   # degenerate: no other live broker exists

    def _broker_for_fork(self, parent_log: int, parent_broker: int,
                         dedicated: bool) -> Broker:
        if dedicated:
            return self.brokers[self._pick_fork_broker(parent_broker)]
        b = self._fork_broker.get(parent_log)
        if b is None or b == parent_broker:
            b = self._pick_fork_broker(parent_broker)
            self._fork_broker[parent_log] = b
        return self.brokers[b]

    # -- entry point ----------------------------------------------------------------
    def create_log(self, name: str) -> "AgileLog":
        log_id = self.metadata.propose(("create_root", name))
        return AgileLog(self, log_id, self._broker_for_root())

    def open_log(self, log_id: int) -> "AgileLog":
        """Fresh client handle for an EXISTING log id — the re-attach path
        (DESIGN.md §17): checkpoint manifests and serving catalogs record
        fork ids durably, and a restarted process opens them by id. Brokers
        are stateless, so the handle routes through the normal placement
        map (forks keep their isolation broker, roots stay on broker 0)."""
        meta = self.metadata.read_state().logs.get(log_id)
        if meta is None or not meta.alive:
            raise UnknownLog(f"log {log_id} does not exist or is dead")
        if meta.kind == "root" or meta.parent is None:
            return AgileLog(self, log_id, self._broker_for_root())
        broker = self._broker_for_fork(
            meta.parent, self._broker_for_root().broker_id, dedicated=False)
        return AgileLog(self, log_id, broker)

    def find_log(self, name: str) -> Optional["AgileLog"]:
        """Root log by exact name, or None — the lookup half of the
        re-attach path (``create_log`` is not idempotent: calling it twice
        makes two roots). Newest wins if names were reused."""
        state = self.metadata.read_state()
        for log_id in sorted(state.logs, reverse=True):
            meta = state.logs[log_id]
            if meta.kind == "root" and meta.name == name and meta.alive:
                return AgileLog(self, log_id, self._broker_for_root())
        return None

    # -- broker failover (straggler mitigation §6; crash recovery §15) --------------
    def fail_broker(self, broker_id: int) -> None:
        """Mark a broker dead and fail its staged group-commit records OVER
        to a surviving broker (DESIGN.md §15): brokers are stateless (§5.2),
        so the only broker-private state is the object cache (rebuildable)
        and the unflushed staging buffer. The staged records were never
        acked, so re-routing them preserves exactly-once: the survivor's
        next flush commits them under a fresh segment id, and the receipts
        resolve with the surviving positions. Orphaned PUTs the crashed
        broker noted (torn or unproposed segments) go to the §13 reaper's
        resync path. Only with NO survivor do the pendings fail."""
        if broker_id in self._dead:
            return
        self._dead.add(broker_id)
        dead = self.brokers[broker_id]
        for parent, b in list(self._fork_broker.items()):
            if b == broker_id:
                del self._fork_broker[parent]
        self.collector.note_orphans(dead.take_orphans())
        staged = dead.take_staging()
        if not staged:
            return
        survivor = next((b for b in self.brokers
                         if b.broker_id not in self._dead), None)
        if survivor is None:
            for pending, _records in staged:
                pending._fail(NoLiveBrokers(
                    f"broker {broker_id} failed with no live peer; "
                    f"append not committed"), 0.0)
            return
        survivor.adopt_staging(staged)
        self.broker_failovers += 1

    def recover_broker(self, broker_id: int) -> None:
        """Restart a dead broker (DESIGN.md §15). Brokers are stateless
        (§5.2), so recovery is just rejoining the fleet: the cache refills
        on demand and staging starts empty. Any orphan PUT notes it carried
        were already handed to the §13 reaper at failure time."""
        self._dead.discard(broker_id)

    # -- network partitions (DESIGN.md §16) --------------------------------
    def partition(self, *groups) -> None:
        """Partition the metadata replica network into ``groups`` (iterables
        of replica ids): traffic crosses group boundaries in neither
        direction until :meth:`heal_network`. Convenience front for
        ``faults.net.partition`` — requires a fault plane."""
        assert self.faults is not None, "partition() needs a fault plane"
        self.faults.net.partition(*groups)

    def heal_network(self) -> None:
        """Lift every partition (symmetric and one-way) and deliver delayed
        in-flight messages; replica reconciliation then happens through
        normal AppendEntries traffic (``sync_followers`` / the next
        ``check_convergence``)."""
        assert self.faults is not None, "heal_network() needs a fault plane"
        self.faults.net.heal()
        self.faults.net.flush()

    def live_broker(self, preferred: Broker) -> Broker:
        if preferred.broker_id not in self._dead:
            return preferred
        for b in self.brokers:
            if b.broker_id not in self._dead:
                return b
        raise NoLiveBrokers("no live brokers")

    # -- data-plane retry (DESIGN.md §15) -------------------------------------------
    def _retrying(self, fn):
        """Run a data-plane operation under the client retry policy when a
        fault plane is active (plain synchronous call otherwise). On a
        :class:`BrokerCrashed` the crashed broker is failed over BEFORE the
        backoff, so the retry routes through a survivor via ``live_broker``.
        Metadata-level transients never reach here with budget left — the
        metadata layer retries them internally with the SAME idempotency
        token — and its ``RetryBudgetExhausted`` is not re-retried (the
        helper re-raises it immediately), so budgets never multiply."""
        plane = self.faults
        if plane is None or not plane.enabled:
            return fn(1)

        def attempt(i):
            try:
                return fn(i)
            except BrokerCrashed as e:
                if e.broker_id is not None:
                    self.fail_broker(e.broker_id)
                raise

        return run_with_retries(attempt, self.retry, plane.rng,
                                stats=self.retry_stats)


class AgileLog:
    """Client handle for one log (root or fork): Figure 1's interface plus
    the §12 session primitives (receipts, speculate, subscribe)."""

    def __init__(self, system: BoltSystem, log_id: int, broker: Broker) -> None:
        self.system = system
        self.log_id = log_id
        self.broker = broker

    # -- traditional shared-log API --------------------------------------------------
    def _b(self) -> Broker:
        """Current broker, re-routed if ours failed (stateless brokers)."""
        b = self.system.live_broker(self.broker)
        if b is not self.broker:
            self.broker = b
        return b

    def _sync(self) -> Broker:
        """Broker handle with this log's staged records committed: metadata
        operations (tails, forks, promote, squash) must observe the caller's
        own prior appends (read-your-writes, DESIGN.md §9), so they flush a
        staging buffer holding records of this log first."""
        self.system._retrying(
            lambda _a: self._b()._flush_if_staged(self.log_id))
        return self._b()

    def append(self, record: bytes) -> AppendReceipt:
        """Append one record; always returns an :class:`AppendReceipt` —
        resolved immediately in per-call mode (deterministic errors raise
        here), at flush in group-commit mode (errors raise at ``wait()``).
        With a fault plane active (§15) transient failures retry under the
        client policy, failing over to a surviving broker if ours crashes."""
        return AppendReceipt(self.system._retrying(
            lambda _a: self._b().submit(self.log_id, [record])))

    def append_batch(self, records: Sequence[bytes]) -> AppendReceipt:
        """Append a batch atomically; one receipt covering every record."""
        recs = list(records)
        return AppendReceipt(self.system._retrying(
            lambda _a: self._b().submit(self.log_id, recs)))

    def flush(self) -> None:
        """Commit this log's staged records (group commit, DESIGN.md §9).
        Only flushes the broker staging buffer if records of THIS log are in
        it — other logs' staged batches keep accumulating. Use
        ``BoltSystem.flush()`` for the global flush."""
        self.system._retrying(
            lambda _a: self._b()._flush_if_staged(self.log_id))

    def read(self, lo: int, hi: int) -> List[bytes]:
        records, _ = self.system._retrying(
            lambda _a: self._b().read_records(self.log_id, lo, hi))
        return records

    def scan(self, lo: int = 0, hi: Optional[int] = None,
             batch: int = 1024) -> Iterator[bytes]:
        """Stream records [lo, hi) in position order (DESIGN.md §10).

        The agent catch-up pattern: one metadata resolution + one
        scatter-gather ranged-GET round per ``batch`` positions, with the
        broker cache's sequential readahead prefetching ahead of the cursor —
        instead of a chain walk and a GET per record. ``hi=None`` snapshots
        the visible tail when ``scan`` is called; records appended afterwards
        are not included. Validation is eager (this returns a generator, but
        bad ``batch``/bounds raise here, at the call site, exactly as
        ``read`` would)."""
        if batch <= 0:
            raise InvalidOperation(f"scan batch must be positive, got {batch}")
        self._sync()
        state = self.system.metadata.read_state()
        if hi is None:
            hi = state.visible_tail(self.log_id)
        tail = state.tail(self.log_id)
        if not (0 <= lo <= hi <= tail):
            raise InvalidOperation(f"scan [{lo},{hi}) out of range (tail {tail})")
        return self._scan_iter(lo, hi, batch)

    def _scan_iter(self, lo: int, hi: int, batch: int) -> Iterator[bytes]:
        # each chunk re-resolves the broker AND runs under the retry policy:
        # a scan survives its broker dying mid-iteration (§15) — the next
        # chunk (or the retried current one) reads through a survivor
        pos = lo
        while pos < hi:
            chunk_hi = min(pos + batch, hi)
            records, _ = self.system._retrying(
                lambda _a, lo_=pos, hi_=chunk_hi:
                    self._b().read_records(self.log_id, lo_, hi_))
            yield from records
            pos = chunk_hi

    def subscribe(self, from_pos: int = 0, batch: int = 1024,
                  follow: bool = True, max_idle: Optional[int] = None,
                  backoff: Optional[Callable[[int], None]] = None
                  ) -> Subscription:
        """Tailing subscription from ``from_pos`` (DESIGN.md §12): iterate
        for batches as the visible tail advances, or drive it one
        ``poll()`` at a time."""
        return Subscription(self, from_pos=from_pos, batch=batch,
                            follow=follow, max_idle=max_idle, backoff=backoff)

    @property
    def tail(self) -> int:
        self._sync()
        return self.system.metadata.read_state().tail(self.log_id)

    @property
    def visible_tail(self) -> int:
        self._sync()
        return self.system.metadata.read_state().visible_tail(self.log_id)

    # -- forking -----------------------------------------------------------------------
    def cfork(self, promotable: bool = False, dedicated: bool = False) -> "AgileLog":
        self._sync()
        child_id = self.system.metadata.propose(("cfork", self.log_id, promotable))
        broker = self.system._broker_for_fork(self.log_id, self.broker.broker_id,
                                              dedicated)
        return AgileLog(self.system, child_id, broker)

    def sfork(self, past: Optional[int] = None, dedicated: bool = False) -> "AgileLog":
        self._sync()
        child_id = self.system.metadata.propose(("sfork", self.log_id, past))
        broker = self.system._broker_for_fork(self.log_id, self.broker.broker_id,
                                              dedicated)
        return AgileLog(self.system, child_id, broker)

    def speculate(self, promotable: bool = True, dedicated: bool = False,
                  max_rebases: int = 3,
                  on_rebase: Optional[Callable[[Speculation, int, int],
                                               Optional[bool]]] = None,
                  mode: Optional[str] = None) -> Speculation:
        """Open a speculative fork transaction against this log
        (DESIGN.md §12): ``with log.speculate() as s: ... s.commit()``."""
        if promotable is False and on_rebase is not None:
            raise InvalidOperation(
                "on_rebase only applies to promotable speculations")
        return Speculation(self, promotable=promotable, dedicated=dedicated,
                           max_rebases=max_rebases, on_rebase=on_rebase,
                           mode=mode)

    def promote(self, mode: Optional[str] = None) -> bool:
        self._sync()
        result = self.system.metadata.propose(("promote", self.log_id, mode))
        self.system._gc_nudge()   # restructure may have freed segments (§13)
        return result

    def squash(self) -> None:
        self._sync()
        self.system.metadata.propose(("squash", self.log_id))
        self.system._gc_nudge()   # dead-lineage hand-off (§13)

    def close(self) -> None:
        """Release this handle's log (DESIGN.md §13): flush any staged
        records, and — for a FORK — squash it, eagerly handing its private
        suffix segments to GC (the next quantum reclaims whatever no other
        lineage references). A root log only flushes: closing a handle must
        not destroy the shared stream. Idempotent: closing a handle whose
        fork is already gone (squashed, or promoted away) is a no-op."""
        b = self._b()
        b._flush_if_staged(self.log_id)
        meta = self.system.metadata.state.logs.get(self.log_id)
        if meta is not None and meta.alive and meta.kind != "root":
            try:
                self.system.metadata.propose(("squash", self.log_id))
            except AgileLogError:
                pass              # blocked/raced away: nothing to hand over
        self.system._gc_nudge()

    def __repr__(self) -> str:
        return f"AgileLog(id={self.log_id}, broker={self.broker.broker_id})"
