"""AgileLog / Bolt — the paper's primary contribution.

Layers (bottom-up):
  objectstore — S3-like shared storage (diskless substrate)
  index       — Hierarchical Log Index (HLI) run entries + naive variants
  ltt         — Lazy Tail Tree (Euler tour in a treap, lazy range updates)
  metadata    — the SMR state machine: forks, promote, squash, reads
  raft        — replicated metadata service (majority commit, failover)
  broker      — stateless brokers (append batching, object cache, DES hooks)
  api         — the AgileLog interface (Fig. 1) + BoltSystem wiring
  sim         — deterministic DES used by isolation benchmarks
"""

from .api import AgileLog, BoltSystem
from .broker import GroupCommitConfig, PendingAppend
from .errors import AgileLogError, ForkBlocked, InvalidOperation, UnknownLog

__all__ = [
    "AgileLog", "BoltSystem", "GroupCommitConfig", "PendingAppend",
    "AgileLogError", "ForkBlocked", "InvalidOperation", "UnknownLog",
]
