"""AgileLog / Bolt — the paper's primary contribution.

Layers (bottom-up):
  objectstore — S3-like shared storage (diskless substrate)
  index       — Hierarchical Log Index (HLI) run entries + naive variants
  ltt         — Lazy Tail Tree (Euler tour in a treap, lazy range updates)
  metadata    — the SMR state machine: forks, promote, squash, reads
  raft        — replicated metadata service (majority commit, failover)
  broker      — stateless brokers (append batching, object cache, DES hooks)
  gc          — lineage-aware segment garbage collection: consensus-ordered
                manifests + broker-side reaper (DESIGN.md §13)
  compact     — segment compaction + cold tiering: live-byte manifests,
                consensus-ordered index swaps, age-based demotion into a
                compressed store class (DESIGN.md §14)
  faults      — deterministic fault-injection plane + client retry policy
                (seeded per-site probabilities, DES-time kill/recover
                schedules, bounded backoff — DESIGN.md §15) + the message-
                level network layer (drop/delay/duplicate/reorder, partitions
                — DESIGN.md §16)
  linearize   — general porcupine-style linearizability checker over
                recorded append/read histories (DESIGN.md §16)
  api         — the agent-session client API (receipts, speculation sessions,
                tailing subscriptions — DESIGN.md §12) + BoltSystem wiring
  sim         — deterministic DES used by isolation benchmarks
"""

from .api import (AgileLog, AppendReceipt, BoltSystem, CommitResult,
                  Speculation, Subscription)
from .broker import GroupCommitConfig
from .compact import (CompactionConfig, Compactor, CompactStats, TieringConfig,
                      TierManager, TierStats)
from .errors import (AgileLogError, AmbiguousProposal, BrokerCrashed,
                     ConflictError, ForkBlocked, InvalidOperation,
                     LeaseExpired, NoLiveBrokers, NoQuorum, NotLeader,
                     ObjectMissing, RetryBudgetExhausted, StoreFault,
                     Unavailable, UnknownLog)
from .faults import FaultConfig, FaultPlane, LinkFaults, RetryPolicy, RetryStats
from .gc import GarbageCollector, GCConfig, GCStats
from .linearize import History, LinearizeResult, check_log
from .objectstore import (FileObjectStore, MemoryObjectStore, ObjectStore,
                          RangedStore, StoreProfile, TieredObjectStore)

__all__ = [
    "AgileLog", "AppendReceipt", "BoltSystem", "CommitResult", "Speculation",
    "Subscription", "GroupCommitConfig", "GarbageCollector", "GCConfig",
    "GCStats", "CompactionConfig", "Compactor", "CompactStats",
    "TieringConfig", "TierManager", "TierStats",
    "ObjectStore", "StoreProfile", "MemoryObjectStore", "FileObjectStore",
    "RangedStore", "TieredObjectStore",
    "FaultConfig", "FaultPlane", "LinkFaults", "RetryPolicy", "RetryStats",
    "History", "LinearizeResult", "check_log",
    "AgileLogError", "ConflictError", "ForkBlocked",
    "InvalidOperation", "UnknownLog", "ObjectMissing",
    "Unavailable", "NoQuorum", "NotLeader", "LeaseExpired", "NoLiveBrokers",
    "StoreFault", "BrokerCrashed", "AmbiguousProposal",
    "RetryBudgetExhausted",
]
