"""S3-like object store backends (the diskless "shared storage" layer, §5.2).

Bolt brokers are stateless: durability lives here. Two backends are provided:

* :class:`MemoryObjectStore` — dict-backed, used by tests/benchmarks.
* :class:`FileObjectStore`   — one file per object under a root dir; used by the
  checkpoint substrate so training state and log data share one storage layer.

Both support ranged GETs, which is what brokers use to fetch a single record
out of a large multi-record object.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple


class ObjectStore:
    """Abstract S3-ish KV-of-bytes interface."""

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        raise NotImplementedError


class MemoryObjectStore(ObjectStore):
    def __init__(self) -> None:
        self._objects: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.put_count = 0
        self.get_count = 0
        self.bytes_written = 0
        self.bytes_read = 0

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._objects[key] = bytes(data)
            self.put_count += 1
            self.bytes_written += len(data)

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        with self._lock:
            obj = self._objects[key]
            self.get_count += 1
            end = len(obj) if length is None else offset + length
            out = obj[offset:end]
            self.bytes_read += len(out)
            return out

    def delete(self, key: str) -> None:
        with self._lock:
            self._objects.pop(key, None)

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._objects.values())


class FileObjectStore(ObjectStore):
    """Filesystem-backed store; object keys map to files (slashes allowed).

    Writes are atomic (write to tmp + rename) so a crash mid-PUT never leaves a
    torn object — the property the checkpoint manifest protocol relies on.
    """

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        path = os.path.join(self.root, key)
        if os.path.commonpath([os.path.abspath(path), os.path.abspath(self.root)]) != os.path.abspath(self.root):
            raise ValueError(f"key escapes store root: {key!r}")
        return path

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        with open(self._path(key), "rb") as f:
            f.seek(offset)
            return f.read(length) if length is not None else f.read()

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def list(self, prefix: str = "") -> List[str]:
        out = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                if fn.endswith(".tmp"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn), self.root)
                if rel.startswith(prefix):
                    out.append(rel)
        return sorted(out)


class SegmentWriter:
    """Builder for segment-style multi-log objects (group commit, DESIGN.md §9).

    A group-commit flush packs the records of many staged appends — possibly
    for several different logs — into one object::

        payload = records of append 0 || records of append 1 || ...

    ``add()`` returns where the append landed inside its log's *entry* (all
    appends for one log are merged, in staging order, into a single entry of
    the batched metadata proposal); ``finish()`` returns the payload plus the
    per-log ``(log_id, offsets, lengths)`` table that proposal carries. Byte
    offsets are absolute within the segment object, so readers ranged-GET a
    record without knowing anything about the batch that produced it.
    """

    def __init__(self) -> None:
        self._chunks: List[bytes] = []
        self._size = 0
        self._log_ids: List[int] = []
        self._spans: List[Tuple[List[int], List[int]]] = []  # per-entry (offsets, lengths)
        self._entry_of: Dict[int, int] = {}

    def add(self, log_id: int, records: Iterable[bytes]) -> Tuple[int, int]:
        """Append `records` for `log_id`; returns (entry_index, start) — the
        entry's position in the batch and the records' start slot within it."""
        entry_index = self._entry_of.get(log_id)
        if entry_index is None:
            entry_index = self._entry_of[log_id] = len(self._log_ids)
            self._log_ids.append(log_id)
            self._spans.append(([], []))
        offsets, lengths = self._spans[entry_index]
        start = len(offsets)
        for r in records:
            self._chunks.append(r)
            offsets.append(self._size)
            lengths.append(len(r))
            self._size += len(r)
        return entry_index, start

    @property
    def nbytes(self) -> int:
        return self._size

    @property
    def nrecords(self) -> int:
        return sum(len(offs) for offs, _ in self._spans)

    def finish(self) -> Tuple[bytes, List[Tuple[int, Tuple[int, ...], Tuple[int, ...]]]]:
        payload = b"".join(self._chunks)
        entries = [(log_id, tuple(offs), tuple(lens))
                   for log_id, (offs, lens) in zip(self._log_ids, self._spans)]
        return payload, entries


class LRUObjectCache:
    """Broker-side object cache (§5.7: "we equip brokers with a local object cache").

    Caches whole objects; ranged reads slice the cached object. Forks of one
    parent co-located on one broker share this cache (the paper's rationale for
    co-location).
    """

    def __init__(self, store: ObjectStore, capacity_bytes: int = 64 << 20) -> None:
        self.store = store
        self.capacity = capacity_bytes
        self._cache: "OrderedDict[str, bytes]" = OrderedDict()
        self._size = 0
        self.hits = 0
        self.misses = 0

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        obj = self._cache.get(key)
        if obj is None:
            self.misses += 1
            obj = self.store.get(key)
            self._cache[key] = obj
            self._size += len(obj)
            while self._size > self.capacity and self._cache:
                _, evicted = self._cache.popitem(last=False)
                self._size -= len(evicted)
        else:
            self.hits += 1
            self._cache.move_to_end(key)
        end = len(obj) if length is None else offset + length
        return obj[offset:end]

    def get_spans(self, spans: Iterable[Tuple[str, int, int]]) -> List[bytes]:
        return [self.get(k, off, ln) for (k, off, ln) in spans]
