"""S3-like object store backends (the diskless "shared storage" layer, §5.2).

Bolt brokers are stateless: durability lives here. The backend protocol
(DESIGN.md §18) is the abstract :class:`ObjectStore` plus, per backend:

* a uniform **miss type** — every GET of an absent key raises
  :class:`~repro.core.errors.ObjectMissing`, never the backend's native error;
* the **fault hooks** (`_fault_put`/`_fault_get`/`_fault_delete`) consulted at
  every entry point, so the §15 plane exercises all backends identically;
* the **op counters** ``put_count``/``get_count``/``delete_count`` and
  ``bytes_written``/``bytes_read``/``bytes_deleted`` that ``OpTally`` captures;
* an optional DES cost :class:`StoreProfile` — brokers book store service
  times from it when present, falling back to the global ``ServiceTimes``
  store rates when it is ``None`` (the memory/tiered backends, keeping every
  pre-§18 benchmark byte-identical).

Backends:

* :class:`MemoryObjectStore` — dict-backed; the default for tests/benchmarks.
* :class:`TieredObjectStore` — hot + compressed cold store classes (§14).
* :class:`FileObjectStore`   — one file per object under a root dir, atomic
  tmp+rename PUTs with file *and directory* fsync; shared with checkpoints.
* :class:`RangedStore`       — S3-shaped cost model: high per-op latency, high
  throughput (tiny per-KB cost), and a ranged-GET *minimum billable size* —
  a 1 KB ranged GET costs the same as ``min_get_bytes`` (the
  latency-vs-throughput asymmetry real object stores have).

All support ranged GETs, which is what brokers use to fetch a single record
out of a large multi-record object.
"""

from __future__ import annotations

import os
import threading
import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from .errors import ObjectMissing


@dataclass(frozen=True)
class StoreProfile:
    """Per-backend DES service-time profile (DESIGN.md §18).

    Brokers resolve store costs through ``store.profile`` when one is set;
    ``None`` (memory/tiered) means "use the global ``ServiceTimes`` store
    rates" — the seed cost model, unchanged. ``min_get_bytes`` models the
    ranged-GET minimum of S3-class stores: every GET is billed at least that
    many bytes of transfer time, so tiny ranged reads pay the asymmetry.
    """

    put_base: float = 1.5e-3        # per-PUT latency floor (s)
    put_per_kb: float = 2e-6        # PUT transfer time per KiB
    get_base: float = 0.6e-3        # per-GET latency floor (s)
    get_per_kb: float = 1e-6        # GET transfer time per KiB
    delete_base: float = 0.5e-3     # per-DELETE latency (s)
    min_get_bytes: int = 0          # ranged-GET minimum billable size


#: Local-file backend: fsync dominates the PUT floor (file + parent dir),
#: but there is no network — per-KB transfer is cheap and GETs are page-cache
#: fast. The first *honest* durable-ack cost model in the repo.
FILE_PROFILE = StoreProfile(put_base=120e-6, put_per_kb=0.5e-6,
                            get_base=20e-6, get_per_kb=0.25e-6,
                            delete_base=30e-6, min_get_bytes=0)

#: S3-style backend: milliseconds of per-op latency, near-free marginal
#: bytes (high throughput), and a ranged-GET minimum — the classic object
#: store latency-vs-throughput asymmetry.
RANGED_PROFILE = StoreProfile(put_base=8e-3, put_per_kb=0.05e-6,
                              get_base=12e-3, get_per_kb=0.04e-6,
                              delete_base=4e-3, min_get_bytes=128 << 10)


class ObjectStore:
    """Abstract S3-ish KV-of-bytes interface (backend protocol, §18)."""

    #: Optional DES cost profile; ``None`` = global ServiceTimes store rates.
    profile: Optional[StoreProfile] = None

    #: Optional fault plane (DESIGN.md §15): backends consult it at their
    #: PUT/GET/DELETE entry points so injected store errors and torn partial
    #: PUTs exercise every layer above, deterministically.
    _faults = None

    def attach_faults(self, plane) -> None:
        self._faults = plane

    def _fault_put(self, key: str, data: bytes) -> bytes:
        """Consult the fault plane before a PUT. Returns the payload to
        durably write; raises after the caller-visible prefix of a torn PUT
        has been handed back (the *caller* of put() sees the error, the
        store commits whatever the plane let through)."""
        if self._faults is None:
            return data
        payload, error = self._faults.on_put(key, data)
        if error is not None:
            if payload is not None:
                self._commit_put(key, payload)   # the torn prefix lands
            raise error
        return data

    def _commit_put(self, key: str, data: bytes) -> None:
        """Durably write without re-consulting the fault plane (used only
        for torn-PUT prefixes). Backends that support fault injection
        override this with their raw write."""
        raise NotImplementedError

    def _fault_get(self, key: str) -> None:
        if self._faults is not None:
            self._faults.on_get(key)

    def _fault_delete(self, key: str) -> None:
        if self._faults is not None:
            self._faults.on_delete(key)

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def size(self, key: str) -> Optional[int]:
        """Object size in bytes, or None if absent (used by the GC reaper to
        book bytes_reclaimed without a GET)."""
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        raise NotImplementedError


class MemoryObjectStore(ObjectStore):
    def __init__(self) -> None:
        self._objects: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.put_count = 0
        self.get_count = 0
        self.delete_count = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.bytes_deleted = 0

    def _commit_put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._objects[key] = bytes(data)
            self.put_count += 1
            self.bytes_written += len(data)

    def put(self, key: str, data: bytes) -> None:
        self._commit_put(key, self._fault_put(key, data))

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        self._fault_get(key)
        with self._lock:
            obj = self._objects.get(key)
            if obj is None:
                raise ObjectMissing(key)
            self.get_count += 1
            end = len(obj) if length is None else offset + length
            out = obj[offset:end]
            self.bytes_read += len(out)
            return out

    def delete(self, key: str) -> None:
        self._fault_delete(key)
        with self._lock:
            obj = self._objects.pop(key, None)
            if obj is not None:
                self.delete_count += 1
                self.bytes_deleted += len(obj)

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def size(self, key: str) -> Optional[int]:
        with self._lock:
            obj = self._objects.get(key)
            return None if obj is None else len(obj)

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._objects.values())


class TieredObjectStore(ObjectStore):
    """Two store classes behind one keyspace (DESIGN.md §14): a **hot** tier
    (raw bytes, S3-standard-like) and a **cold** tier (zlib-compressed,
    archive-like — the DES model charges it distinct, slower service times).

    Routing is by *presence*, hot tier first: whichever tier physically holds
    the key serves it, so reads stay byte-correct at every point of a
    demotion/rehydration crash window — the consensus ``cold_objects`` set is
    the durable record of where objects *belong*, and ``TierManager.resync``
    converges physical placement to it. Tier moves are split into copy and
    drop halves (``copy_to_cold``/``drop_hot``, ``rehydrate``/``drop_cold``)
    so the tier manager can order them around the consensus proposal and a
    crash between halves leaves at worst a double-resident key, never a
    missing one.

    The hot-tier counters mirror :class:`MemoryObjectStore` (``OpTally``
    captures them by name); cold traffic additionally bumps the ``cold_*``
    counters so the DES model and benchmarks can split hot vs cold bytes.
    """

    def __init__(self, compression_level: int = 1) -> None:
        self._hot: Dict[str, bytes] = {}
        self._cold: Dict[str, bytes] = {}        # compressed payloads
        self._cold_sizes: Dict[str, int] = {}    # logical (uncompressed) sizes
        self._lock = threading.Lock()
        self.compression_level = compression_level
        self.put_count = 0
        self.get_count = 0
        self.delete_count = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.bytes_deleted = 0
        self.cold_puts = 0           # demotion writes into the cold class
        self.cold_gets = 0           # GETs served by the cold class
        self.cold_bytes_read = 0     # logical bytes those GETs returned
        self.cold_bytes_written = 0  # compressed bytes demotions stored

    # -- S3-ish interface ---------------------------------------------------
    def _commit_put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._hot[key] = bytes(data)
            self.put_count += 1
            self.bytes_written += len(data)

    def put(self, key: str, data: bytes) -> None:
        self._commit_put(key, self._fault_put(key, data))

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        self._fault_get(key)
        with self._lock:
            obj = self._hot.get(key)
            cold = obj is None
            if cold:
                packed = self._cold.get(key)
                if packed is None:
                    raise ObjectMissing(key)
                obj = zlib.decompress(packed)
            self.get_count += 1
            end = len(obj) if length is None else offset + length
            out = obj[offset:end]
            self.bytes_read += len(out)
            if cold:
                self.cold_gets += 1
                self.cold_bytes_read += len(out)
            return out

    def delete(self, key: str) -> None:
        self._fault_delete(key)
        with self._lock:
            freed = 0
            obj = self._hot.pop(key, None)
            if obj is not None:
                freed += len(obj)
            if self._cold.pop(key, None) is not None:
                freed += self._cold_sizes.pop(key, 0)
            if freed or obj is not None:
                self.delete_count += 1
                self.bytes_deleted += freed

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._hot or key in self._cold

    def size(self, key: str) -> Optional[int]:
        """Logical size regardless of tier (reclaim accounting stays
        tier-agnostic)."""
        with self._lock:
            obj = self._hot.get(key)
            if obj is not None:
                return len(obj)
            return self._cold_sizes.get(key)

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in set(self._hot) | set(self._cold)
                          if k.startswith(prefix))

    # -- tier moves (driven by TierManager, DESIGN.md §14) ------------------
    def is_cold(self, key: str) -> bool:
        """Physically cold: no hot copy, a cold copy exists."""
        with self._lock:
            return key not in self._hot and key in self._cold

    def copy_to_cold(self, key: str) -> int:
        """Compress the hot copy into the cold class (hot copy kept — the
        drop happens after the demotion commits). Returns compressed size."""
        with self._lock:
            data = self._hot.get(key)
            if data is None:
                return len(self._cold.get(key, b""))
            packed = zlib.compress(data, self.compression_level)
            self._cold[key] = packed
            self._cold_sizes[key] = len(data)
            self.cold_puts += 1
            self.cold_bytes_written += len(packed)
            return len(packed)

    def drop_hot(self, key: str) -> None:
        with self._lock:
            assert key in self._cold, f"dropping sole copy of {key}"
            self._hot.pop(key, None)

    def rehydrate(self, key: str) -> int:
        """Decompress the cold copy back into the hot class (cold copy kept
        until the promotion commits). Returns the logical size."""
        with self._lock:
            if key in self._hot:
                return len(self._hot[key])
            data = zlib.decompress(self._cold[key])
            self._hot[key] = data
            return len(data)

    def drop_cold(self, key: str) -> None:
        with self._lock:
            if key in self._hot or key not in self._cold:
                self._cold.pop(key, None)
                self._cold_sizes.pop(key, None)

    # -- accounting ---------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        """Physical footprint: hot logical bytes + cold *compressed* bytes
        (double-resident keys during a move window count both)."""
        with self._lock:
            return (sum(len(v) for v in self._hot.values())
                    + sum(len(v) for v in self._cold.values()))

    @property
    def cold_stored_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._cold.values())

    @property
    def cold_logical_bytes(self) -> int:
        with self._lock:
            return sum(self._cold_sizes.get(k, 0) for k in self._cold)


class FileObjectStore(ObjectStore):
    """Filesystem-backed store; object keys map to files (slashes allowed).

    Writes are atomic and durable: write to tmp, fsync the file, rename over
    the target, then fsync the *parent directory* — the rename itself is only
    durable once the directory entry is, which is the property the checkpoint
    manifest protocol relies on (a manifest PUT that acked must survive a
    crash). Opening a root sweeps ``*.tmp`` carcasses left by PUTs that
    crashed before their rename, mirroring ``SegmentCollector.resync()``'s
    orphan sweep: a tmp file is by construction un-acked and unreferenced.
    """

    profile = FILE_PROFILE

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.put_count = 0
        self.get_count = 0
        self.delete_count = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.bytes_deleted = 0
        self.tmp_swept = 0          # crash carcasses removed on open
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                if fn.endswith(".tmp"):
                    os.remove(os.path.join(dirpath, fn))
                    self.tmp_swept += 1

    def _path(self, key: str) -> str:
        path = os.path.join(self.root, key)
        if os.path.commonpath([os.path.abspath(path), os.path.abspath(self.root)]) != os.path.abspath(self.root):
            raise ValueError(f"key escapes store root: {key!r}")
        return path

    @staticmethod
    def _fsync_dir(path: str) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _commit_put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        parent = os.path.dirname(path)
        os.makedirs(parent, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self._fsync_dir(parent)
        self.put_count += 1
        self.bytes_written += len(data)

    def put(self, key: str, data: bytes) -> None:
        self._commit_put(key, self._fault_put(key, data))

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        self._fault_get(key)
        try:
            f = open(self._path(key), "rb")
        except FileNotFoundError:
            raise ObjectMissing(key) from None
        with f:
            f.seek(offset)
            out = f.read(length) if length is not None else f.read()
        self.get_count += 1
        self.bytes_read += len(out)
        return out

    def delete(self, key: str) -> None:
        self._fault_delete(key)
        path = self._path(key)
        try:
            freed = os.path.getsize(path)
            os.remove(path)
        except FileNotFoundError:
            return
        self.delete_count += 1
        self.bytes_deleted += freed

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def size(self, key: str) -> Optional[int]:
        try:
            return os.path.getsize(self._path(key))
        except OSError:
            return None

    def list(self, prefix: str = "") -> List[str]:
        out = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                if fn.endswith(".tmp"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn), self.root)
                if rel.startswith(prefix):
                    out.append(rel)
        return sorted(out)

    @property
    def total_bytes(self) -> int:
        total = 0
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                if not fn.endswith(".tmp"):
                    total += os.path.getsize(os.path.join(dirpath, fn))
        return total


class RangedStore(MemoryObjectStore):
    """S3-shaped backend: memory-backed semantics with the S3 *cost model*
    (DESIGN.md §18) — milliseconds of per-op latency, near-free marginal
    bytes, and a ranged-GET minimum billable size. ``billed_read_bytes``
    tracks what the DES model charges (each GET at least
    ``profile.min_get_bytes``) next to the logical ``bytes_read``, so
    benchmarks can show the asymmetry a page-granular cache must amortize.
    """

    profile = RANGED_PROFILE

    def __init__(self) -> None:
        super().__init__()
        self.billed_read_bytes = 0

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        out = super().get(key, offset, length)
        self.billed_read_bytes += max(len(out), self.profile.min_get_bytes)
        return out


class SegmentWriter:
    """Builder for segment-style multi-log objects (group commit, DESIGN.md §9).

    A group-commit flush packs the records of many staged appends — possibly
    for several different logs — into one object::

        payload = records of append 0 || records of append 1 || ...

    ``add()`` returns where the append landed inside its log's *entry* (all
    appends for one log are merged, in staging order, into a single entry of
    the batched metadata proposal); ``finish()`` returns the payload plus the
    per-log ``(log_id, offsets, lengths)`` table that proposal carries. Byte
    offsets are absolute within the segment object, so readers ranged-GET a
    record without knowing anything about the batch that produced it.
    """

    def __init__(self) -> None:
        self._chunks: List[bytes] = []
        self._size = 0
        self._log_ids: List[int] = []
        self._spans: List[Tuple[List[int], List[int]]] = []  # per-entry (offsets, lengths)
        self._entry_of: Dict[int, int] = {}

    def add(self, log_id: int, records: Iterable[bytes]) -> Tuple[int, int]:
        """Append `records` for `log_id`; returns (entry_index, start) — the
        entry's position in the batch and the records' start slot within it."""
        entry_index = self._entry_of.get(log_id)
        if entry_index is None:
            entry_index = self._entry_of[log_id] = len(self._log_ids)
            self._log_ids.append(log_id)
            self._spans.append(([], []))
        offsets, lengths = self._spans[entry_index]
        start = len(offsets)
        for r in records:
            self._chunks.append(r)
            offsets.append(self._size)
            lengths.append(len(r))
            self._size += len(r)
        return entry_index, start

    @property
    def nbytes(self) -> int:
        return self._size

    @property
    def nrecords(self) -> int:
        return sum(len(offs) for offs, _ in self._spans)

    def finish(self) -> Tuple[bytes, List[Tuple[int, Tuple[int, ...], Tuple[int, ...]]]]:
        payload = b"".join(self._chunks)
        entries = [(log_id, tuple(offs), tuple(lens))
                   for log_id, (offs, lens) in zip(self._log_ids, self._spans)]
        return payload, entries


class LRUObjectCache:
    """Broker-side object cache (§5.7: "we equip brokers with a local object
    cache") — page-granular byte-range caching (DESIGN.md §10).

    The seed version cached *whole objects*: a single-record read of a 1 MB
    group-commit segment faulted in the full megabyte. This cache holds
    fixed-size ``page_bytes`` pages per object instead. A miss fetches only
    the pages a request needs — one coalesced ranged GET per contiguous
    missing stretch (scatter-gather) — and an optional sequential-readahead
    window (``readahead_bytes``) extends the fetch when a request continues
    exactly where the previous one on the same object ended (scan-shaped
    access). Requests larger than ``capacity_bytes`` bypass the cache
    entirely: admitting them would evict everything and then churn.

    Forks of one parent co-located on one broker share this cache (the
    paper's rationale for co-location).

    Stats: ``hits``/``misses`` count *pages*; ``ranged_gets``/``bytes_fetched``
    count actual store traffic (what the DES model books, §8).
    """

    def __init__(self, store: ObjectStore, capacity_bytes: int = 64 << 20,
                 page_bytes: int = 64 << 10, readahead_bytes: int = 0) -> None:
        assert page_bytes > 0
        self.store = store
        self.capacity = capacity_bytes
        self.page_bytes = page_bytes
        self.readahead = readahead_bytes
        self._pages: "OrderedDict[Tuple[str, int], bytes]" = OrderedDict()
        self._size = 0
        self._obj_pages: Dict[str, set] = {}  # key -> resident page numbers
        self._obj_size: Dict[str, int] = {}   # sizes learned from short reads
        self._last_end: Dict[str, int] = {}   # per-object last request end
        # the two hint dicts above must stay bounded too (brokers never reuse
        # object ids, so "one entry per object ever read" is a leak): prune
        # oldest entries past a limit sized like the page population
        self._meta_limit = max(1024, capacity_bytes // page_bytes)
        self.hits = 0
        self.misses = 0
        self.ranged_gets = 0
        self.bytes_fetched = 0
        self.invalidations = 0                # invalidate_object calls

    # -- store traffic ------------------------------------------------------
    def _bypass(self, key: str, offset: int, length: Optional[int]) -> bytes:
        data = self.store.get(key, offset, length)
        self.ranged_gets += 1
        self.bytes_fetched += len(data)
        self.misses += 1
        return data

    def _admit(self, pkey: Tuple[str, int], data: bytes) -> None:
        if not data:
            return
        old = self._pages.pop(pkey, None)
        if old is not None:
            self._size -= len(old)
        self._pages[pkey] = data
        self._size += len(data)
        self._obj_pages.setdefault(pkey[0], set()).add(pkey[1])
        while self._size > self.capacity and self._pages:
            epk, evicted = self._pages.popitem(last=False)
            self._size -= len(evicted)
            self._forget_page(epk)

    def _forget_page(self, pkey: Tuple[str, int]) -> None:
        pages = self._obj_pages.get(pkey[0])
        if pages is not None:
            pages.discard(pkey[1])
            if not pages:
                del self._obj_pages[pkey[0]]

    def invalidate_object(self, key: str) -> int:
        """Drop every resident page and size/readahead hint for ``key``.

        Required before an object key can be deleted or recreated: pages are
        keyed by (object, page#) with no versioning, so a stale page would
        silently serve the OLD bytes to every later read (the pre-§13 gap —
        load-bearing once the GC reaper deletes objects, and for any backend
        caller that overwrites a key in place). Returns pages dropped."""
        self.invalidations += 1
        dropped = 0
        for p in sorted(self._obj_pages.pop(key, ())):
            page = self._pages.pop((key, p), None)
            if page is not None:
                self._size -= len(page)
                dropped += 1
        self._obj_size.pop(key, None)
        self._last_end.pop(key, None)
        return dropped

    def _fetch_pages(self, key: str, p_lo: int, p_hi: int) -> None:
        """ONE ranged GET for pages [p_lo, p_hi); splits the result into pages."""
        B = self.page_bytes
        want = (p_hi - p_lo) * B
        data = self.store.get(key, p_lo * B, want)
        self.ranged_gets += 1
        self.bytes_fetched += len(data)
        if len(data) < want:
            # short read: p_lo*B + len(data) is the object's size when the
            # offset was in range, and an upper bound on it otherwise
            bound = p_lo * B + len(data)
            known = self._obj_size.get(key)
            self._obj_size[key] = bound if known is None else min(known, bound)
        for i in range(0, len(data), B):
            self._admit((key, p_lo + i // B), data[i:i + B])

    def _ensure(self, key: str, pages: List[int], ra_pages: int) -> None:
        """Make the given (sorted) pages resident: coalesce missing stretches
        into one ranged GET each; extend the last stretch by the readahead."""
        size = self._obj_size.get(key)
        B = self.page_bytes
        missing: List[int] = []
        for p in pages:
            if size is not None and p * B >= size:
                continue   # provably beyond the object's end
            pk = (key, p)
            if pk in self._pages:
                self._pages.move_to_end(pk)
                self.hits += 1
            else:
                missing.append(p)
                self.misses += 1
        if not missing:
            return
        stretches: List[List[int]] = []
        for p in missing:
            if stretches and p == stretches[-1][1]:
                stretches[-1][1] = p + 1
            else:
                stretches.append([p, p + 1])
        if ra_pages > 0:
            a, b = stretches[-1]
            max_p = None if size is None else (size + B - 1) // B
            ext = b
            while (ext < b + ra_pages and (max_p is None or ext < max_p)
                   and (key, ext) not in self._pages):
                ext += 1
            stretches[-1][1] = ext
        for a, b in stretches:
            self._fetch_pages(key, a, b)

    def _assemble(self, key: str, offset: int, length: int) -> bytes:
        """Slice [offset, offset+length) out of resident pages; truncates at
        the object's end exactly like ``ObjectStore.get`` does."""
        B = self.page_bytes
        end = offset + length
        parts: List[bytes] = []
        pos = offset
        while pos < end:
            p, a = divmod(pos, B)
            page = self._pages.get((key, p))
            if page is None or a >= len(page):
                size = self._obj_size.get(key)
                if size is not None and pos >= size:
                    break   # provably past the object's end
                # a near-capacity request can evict its own earlier pages
                # between _ensure and assembly — fall back to a direct read
                # of the remainder rather than silently truncating
                parts.append(self._bypass(key, pos, end - pos))
                break
            take = page[a:min(end - p * B, len(page))]
            parts.append(take)
            pos += len(take)
            if len(page) < B:
                break
        return b"".join(parts)

    def _prune_meta(self) -> None:
        while len(self._obj_size) > self._meta_limit:
            self._obj_size.pop(next(iter(self._obj_size)))
        while len(self._last_end) > self._meta_limit:
            self._last_end.pop(next(iter(self._last_end)))

    # -- public API ---------------------------------------------------------
    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        if length is None:
            size = self._obj_size.get(key)
            if size is None:
                # whole-object fetch of unknown size: one GET; admit pages
                # only if the object fits (oversized objects bypass — they
                # would evict the entire cache and then not even be reusable)
                data = self._bypass(key, 0, None)
                self._obj_size[key] = len(data)
                self._last_end[key] = len(data)
                if len(data) <= self.capacity:
                    B = self.page_bytes
                    for i in range(0, len(data), B):
                        self._admit((key, i // B), data[i:i + B])
                self._prune_meta()
                return data[offset:]
            length = max(0, size - offset)
        return self.get_spans([(key, offset, length)])[0]

    def get_spans(self, spans: Iterable[Tuple[str, int, int]]) -> List[bytes]:
        """Scatter-gather ranged reads: spans are grouped by object, each
        object's missing pages coalesce into minimal ranged GETs, results
        come back in input order."""
        spans = list(spans)
        out: List[Optional[bytes]] = [None] * len(spans)
        by_obj: Dict[str, List[int]] = {}
        for i, (key, _off, _ln) in enumerate(spans):
            by_obj.setdefault(key, []).append(i)
        B = self.page_bytes
        for key, idxs in by_obj.items():
            small: List[int] = []
            for i in idxs:
                _, off, ln = spans[i]
                if ln > self.capacity:
                    out[i] = self._bypass(key, off, ln)   # oversized: bypass
                elif ln <= 0:
                    out[i] = b""
                else:
                    small.append(i)
            if not small:
                continue
            pages: set = set()
            lo = min(spans[i][1] for i in small)
            hi = max(spans[i][1] + spans[i][2] for i in small)
            for i in small:
                _, off, ln = spans[i]
                pages.update(range(off // B, (off + ln + B - 1) // B))
            seq = self.readahead > 0 and self._last_end.get(key) == lo
            self._ensure(key, sorted(pages), (self.readahead // B) if seq else 0)
            self._last_end.pop(key, None)   # re-insert: prune is oldest-first
            self._last_end[key] = hi
            for i in small:
                out[i] = self._assemble(key, spans[i][1], spans[i][2])
        self._prune_meta()
        return out  # type: ignore[return-value]
