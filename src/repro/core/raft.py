"""Fault-tolerant metadata layer: a minimal in-process replicated SMR group.

The paper's metadata layer is "a fault-tolerant group that implements state-
machine replication using Paxos or Raft" (§5.2). We implement the SMR contract
the rest of Bolt depends on — a single totally-ordered command log applied
deterministically on every replica, with majority commit, leader failover, and
snapshot/compaction — without the wire protocol (single-process container).

Properties exercised by tests:
  * a committed command survives any minority of replica failures;
  * killing the leader elects a new one and the state machines converge;
  * snapshots truncate the command log and a replica restarted from a snapshot
    replays the suffix and converges.
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from .errors import NotLeader
from .metadata import MetadataState


@dataclass
class _Entry:
    term: int
    cmd: Tuple


class Replica:
    def __init__(self, rid: int, make_state: Callable[[], MetadataState]) -> None:
        self.rid = rid
        self.make_state = make_state
        self.state = make_state()
        self.log: List[_Entry] = []
        self.commit_index = -1      # highest applied entry index
        self.snapshot_index = -1    # entries <= this are compacted into `snapshot`
        self.snapshot: Optional[bytes] = None
        self.alive = True

    def append_entry(self, entry: _Entry) -> bool:
        if not self.alive:
            return False
        self.log.append(entry)
        return True

    def apply_to(self, index: int) -> None:
        """Apply committed entries up to `index` (0-based global index)."""
        while self.commit_index < index:
            self.commit_index += 1
            local = self.commit_index - self.snapshot_index - 1
            entry = self.log[local]
            try:
                self.state.apply(entry.cmd)
            except Exception:
                # Deterministic command failures (e.g. ForkBlocked) are part of
                # the state machine contract: every replica fails identically
                # and the state is unchanged; the leader surfaces the error.
                pass

    def take_snapshot(self) -> None:
        self.snapshot = pickle.dumps(self.state)
        drop = self.commit_index - self.snapshot_index
        self.log = self.log[drop:]
        self.snapshot_index = self.commit_index

    def restore_from(self, other: "Replica") -> None:
        """Crash-recovery: install peer snapshot + replay suffix."""
        assert other.snapshot is not None
        self.state = pickle.loads(other.snapshot)
        self.snapshot = other.snapshot
        self.snapshot_index = other.snapshot_index
        self.commit_index = other.snapshot_index
        self.log = list(other.log)
        self.apply_to(other.commit_index)


class MetadataService:
    """Client-facing façade: propose() commands, query the leader's state."""

    def __init__(self, n_replicas: int = 3, snapshot_every: int = 0,
                 **state_kwargs) -> None:
        make_state = lambda: MetadataState(**state_kwargs)  # noqa: E731
        self.replicas = [Replica(i, make_state) for i in range(n_replicas)]
        self.term = 1
        self.leader_id = 0
        self.snapshot_every = snapshot_every
        self._since_snapshot = 0
        self.proposals = 0

    # -- leadership ------------------------------------------------------------
    @property
    def leader(self) -> Replica:
        return self.replicas[self.leader_id]

    def fail_replica(self, rid: int) -> None:
        self.replicas[rid].alive = False
        if rid == self.leader_id:
            self._elect()

    def recover_replica(self, rid: int) -> None:
        r = self.replicas[rid]
        r.alive = True
        donor = max((p for p in self.replicas if p.alive and p.rid != rid),
                    key=lambda p: p.commit_index)
        if donor.commit_index > r.commit_index:
            if donor.snapshot is None:
                donor.take_snapshot()
            r.restore_from(donor)

    def _elect(self) -> None:
        alive = [r for r in self.replicas if r.alive]
        if len(alive) * 2 <= len(self.replicas):
            raise RuntimeError("no quorum: metadata layer unavailable")
        # most-up-to-date alive replica wins (Raft's log-completeness rule)
        winner = max(alive, key=lambda r: (len(r.log) + r.snapshot_index, -r.rid))
        self.leader_id = winner.rid
        self.term += 1
        # discard uncommitted suffix (never acked to clients)
        for r in alive:
            keep = winner.commit_index - r.snapshot_index
            r.log = r.log[:max(0, keep)]

    # -- the SMR write path ------------------------------------------------------
    def propose(self, cmd: Tuple, replica_hint: Optional[int] = None) -> object:
        """Sequence `cmd`, commit at majority, apply everywhere, return the
        leader's apply result (or raise its deterministic error)."""
        if replica_hint is not None and replica_hint != self.leader_id:
            raise NotLeader(f"replica {replica_hint} is not the leader")
        entry = _Entry(self.term, cmd)
        acked = []
        for r in self.replicas:
            if r.alive and r.append_entry(entry):
                acked.append(r)
        if len(acked) * 2 <= len(self.replicas):
            # roll back: the entry was never committed (nor applied anywhere),
            # so leaving it in minority logs would skew the global index of
            # every later proposal after recovery
            for r in acked:
                r.log.pop()
            raise RuntimeError("no quorum: append not committed")
        # global index of the just-appended entry: entries [0..snapshot_index]
        # are compacted, so global = snapshot_index + local_length
        index = self.leader.snapshot_index + len(self.leader.log)
        result: object = None
        error: Optional[Exception] = None
        for r in self.replicas:
            if not r.alive:
                continue
            if r is self.leader:
                # capture leader's apply result/error explicitly
                while r.commit_index < index - 1:
                    r.apply_to(index - 1)
                r.commit_index = index
                try:
                    result = r.state.apply(entry.cmd)
                except Exception as e:  # deterministic command error
                    error = e
            else:
                r.apply_to(index)
        self.proposals += 1
        self._since_snapshot += 1
        if self.snapshot_every and self._since_snapshot >= self.snapshot_every:
            for r in self.replicas:
                if r.alive:
                    r.take_snapshot()
            self._since_snapshot = 0
        if error is not None:
            raise error
        return result

    # -- linearizable reads (leader-local) -------------------------------------
    @property
    def state(self) -> MetadataState:
        return self.leader.state

    def check_convergence(self) -> bool:
        """All alive replicas have identical applied state (test hook).

        The digest covers live log ids AND per-log tails, so a replica that
        diverged in *content* while agreeing on *membership* — e.g. by
        replaying a batched append differently after a snapshot restore — is
        caught, not just one that lost a whole log.
        """
        def digest(state: MetadataState) -> bytes:
            ids = state.live_log_ids()
            return pickle.dumps([(lid, state.tails.get(lid)) for lid in ids])

        blobs = {digest(r.state)
                 for r in self.replicas if r.alive and r.commit_index == self.leader.commit_index}
        return len(blobs) <= 1
