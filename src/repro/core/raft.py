"""Fault-tolerant metadata layer: a minimal in-process replicated SMR group.

The paper's metadata layer is "a fault-tolerant group that implements state-
machine replication using Paxos or Raft" (§5.2). We implement the SMR contract
the rest of Bolt depends on — a single totally-ordered command log applied
deterministically on every replica, with majority commit, leader failover, and
snapshot/compaction — without a wire protocol (single-process container).

Two replication paths (DESIGN.md §16):

* **Direct** (``faults=None``): replicas are updated by direct call inside
  ``propose`` — the seed behavior, byte-identical to the pre-§16 system.
* **Message mode** (a :class:`~repro.core.faults.FaultPlane` attached):
  replication is reified as explicit term-tagged messages — AppendEntries
  with prev-index/term consistency checks and conflict truncation, vote
  requests, snapshot installs, and their acks — each routed through the
  plane's deterministic :class:`~repro.core.faults.Network`. Partitions,
  drops, delays, duplicates and reordering therefore hit the consensus
  traffic itself: a stale leader is fenced by term (``NotLeader``), its
  lease-fenced local reads expire (``LeaseExpired``), elections make
  progress on the majority side of a partition, and divergent minority
  suffixes are truncated when reconciliation traffic reaches them on heal.

Properties exercised by tests:
  * a committed command survives any minority of replica failures;
  * killing the leader elects a new one and the state machines converge;
  * snapshots truncate the command log and a replica restarted from a snapshot
    replays the suffix and converges;
  * under partitions the majority side keeps committing, the minority side's
    leader is term-fenced, and heal + ``sync_followers`` reconverges every
    replica (``tests/test_network_faults.py``, ``test_fault_tolerance_e2e``).
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .errors import (AmbiguousProposal, LeaseExpired, NoQuorum, NotLeader,
                     Unavailable)
from .faults import RetryPolicy, RetryStats, run_with_retries
from .metadata import MetadataState


@dataclass
class _Entry:
    term: int
    cmd: Tuple


class Replica:
    def __init__(self, rid: int, make_state: Callable[[], MetadataState]) -> None:
        self.rid = rid
        self.make_state = make_state
        self.state = make_state()
        self.log: List[_Entry] = []
        self.commit_index = -1      # highest COMMITTED entry index
        self.applied_index = -1     # highest entry applied to the state machine
        self.snapshot_index = -1    # entries <= this are compacted into `snapshot`
        self.snapshot: Optional[bytes] = None
        self.snapshot_term = 0      # term of the last entry inside `snapshot`
        self.alive = True
        self.lazy_applies = 0       # entries applied via deferred batches
        # -- message-mode raft state (DESIGN.md §16) -----------------------
        self.current_term = 1       # highest term this replica has seen
        self.voted_for: Optional[int] = None   # candidate granted in current_term
        self.is_leader = False      # LOCAL belief — a partitioned deposed
                                    # leader keeps believing until a higher
                                    # term reaches it (that is the fencing
                                    # scenario the §16 tests drive)
        self.lease_until = 0.0      # leader-lease horizon on the DES clock

    def append_entry(self, entry: _Entry) -> bool:
        if not self.alive:
            return False
        self.log.append(entry)
        return True

    @property
    def pending_applies(self) -> int:
        return self.commit_index - self.applied_index

    # -- log coordinates (global index space; entries <= snapshot_index are
    # compacted away but their positions remain occupied) ---------------------
    @property
    def last_index(self) -> int:
        return self.snapshot_index + len(self.log)

    @property
    def last_term(self) -> int:
        return self.log[-1].term if self.log else self.snapshot_term

    def term_at(self, index: int) -> int:
        """Term of the entry at global ``index`` (snapshot boundary term for
        the compacted prefix — exact at the boundary, which is the only
        compacted position the prev-check ever consults)."""
        if index < 0:
            return 0
        if index <= self.snapshot_index:
            return self.snapshot_term
        return self.log[index - self.snapshot_index - 1].term

    # -- message handlers (DESIGN.md §16) -------------------------------------
    # Each returns a reply payload, or None when the replica is dead (the
    # network reports an unreachable destination as a lost message). Handlers
    # are duplicate- and reorder-safe: a redelivered AppendEntries is a no-op
    # (same term + same entries), a stale one is fenced by term.

    def _observe_term(self, term: int) -> None:
        if term > self.current_term:
            self.current_term = term
            self.voted_for = None
            self.is_leader = False   # a higher term deposes any local belief

    def on_append_entries(self, payload: tuple):
        """AppendEntries: term fence, prev-index/term consistency check with
        conflict truncation, idempotent append, commit piggyback. Replies
        ``("ok", last_index)``, ``("reject_term", higher_term)`` (the fencing
        signal), or ``("reject_log", hint)`` (backtrack ``next_index`` to
        ``hint + 1``)."""
        if not self.alive:
            return None
        term, prev, prev_term, entries, leader_commit = payload
        if term < self.current_term:
            return ("reject_term", self.current_term)
        self._observe_term(term)
        if prev > self.last_index:
            return ("reject_log", self.last_index)       # gap: fast backtrack
        if prev > self.snapshot_index and self.term_at(prev) != prev_term:
            # conflicting entry at prev: drop it and the divergent suffix
            # after it. Committed prefixes never conflict (majority-
            # intersection), so this can only touch uncommitted entries.
            assert prev > self.commit_index, "conflict below commit point"
            del self.log[prev - self.snapshot_index - 1:]
            return ("reject_log", prev - 1)
        for i, e in enumerate(entries):
            g = prev + 1 + i
            if g <= self.snapshot_index:
                continue              # compacted == committed == identical
            local = g - self.snapshot_index - 1
            if local < len(self.log):
                if self.log[local].term == e.term:
                    continue          # duplicate delivery: no-op
                assert g > self.commit_index, "truncation below commit point"
                del self.log[local:]  # divergent suffix: truncate, replace
            self.log.append(e)
        if leader_commit > self.commit_index:
            # piggybacked commit (pipelined, §11: apply stays deferred)
            self.commit_index = min(leader_commit, self.last_index)
        return ("ok", self.last_index)

    def on_pre_vote(self, payload: tuple):
        """PreVote (raft §9.6): answer how RequestVote WOULD go, without
        adopting the term or recording a vote. Keeps a partitioned minority's
        doomed candidacies from perturbing terms — in particular, a deposed
        leader stranded with minority peers keeps believing it leads (the
        fencing scenario) instead of being deposed by a neighbor's hopeless
        campaign."""
        if not self.alive:
            return None
        term, candidate, last_term, last_index = payload
        if term < self.current_term:
            return ("deny", self.current_term)
        if (last_term, last_index) >= (self.last_term, self.last_index):
            return ("grant", self.current_term)
        return ("deny", self.current_term)

    def on_request_vote(self, payload: tuple):
        """RequestVote: grant at most one vote per term, and only to a
        candidate whose log is at least as up-to-date (Raft's election
        restriction — it is what keeps committed entries on every electable
        leader)."""
        if not self.alive:
            return None
        term, candidate, last_term, last_index = payload
        if term < self.current_term:
            return ("deny", self.current_term)
        self._observe_term(term)
        if self.voted_for is not None and self.voted_for != candidate:
            return ("deny", self.current_term)
        if (last_term, last_index) >= (self.last_term, self.last_index):
            self.voted_for = candidate
            return ("grant", self.current_term)
        return ("deny", self.current_term)

    def on_install_snapshot(self, payload: tuple):
        """InstallSnapshot: a follower behind the leader's compaction horizon
        restores the snapshot and resumes AppendEntries from there. A stale
        or duplicated install (snapshot at-or-below our commit) is a no-op."""
        if not self.alive:
            return None
        term, snapshot, sidx, sterm = payload
        if term < self.current_term:
            return ("reject_term", self.current_term)
        self._observe_term(term)
        if sidx <= self.commit_index:
            return ("ok", self.last_index)
        self.state = pickle.loads(snapshot)
        self.snapshot = snapshot
        self.snapshot_index = sidx
        self.snapshot_term = sterm
        self.commit_index = sidx
        self.applied_index = sidx
        self.log = []
        return ("ok", sidx)

    def apply_to(self, index: int) -> None:
        """Apply committed entries up to `index` (0-based global index)."""
        while self.applied_index < index:
            self.applied_index += 1
            local = self.applied_index - self.snapshot_index - 1
            entry = self.log[local]
            try:
                self.state.apply(entry.cmd)
            except Exception:
                # Deterministic command failures (e.g. ForkBlocked) are part
                # of the state machine contract: every replica fails
                # identically, leaving identical state (a failed append still
                # registers its orphaned PUT object for GC, §13, but does so
                # before raising — deterministically); the leader surfaces
                # the error.
                pass
        if self.commit_index < index:
            self.commit_index = index

    def apply_pending(self) -> int:
        """Drain the deferred-apply backlog (pipelined followers, DESIGN.md
        §11): one sequential batch replay instead of per-proposal work."""
        n = self.pending_applies
        if n > 0:
            self.lazy_applies += n
            self.apply_to(self.commit_index)
        return n

    def take_snapshot(self) -> None:
        self.apply_pending()   # a snapshot serializes APPLIED state
        self.snapshot_term = self.term_at(self.commit_index)
        self.snapshot = pickle.dumps(self.state)
        drop = self.commit_index - self.snapshot_index
        self.log = self.log[drop:]
        self.snapshot_index = self.commit_index

    def restore_from(self, other: "Replica") -> None:
        """Crash-recovery: install peer snapshot + replay suffix."""
        assert other.snapshot is not None
        self.state = pickle.loads(other.snapshot)
        self.snapshot = other.snapshot
        self.snapshot_index = other.snapshot_index
        self.snapshot_term = other.snapshot_term
        self.commit_index = other.snapshot_index
        self.applied_index = other.snapshot_index
        self.log = list(other.log)
        self.apply_to(other.commit_index)
        # term/vote are persisted state in raft; leadership belief is not
        self.current_term = max(self.current_term, other.current_term)
        self.voted_for = None
        self.is_leader = False


class MetadataService:
    """Client-facing façade: propose() commands, query the leader's state."""

    def __init__(self, n_replicas: int = 3, snapshot_every: int = 0,
                 pipeline_apply: bool = True, **state_kwargs) -> None:
        make_state = lambda: MetadataState(**state_kwargs)  # noqa: E731
        self.replicas = [Replica(i, make_state) for i in range(n_replicas)]
        self.term = 1
        self.leader_id = 0
        self.snapshot_every = snapshot_every
        self._since_snapshot = 0
        self.proposals = 0
        # Pipelined replica apply (DESIGN.md §11): followers only append the
        # entry and advance their commit index on the propose critical path;
        # the state-machine apply is deferred and batch-replayed on snapshot,
        # failover, recovery, and convergence checks. With it off, every
        # replica applies synchronously inside propose() (the seed behavior).
        self.pipeline_apply = pipeline_apply
        # Fault plane + client retry policy (DESIGN.md §15). With no plane
        # attached, propose() is the plain synchronous path below — no token
        # wrapping, no retry loop, byte-identical to the pre-§15 system.
        self.faults = None
        self.retry = RetryPolicy()
        self.retry_stats = RetryStats()
        self._token_seq = 0
        self.elections = 0
        # message-mode replication bookkeeping (DESIGN.md §16): per
        # (leader, follower) link, the next global index to send — raft's
        # next_index, reset on every election
        self._next_index: Dict[Tuple[int, int], int] = {}
        self._electing = False       # reentrancy guard (election -> noop
                                     # barrier -> NoQuorum -> election ...)
        self.lease_reads = 0         # reads served by the lease fast path (§18)
        self.lease_fallbacks = 0     # reads that took the slow/barrier path
        self.replicas[0].is_leader = True

    # -- leadership ------------------------------------------------------------
    @property
    def leader(self) -> Replica:
        return self.replicas[self.leader_id]

    def fail_replica(self, rid: int) -> None:
        r = self.replicas[rid]
        r.alive = False
        r.is_leader = False      # leadership belief is volatile, not persisted
        if rid == self.leader_id:
            if self.faults is not None:
                self._elect_msg()
            else:
                self._elect()

    def recover_replica(self, rid: int) -> None:
        r = self.replicas[rid]
        r.alive = True
        donor = max((p for p in self.replicas if p.alive and p.rid != rid),
                    key=lambda p: p.commit_index)
        if donor.commit_index > r.commit_index:
            # The donor won on commit_index, which says nothing about its
            # APPLIED state: a pipelined follower (§11) may carry a stale
            # snapshot from an earlier compaction plus a deferred-apply
            # backlog — its log is shorter than its commit point. Drain the
            # backlog and refresh the snapshot so the recovering replica
            # installs fully-applied state and replays only the (empty)
            # suffix, instead of re-running the donor's whole backlog.
            donor.apply_pending()
            if donor.snapshot is None or donor.snapshot_index < donor.commit_index:
                donor.take_snapshot()
            r.restore_from(donor)

    def _elect(self) -> None:
        alive = [r for r in self.replicas if r.alive]
        if len(alive) * 2 <= len(self.replicas):
            raise NoQuorum("no quorum: metadata layer unavailable")
        self.elections += 1
        # most-up-to-date alive replica wins (Raft's log-completeness rule)
        winner = max(alive, key=lambda r: (len(r.log) + r.snapshot_index, -r.rid))
        self.leader_id = winner.rid
        self.term += 1
        # discard uncommitted suffix (never acked to clients)
        for r in alive:
            keep = winner.commit_index - r.snapshot_index
            r.log = r.log[:max(0, keep)]
        # a pipelined follower stepping up must serve linearizable reads:
        # drain its deferred-apply backlog before taking queries
        winner.apply_pending()
        for r in self.replicas:
            r.is_leader = r is winner

    # -- message-mode leadership (DESIGN.md §16) -------------------------------
    def _elect_msg(self) -> None:
        """Message-routed election: candidates stand in up-to-dateness order,
        each soliciting votes through the network at a fresh term; the first
        to assemble a majority of grants wins. Progress is exactly the raft
        condition — some candidate can reach a voting majority — so the
        majority side of a partition elects and the minority side cannot."""
        if self._electing:
            raise NoQuorum("election already in progress")
        plane = self.faults
        net = plane.net
        alive = [r for r in self.replicas if r.alive]
        n = len(self.replicas)
        if len(alive) * 2 <= n:
            raise NoQuorum("no quorum: metadata layer unavailable")
        self._electing = True
        try:
            term_try = max(self.term, max(r.current_term for r in alive)) + 1
            for cand in sorted(alive, reverse=True,
                               key=lambda r: (r.last_term, r.last_index,
                                              -r.rid)):
                # pre-vote round (§9.6): a term-neutral reachability +
                # up-to-dateness probe. A candidate that cannot assemble a
                # pre-vote majority (it is on the minority side) skips the
                # real candidacy, leaving every term untouched.
                pre = 1
                for r in self.replicas:
                    if r is cand or not r.alive:
                        continue
                    reply = net.send(cand.rid, r.rid, r.on_pre_vote,
                                     (term_try, cand.rid, cand.last_term,
                                      cand.last_index))
                    if reply is not None and reply[0] == "grant":
                        pre += 1
                if pre * 2 <= n:
                    continue
                cand.current_term = max(cand.current_term, term_try)
                term_try = cand.current_term
                cand.voted_for = cand.rid
                votes = 1
                for r in self.replicas:
                    if r is cand or not r.alive:
                        continue
                    reply = net.send(cand.rid, r.rid, r.on_request_vote,
                                     (term_try, cand.rid, cand.last_term,
                                      cand.last_index))
                    if reply is None:
                        continue             # unreachable / message lost
                    status, info = reply
                    if status == "grant":
                        votes += 1
                    elif info > term_try:
                        term_try = info      # a higher term is out there
                if votes * 2 > n:
                    self.leader_id = cand.rid
                    self.term = cand.current_term
                    cand.is_leader = True
                    cand.lease_until = plane.now + plane.config.lease_duration
                    self.elections += 1
                    self._next_index = {}
                    # a pipelined winner must serve linearizable reads (§11)
                    cand.apply_pending()
                    # no-op barrier (raft §8): the winner's log holds every
                    # committed entry (vote restriction) but its commit index
                    # may lag an entry the old leader committed whose ack to
                    # this replica was lost. One current-term no-op commits
                    # that prefix so leader-local reads are never stale.
                    # Best-effort: if its messages fail, `_read_barrier`
                    # retries at read time.
                    if cand.last_index > cand.commit_index:
                        try:
                            self._propose_once(("noop",))
                        except Unavailable:
                            pass
                    return
                term_try += 1                # failed candidacy burns the term
            raise NoQuorum(
                "no electable majority: every candidacy failed to gather "
                "votes (network partition?)")
        finally:
            self._electing = False

    def _maybe_elect(self) -> None:
        """Best-effort election after a fencing event: if no majority is
        reachable right now the caller's NotLeader/NoQuorum still propagates
        and the client's retry policy re-drives the election later."""
        try:
            self._elect_msg()
        except Unavailable:
            pass

    # -- the SMR write path ------------------------------------------------------
    def propose(self, cmd: Tuple, replica_hint: Optional[int] = None) -> object:
        """Sequence `cmd`, commit at majority, apply everywhere, return the
        leader's apply result (or raise its deterministic error).

        With a fault plane attached (DESIGN.md §15) this is the full client
        submit path: the command is wrapped with a fresh idempotency token —
        deduplicated in the replicated state, so a retry after an ambiguous
        (committed-but-unacked) outcome applies at most once — and every
        transient :class:`Unavailable` is retried under the bounded backoff
        policy. Without a plane it is the plain synchronous path."""
        if replica_hint is not None and replica_hint != self.leader_id:
            raise NotLeader(f"replica {replica_hint} is not the leader")
        plane = self.faults
        if plane is None or not plane.enabled:
            return self._propose_once(cmd)
        token = f"t{self._token_seq}"
        self._token_seq += 1
        wrapped = ("idem", token, cmd)
        return run_with_retries(lambda _attempt: self._propose_once(wrapped),
                                self.retry, plane.rng, stats=self.retry_stats)

    def _propose_once(self, cmd: Tuple) -> object:
        plane = self.faults
        if plane is not None and plane.fire("leader_crash"):
            # the leader dies before appending the entry anywhere: nothing
            # committed. Failing it triggers the election (which may itself
            # find no quorum); the client retries against the new leader.
            dead = self.leader_id
            self.fail_replica(dead)
            raise Unavailable(
                f"leader replica {dead} crashed mid-operation (injected)")
        entry = _Entry(self.term, cmd)
        if plane is None:
            acked = self._replicate_direct(entry)
        else:
            acked = self._replicate_msg(entry)
        result, error = self._commit_acked(self.leader, entry, acked)
        if plane is not None and plane.fire("propose_unacked"):
            # committed-but-unacked (DESIGN.md §15): the entry is committed
            # and applied, but the ack is lost. The client may retry ONLY
            # because the command rides an idempotency token — the replicated
            # dedup table returns this apply's cached outcome instead of
            # applying twice.
            raise AmbiguousProposal(
                "propose timed out after commit: outcome unacked (injected)")
        if error is not None:
            raise error
        return result

    # -- replication paths (DESIGN.md §16) -------------------------------------
    def _replicate_direct(self, entry: _Entry) -> List[Replica]:
        """Seed path (``faults=None``): append by direct call, roll back on a
        lost majority — byte-identical to the pre-§16 system."""
        acked = []
        for r in self.replicas:
            if r.alive and r.append_entry(entry):
                acked.append(r)
        if len(acked) * 2 <= len(self.replicas):
            # roll back: the entry was never committed (nor applied anywhere),
            # so leaving it in minority logs would skew the global index of
            # every later proposal after recovery
            for r in acked:
                r.log.pop()
            raise NoQuorum("no quorum: append not committed")
        return acked

    def _replicate_msg(self, entry: _Entry,
                       leader: Optional[Replica] = None) -> List[Replica]:
        """Message path: the leader appends locally, then drives each alive
        follower up to its last entry via AppendEntries through the network.
        Unlike the direct path there is NO rollback on a lost majority — a
        minority-acked entry lingers in those logs (raft's behavior) and is
        either committed later under a current-term majority or truncated by
        the conflict check when a new leader's log reaches it; the §15
        idempotency table absorbs the committed-then-retried duplicates.

        ``leader`` overrides the facade leader for the stale-leader client
        path (:meth:`propose_via`): the deposed replica replicates under its
        own stale term and the quorum's higher term fences it (NotLeader)."""
        L = self.leader if leader is None else leader
        facade = leader is None
        if not L.alive:
            if facade:
                self._maybe_elect()
            raise NotLeader(f"replica {L.rid} is dead, cannot lead")
        if facade:
            L.current_term = max(L.current_term, self.term)
        entry.term = L.current_term    # a stale leader stamps its stale term
        L.log.append(entry)
        acked = [L]
        fenced: Optional[int] = None
        for r in self.replicas:
            if r is L or not r.alive:
                continue
            status, _rounds = self._catch_up(L, r)
            if status == "ok":
                acked.append(r)
            elif isinstance(status, tuple):    # ("fenced", higher_term)
                fenced = status[1]
                break
        if fenced is not None:
            # term fence (§16): some replica has seen a higher term, so this
            # leader is deposed. It steps down — adopting the higher term and
            # dropping its leadership belief — and the client fails over.
            L.current_term = max(L.current_term, fenced)
            L.is_leader = False
            if facade:
                # the facade's notion of leadership is stale too (an aborted
                # election left adopted terms behind): re-elect at a term
                # above everything seen
                self._maybe_elect()
            raise NotLeader(
                f"replica {L.rid} deposed: term {entry.term} fenced by "
                f"term {fenced}")
        if len(acked) * 2 <= len(self.replicas):
            if facade:
                # the current leader cannot reach a majority (partitioned
                # away, or the messages died): try to fail leadership over to
                # a side that can — raft's heartbeat-timeout election, driven
                # here by the failed round. The client's retry then lands on
                # the new leader.
                self._maybe_elect()
            raise NoQuorum(
                f"no quorum: append reached {len(acked)}/"
                f"{len(self.replicas)} replicas")
        return acked

    def _catch_up(self, L: Replica, r: Replica):
        """Drive follower ``r`` to ``L``'s last entry with AppendEntries
        rounds (next_index backtracking on log rejects, snapshot install when
        the follower is behind the leader's compaction horizon), all routed
        through the network. Returns ``(status, rounds)`` where status is
        ``"ok"``, ``"unreachable"`` (message lost / partitioned / dead) or
        ``("fenced", higher_term)``."""
        plane = self.faults
        net = plane.net
        key = (L.rid, r.rid)
        last = L.last_index
        next_idx = min(self._next_index.get(key, last + 1), last + 1)
        rounds = 0
        # Bounded: every round either succeeds, loses a message, or moves
        # next_idx strictly down; the +4 covers a snapshot install round-trip.
        for _ in range(2 * (last - L.snapshot_index) + 4):
            rounds += 1
            if next_idx <= L.snapshot_index:
                # follower needs entries the leader has compacted away
                reply = net.send(L.rid, r.rid, r.on_install_snapshot,
                                 (L.current_term, L.snapshot,
                                  L.snapshot_index, L.snapshot_term))
                if reply is None:
                    return "unreachable", rounds
                status, info = reply
                if status == "reject_term":
                    plane.note("fenced_rejections")
                    return ("fenced", info), rounds
                next_idx = info + 1
                continue
            prev = next_idx - 1
            lo = next_idx - L.snapshot_index - 1
            reply = net.send(L.rid, r.rid, r.on_append_entries,
                             (L.current_term, prev, L.term_at(prev),
                              tuple(L.log[lo:]), L.commit_index))
            if reply is None:
                return "unreachable", rounds
            status, info = reply
            if status == "ok":
                self._next_index[key] = info + 1
                # piggybacked commit on the ack leg: the ack proves r holds
                # the leader's prefix through `info`
                if min(L.commit_index, info) > r.commit_index:
                    r.commit_index = min(L.commit_index, info)
                return "ok", rounds
            if status == "reject_term":
                plane.note("fenced_rejections")
                return ("fenced", info), rounds
            next_idx = min(next_idx - 1, info + 1)    # reject_log hint
        return "unreachable", rounds     # pathological flapping: give up,
                                         # treated as a lost ack (no commit)

    def _commit_acked(self, L: Replica, entry: _Entry, acked: List[Replica]):
        """Majority in hand: advance commits, apply on the leader (capturing
        its result/error), run the snapshot cadence, extend the leader lease.
        Shared tail of both replication paths."""
        # global index of the just-appended entry: entries [0..snapshot_index]
        # are compacted, so global = snapshot_index + local_length
        index = L.snapshot_index + len(L.log)
        result: object = None
        error: Optional[Exception] = None
        for r in acked:
            if r is L:
                # capture leader's apply result/error explicitly
                if r.applied_index < index - 1:
                    r.apply_to(index - 1)
                r.commit_index = index
                r.applied_index = index
                try:
                    result = r.state.apply(entry.cmd)
                except Exception as e:  # deterministic command error
                    error = e
            elif self.pipeline_apply:
                # pipelined (DESIGN.md §11): the follower's durable vote is
                # the log append; advancing its commit index is all the
                # critical path needs — the state-machine apply is deferred
                if index > r.commit_index:
                    r.commit_index = index
            else:
                r.apply_to(index)
        if self.faults is not None:
            # a majority ack round is a lease grant (§16): the leader may
            # serve fenced local reads until the DES clock passes the horizon
            L.lease_until = self.faults.now + self.faults.config.lease_duration
        self.proposals += 1
        self._since_snapshot += 1
        if self.snapshot_every and self._since_snapshot >= self.snapshot_every:
            for r in self.replicas:
                if r.alive:
                    r.take_snapshot()
            self._since_snapshot = 0
        return result, error

    def propose_via(self, rid: int, cmd: Tuple) -> object:
        """Submit ``cmd`` through a SPECIFIC replica as if it were the leader
        — the stale-leader client path (§16). A replica that never led (or
        already observed its deposition) rejects locally with ``NotLeader``;
        a partitioned deposed leader that still believes it leads replicates
        under its stale term and is fenced by the quorum's higher term
        (``NotLeader``) or cannot assemble a majority (``NoQuorum``). Either
        way nothing commits through it — that is the §16 safety property."""
        r = self.replicas[rid]
        if rid == self.leader_id and (self.faults is None or r.is_leader):
            return self._propose_once(cmd)
        if self.faults is None or not r.is_leader or not r.alive:
            raise NotLeader(f"replica {rid} is not the leader")
        entry = _Entry(r.current_term, cmd)
        acked = self._replicate_msg(entry, leader=r)
        # Unreachable for a genuinely stale leader (quorum intersection: an
        # elected majority adopted a higher term, so a stale-term append can
        # reach at most a minority). Commit defensively if it ever acks.
        result, error = self._commit_acked(r, entry, acked)
        if error is not None:
            raise error
        return result

    def read_fenced(self, rid: Optional[int] = None) -> MetadataState:
        """Lease-fenced local read (§16): return the replica's state only
        while its leader lease is valid on the plane's DES clock. A deposed
        partitioned leader stops winning ack rounds, its lease stops being
        extended, and once ``plane.now`` passes the horizon its local reads
        raise :class:`LeaseExpired` instead of returning stale state."""
        r = self.replicas[self.leader_id if rid is None else rid]
        plane = self.faults
        if plane is None:
            if r.rid != self.leader_id:
                raise NotLeader(f"replica {r.rid} is not the leader")
            return r.state
        if not r.alive or not r.is_leader:
            raise NotLeader(f"replica {r.rid} is not the leader")
        if plane.now > r.lease_until:
            plane.note("fenced_rejections")
            raise LeaseExpired(
                f"replica {r.rid}'s leader lease expired at "
                f"{r.lease_until:.3f} (now {plane.now:.3f}); "
                f"re-read via the current leader")
        r.apply_pending()
        return r.state

    def sync_followers(self) -> int:
        """Post-heal reconciliation (§16): bring every alive follower up to
        the leader's log, committing any lingering prior-term suffix under
        the CURRENT term (raft's commit rule: prior-term entries commit only
        beneath a current-term entry — one no-op proposal does it). Returns
        the number of message rounds used, the bench's convergence metric.
        Direct mode replicates synchronously and needs none: returns 0."""
        if self.faults is None:
            return 0
        rounds = 0
        if not self.leader.alive:
            self._elect_msg()
        if self.leader.last_index > self.leader.commit_index:
            try:
                self._propose_once(("noop",))
            except Unavailable:
                pass    # still partitioned; callers may sync again later
        fenced = False
        for r in self.replicas:
            if not r.alive or r is self.leader:
                continue
            status, used = self._catch_up(self.leader, r)
            rounds += used
            if isinstance(status, tuple):
                fenced = True
        if fenced:
            # an aborted election left a higher adopted term somewhere:
            # re-elect above it, then reconcile once more
            self._maybe_elect()
            for r in self.replicas:
                if not r.alive or r is self.leader:
                    continue
                _status, used = self._catch_up(self.leader, r)
                rounds += used
        return rounds

    # -- linearizable reads (leader-local) -------------------------------------
    def _read_barrier(self) -> None:
        """Leader with a lingering uncommitted suffix: its commit index may
        lag entries an old leader committed (raft §8), so a leader-local read
        could miss an acked write. The election's no-op barrier normally
        closes the gap; this retries it at read time if those messages
        failed. At most ONE barrier no-op is ever appended per lingering
        suffix — if the tail already is one, the retry is a replication round
        of the existing entry, so reads while partitioned don't grow the log.
        Cheap in the steady state: two int compares."""
        L = self.leader
        if not L.alive or L.last_index <= L.commit_index or self._electing:
            return
        tail = L.log[-1] if L.log else None
        if tail is not None and tail.cmd == ("noop",) \
                and tail.term == L.current_term:
            # barrier entry already in place: one round either commits it
            # (majority holds the tail under the current term) or fails again
            acked = [L]
            for r in self.replicas:
                if r is L or not r.alive:
                    continue
                status, _rounds = self._catch_up(L, r)
                if status == "ok":
                    acked.append(r)
            if len(acked) * 2 > len(self.replicas):
                self._commit_acked(L, tail, acked)
            return
        try:
            self._propose_once(("noop",))
        except Unavailable:
            pass

    @property
    def state(self) -> MetadataState:
        if self.faults is not None:
            self._read_barrier()
        return self.leader.state

    # -- lease-read fast path (DESIGN.md §18) ----------------------------------
    def read_state(self) -> MetadataState:
        """Client-facing read entry point: serve from the leader's local
        state with NO consensus traffic while its lease covers the read.

        The fast path requires all of: the leader is alive and believes it
        leads, the DES clock has not passed its lease horizon, and its log
        has no uncommitted suffix. The last condition is the linearizability
        guard the lease alone cannot give — a freshly elected leader holds a
        lease immediately, but until its no-op barrier commits, its commit
        index may lag entries the OLD leader acked (raft §8); reading then
        could miss an acked write. ``last_index <= commit_index`` is exactly
        "the barrier has landed", so the lease read returns precisely what a
        barrier read would — at two int compares and a clock check instead
        of a replication round.

        Any condition failing falls back to :meth:`_read_state_slow`, which
        re-elects / re-barriers / renews the lease under the client
        ``RetryPolicy`` — the ``LeaseExpired``/``NotLeader`` fallback rule.
        Without a fault plane there is no clock and no lease to fence on;
        reads stay on the plain leader-local path (pre-§18, byte-identical).
        """
        plane = self.faults
        if plane is None:
            return self.leader.state
        L = self.leader
        if (L.alive and L.is_leader and plane.now <= L.lease_until
                and L.last_index <= L.commit_index):
            self.lease_reads += 1
            L.apply_pending()
            return L.state
        self.lease_fallbacks += 1
        return self._read_state_slow()

    def _read_state_slow(self) -> MetadataState:
        """Lease-read fallback: drive whatever the fast path found missing —
        a dead/deposed leader re-elects, a lingering uncommitted suffix
        re-runs the barrier, an expired lease renews through one committed
        no-op ack round (commit extends the lease, §16) — then serve through
        ``read_fenced()``. Runs under the client retry policy: a partitioned
        minority leader keeps failing here until the partition heals or the
        retry budget raises ``RetryBudgetExhausted``."""
        plane = self.faults

        def attempt(_n: int) -> MetadataState:
            if not self.leader.alive or not self.leader.is_leader:
                self._elect_msg()
            self._read_barrier()
            if plane.now > self.leader.lease_until:
                self._propose_once(("noop",))   # committed ack round renews
            return self.read_fenced()

        return run_with_retries(attempt, self.retry, plane.rng,
                                stats=self.retry_stats)

    def check_convergence(self) -> bool:
        """All alive replicas have identical applied state (test hook).

        The digest covers membership, tails, AND per-log index-run content
        (object ids + offsets/lengths, frozen stand-ins included): a replica
        that replayed a promote splice differently but landed on the same
        tails — same positions, different byte mapping — is caught, not just
        one that lost a whole log. With pipelined apply, every replica's
        deferred backlog is drained first: convergence is a statement about
        applied state, not about queued entries.

        In message mode (§16) the followers are reconciled first: replication
        is asynchronous-by-fault there, so a healed system legitimately holds
        stale followers until reconciliation traffic reaches them.
        """
        if self.faults is not None:
            self.sync_followers()

        def digest(state: MetadataState) -> bytes:
            items = []
            for lid, m in sorted(state.logs.items()):
                tails = state.tails.get(lid) if state.tails.contains(lid) else None
                items.append((lid, m.kind, m.parent, m.fork_point, tails,
                              m.stands_for, sorted(m.hli_children),
                              sorted(m.promotable_forks.items()),
                              m.index.content_digest()))
            # segment-GC manifests (§13): replicas must agree not only on the
            # log forest but on refcounts, the candidate queue (order
            # included — it decides future reclaim order), and the reclaimed
            # set, or a failover would reclaim different objects
            gc_items = (sorted(state.object_refs.items()),
                        tuple(state._reclaimable),
                        sorted(state.reclaimed))
            # compaction + tiering manifests (§14): byte-granular refcounts,
            # learned object sizes, birth ticks (they decide future demotion
            # eligibility), and the replicated cold-placement set must match
            # too, or a failover would compact/demote different objects
            compact_items = (sorted(state.object_ref_bytes.items()),
                             sorted(state.object_bytes.items()),
                             sorted(state.object_birth.items()),
                             sorted(state.cold_objects),
                             state.op_seq, state.compact_epoch)
            # idempotency dedup table (§15): content AND order — insertion
            # order is consensus order, and it decides future FIFO evictions,
            # so replicas that agree on entries but not order would diverge
            # at the next eviction. Each outcome is pickled in ISOLATION:
            # cached results are live objects that may share identity with
            # other state on one replica but not another (e.g. after a
            # snapshot round-trip), and pickle's memoization would turn that
            # invisible identity difference into a digest mismatch.
            idem_items = tuple((tok, pickle.dumps(outcome))
                               for tok, outcome in state.idem_results.items())
            # The same isolation applies to the digest as a whole: two logs
            # on one replica may share a tails tuple or index-run object that
            # a snapshot-restored peer reconstructs as distinct (equal)
            # objects, so each component is pickled separately and the digest
            # is built from the independent byte strings.
            return pickle.dumps((tuple(pickle.dumps(it) for it in items),
                                 tuple(pickle.dumps(it) for it in gc_items),
                                 tuple(pickle.dumps(it) for it in compact_items),
                                 idem_items))

        blobs = set()
        for r in self.replicas:
            if not (r.alive and r.commit_index == self.leader.commit_index):
                continue
            r.apply_pending()
            blobs.add(digest(r.state))
        return len(blobs) <= 1
