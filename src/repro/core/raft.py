"""Fault-tolerant metadata layer: a minimal in-process replicated SMR group.

The paper's metadata layer is "a fault-tolerant group that implements state-
machine replication using Paxos or Raft" (§5.2). We implement the SMR contract
the rest of Bolt depends on — a single totally-ordered command log applied
deterministically on every replica, with majority commit, leader failover, and
snapshot/compaction — without the wire protocol (single-process container).

Properties exercised by tests:
  * a committed command survives any minority of replica failures;
  * killing the leader elects a new one and the state machines converge;
  * snapshots truncate the command log and a replica restarted from a snapshot
    replays the suffix and converges.
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from .errors import NotLeader
from .metadata import MetadataState


@dataclass
class _Entry:
    term: int
    cmd: Tuple


class Replica:
    def __init__(self, rid: int, make_state: Callable[[], MetadataState]) -> None:
        self.rid = rid
        self.make_state = make_state
        self.state = make_state()
        self.log: List[_Entry] = []
        self.commit_index = -1      # highest COMMITTED entry index
        self.applied_index = -1     # highest entry applied to the state machine
        self.snapshot_index = -1    # entries <= this are compacted into `snapshot`
        self.snapshot: Optional[bytes] = None
        self.alive = True
        self.lazy_applies = 0       # entries applied via deferred batches

    def append_entry(self, entry: _Entry) -> bool:
        if not self.alive:
            return False
        self.log.append(entry)
        return True

    @property
    def pending_applies(self) -> int:
        return self.commit_index - self.applied_index

    def apply_to(self, index: int) -> None:
        """Apply committed entries up to `index` (0-based global index)."""
        while self.applied_index < index:
            self.applied_index += 1
            local = self.applied_index - self.snapshot_index - 1
            entry = self.log[local]
            try:
                self.state.apply(entry.cmd)
            except Exception:
                # Deterministic command failures (e.g. ForkBlocked) are part
                # of the state machine contract: every replica fails
                # identically, leaving identical state (a failed append still
                # registers its orphaned PUT object for GC, §13, but does so
                # before raising — deterministically); the leader surfaces
                # the error.
                pass
        if self.commit_index < index:
            self.commit_index = index

    def apply_pending(self) -> int:
        """Drain the deferred-apply backlog (pipelined followers, DESIGN.md
        §11): one sequential batch replay instead of per-proposal work."""
        n = self.pending_applies
        if n > 0:
            self.lazy_applies += n
            self.apply_to(self.commit_index)
        return n

    def take_snapshot(self) -> None:
        self.apply_pending()   # a snapshot serializes APPLIED state
        self.snapshot = pickle.dumps(self.state)
        drop = self.commit_index - self.snapshot_index
        self.log = self.log[drop:]
        self.snapshot_index = self.commit_index

    def restore_from(self, other: "Replica") -> None:
        """Crash-recovery: install peer snapshot + replay suffix."""
        assert other.snapshot is not None
        self.state = pickle.loads(other.snapshot)
        self.snapshot = other.snapshot
        self.snapshot_index = other.snapshot_index
        self.commit_index = other.snapshot_index
        self.applied_index = other.snapshot_index
        self.log = list(other.log)
        self.apply_to(other.commit_index)


class MetadataService:
    """Client-facing façade: propose() commands, query the leader's state."""

    def __init__(self, n_replicas: int = 3, snapshot_every: int = 0,
                 pipeline_apply: bool = True, **state_kwargs) -> None:
        make_state = lambda: MetadataState(**state_kwargs)  # noqa: E731
        self.replicas = [Replica(i, make_state) for i in range(n_replicas)]
        self.term = 1
        self.leader_id = 0
        self.snapshot_every = snapshot_every
        self._since_snapshot = 0
        self.proposals = 0
        # Pipelined replica apply (DESIGN.md §11): followers only append the
        # entry and advance their commit index on the propose critical path;
        # the state-machine apply is deferred and batch-replayed on snapshot,
        # failover, recovery, and convergence checks. With it off, every
        # replica applies synchronously inside propose() (the seed behavior).
        self.pipeline_apply = pipeline_apply

    # -- leadership ------------------------------------------------------------
    @property
    def leader(self) -> Replica:
        return self.replicas[self.leader_id]

    def fail_replica(self, rid: int) -> None:
        self.replicas[rid].alive = False
        if rid == self.leader_id:
            self._elect()

    def recover_replica(self, rid: int) -> None:
        r = self.replicas[rid]
        r.alive = True
        donor = max((p for p in self.replicas if p.alive and p.rid != rid),
                    key=lambda p: p.commit_index)
        if donor.commit_index > r.commit_index:
            if donor.snapshot is None:
                donor.take_snapshot()
            r.restore_from(donor)

    def _elect(self) -> None:
        alive = [r for r in self.replicas if r.alive]
        if len(alive) * 2 <= len(self.replicas):
            raise RuntimeError("no quorum: metadata layer unavailable")
        # most-up-to-date alive replica wins (Raft's log-completeness rule)
        winner = max(alive, key=lambda r: (len(r.log) + r.snapshot_index, -r.rid))
        self.leader_id = winner.rid
        self.term += 1
        # discard uncommitted suffix (never acked to clients)
        for r in alive:
            keep = winner.commit_index - r.snapshot_index
            r.log = r.log[:max(0, keep)]
        # a pipelined follower stepping up must serve linearizable reads:
        # drain its deferred-apply backlog before taking queries
        winner.apply_pending()

    # -- the SMR write path ------------------------------------------------------
    def propose(self, cmd: Tuple, replica_hint: Optional[int] = None) -> object:
        """Sequence `cmd`, commit at majority, apply everywhere, return the
        leader's apply result (or raise its deterministic error)."""
        if replica_hint is not None and replica_hint != self.leader_id:
            raise NotLeader(f"replica {replica_hint} is not the leader")
        entry = _Entry(self.term, cmd)
        acked = []
        for r in self.replicas:
            if r.alive and r.append_entry(entry):
                acked.append(r)
        if len(acked) * 2 <= len(self.replicas):
            # roll back: the entry was never committed (nor applied anywhere),
            # so leaving it in minority logs would skew the global index of
            # every later proposal after recovery
            for r in acked:
                r.log.pop()
            raise RuntimeError("no quorum: append not committed")
        # global index of the just-appended entry: entries [0..snapshot_index]
        # are compacted, so global = snapshot_index + local_length
        index = self.leader.snapshot_index + len(self.leader.log)
        result: object = None
        error: Optional[Exception] = None
        for r in self.replicas:
            if not r.alive:
                continue
            if r is self.leader:
                # capture leader's apply result/error explicitly
                if r.applied_index < index - 1:
                    r.apply_to(index - 1)
                r.commit_index = index
                r.applied_index = index
                try:
                    result = r.state.apply(entry.cmd)
                except Exception as e:  # deterministic command error
                    error = e
            elif self.pipeline_apply:
                # pipelined (DESIGN.md §11): the follower's durable vote is
                # the log append above; advancing its commit index is all the
                # critical path needs — the state-machine apply is deferred
                r.commit_index = index
            else:
                r.apply_to(index)
        self.proposals += 1
        self._since_snapshot += 1
        if self.snapshot_every and self._since_snapshot >= self.snapshot_every:
            for r in self.replicas:
                if r.alive:
                    r.take_snapshot()
            self._since_snapshot = 0
        if error is not None:
            raise error
        return result

    # -- linearizable reads (leader-local) -------------------------------------
    @property
    def state(self) -> MetadataState:
        return self.leader.state

    def check_convergence(self) -> bool:
        """All alive replicas have identical applied state (test hook).

        The digest covers membership, tails, AND per-log index-run content
        (object ids + offsets/lengths, frozen stand-ins included): a replica
        that replayed a promote splice differently but landed on the same
        tails — same positions, different byte mapping — is caught, not just
        one that lost a whole log. With pipelined apply, every replica's
        deferred backlog is drained first: convergence is a statement about
        applied state, not about queued entries.
        """
        def digest(state: MetadataState) -> bytes:
            items = []
            for lid, m in sorted(state.logs.items()):
                tails = state.tails.get(lid) if state.tails.contains(lid) else None
                items.append((lid, m.kind, m.parent, m.fork_point, tails,
                              m.stands_for, sorted(m.hli_children),
                              sorted(m.promotable_forks.items()),
                              m.index.content_digest()))
            # segment-GC manifests (§13): replicas must agree not only on the
            # log forest but on refcounts, the candidate queue (order
            # included — it decides future reclaim order), and the reclaimed
            # set, or a failover would reclaim different objects
            gc_items = (sorted(state.object_refs.items()),
                        tuple(state._reclaimable),
                        sorted(state.reclaimed))
            # compaction + tiering manifests (§14): byte-granular refcounts,
            # learned object sizes, birth ticks (they decide future demotion
            # eligibility), and the replicated cold-placement set must match
            # too, or a failover would compact/demote different objects
            compact_items = (sorted(state.object_ref_bytes.items()),
                             sorted(state.object_bytes.items()),
                             sorted(state.object_birth.items()),
                             sorted(state.cold_objects),
                             state.op_seq, state.compact_epoch)
            return pickle.dumps((items, gc_items, compact_items))

        blobs = set()
        for r in self.replicas:
            if not (r.alive and r.commit_index == self.leader.commit_index):
                continue
            r.apply_pending()
            blobs.add(digest(r.state))
        return len(blobs) <= 1
