"""Fault-tolerant metadata layer: a minimal in-process replicated SMR group.

The paper's metadata layer is "a fault-tolerant group that implements state-
machine replication using Paxos or Raft" (§5.2). We implement the SMR contract
the rest of Bolt depends on — a single totally-ordered command log applied
deterministically on every replica, with majority commit, leader failover, and
snapshot/compaction — without the wire protocol (single-process container).

Properties exercised by tests:
  * a committed command survives any minority of replica failures;
  * killing the leader elects a new one and the state machines converge;
  * snapshots truncate the command log and a replica restarted from a snapshot
    replays the suffix and converges.
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from .errors import AmbiguousProposal, NoQuorum, NotLeader, Unavailable
from .faults import RetryPolicy, RetryStats, run_with_retries
from .metadata import MetadataState


@dataclass
class _Entry:
    term: int
    cmd: Tuple


class Replica:
    def __init__(self, rid: int, make_state: Callable[[], MetadataState]) -> None:
        self.rid = rid
        self.make_state = make_state
        self.state = make_state()
        self.log: List[_Entry] = []
        self.commit_index = -1      # highest COMMITTED entry index
        self.applied_index = -1     # highest entry applied to the state machine
        self.snapshot_index = -1    # entries <= this are compacted into `snapshot`
        self.snapshot: Optional[bytes] = None
        self.alive = True
        self.lazy_applies = 0       # entries applied via deferred batches

    def append_entry(self, entry: _Entry) -> bool:
        if not self.alive:
            return False
        self.log.append(entry)
        return True

    @property
    def pending_applies(self) -> int:
        return self.commit_index - self.applied_index

    def apply_to(self, index: int) -> None:
        """Apply committed entries up to `index` (0-based global index)."""
        while self.applied_index < index:
            self.applied_index += 1
            local = self.applied_index - self.snapshot_index - 1
            entry = self.log[local]
            try:
                self.state.apply(entry.cmd)
            except Exception:
                # Deterministic command failures (e.g. ForkBlocked) are part
                # of the state machine contract: every replica fails
                # identically, leaving identical state (a failed append still
                # registers its orphaned PUT object for GC, §13, but does so
                # before raising — deterministically); the leader surfaces
                # the error.
                pass
        if self.commit_index < index:
            self.commit_index = index

    def apply_pending(self) -> int:
        """Drain the deferred-apply backlog (pipelined followers, DESIGN.md
        §11): one sequential batch replay instead of per-proposal work."""
        n = self.pending_applies
        if n > 0:
            self.lazy_applies += n
            self.apply_to(self.commit_index)
        return n

    def take_snapshot(self) -> None:
        self.apply_pending()   # a snapshot serializes APPLIED state
        self.snapshot = pickle.dumps(self.state)
        drop = self.commit_index - self.snapshot_index
        self.log = self.log[drop:]
        self.snapshot_index = self.commit_index

    def restore_from(self, other: "Replica") -> None:
        """Crash-recovery: install peer snapshot + replay suffix."""
        assert other.snapshot is not None
        self.state = pickle.loads(other.snapshot)
        self.snapshot = other.snapshot
        self.snapshot_index = other.snapshot_index
        self.commit_index = other.snapshot_index
        self.applied_index = other.snapshot_index
        self.log = list(other.log)
        self.apply_to(other.commit_index)


class MetadataService:
    """Client-facing façade: propose() commands, query the leader's state."""

    def __init__(self, n_replicas: int = 3, snapshot_every: int = 0,
                 pipeline_apply: bool = True, **state_kwargs) -> None:
        make_state = lambda: MetadataState(**state_kwargs)  # noqa: E731
        self.replicas = [Replica(i, make_state) for i in range(n_replicas)]
        self.term = 1
        self.leader_id = 0
        self.snapshot_every = snapshot_every
        self._since_snapshot = 0
        self.proposals = 0
        # Pipelined replica apply (DESIGN.md §11): followers only append the
        # entry and advance their commit index on the propose critical path;
        # the state-machine apply is deferred and batch-replayed on snapshot,
        # failover, recovery, and convergence checks. With it off, every
        # replica applies synchronously inside propose() (the seed behavior).
        self.pipeline_apply = pipeline_apply
        # Fault plane + client retry policy (DESIGN.md §15). With no plane
        # attached, propose() is the plain synchronous path below — no token
        # wrapping, no retry loop, byte-identical to the pre-§15 system.
        self.faults = None
        self.retry = RetryPolicy()
        self.retry_stats = RetryStats()
        self._token_seq = 0
        self.elections = 0

    # -- leadership ------------------------------------------------------------
    @property
    def leader(self) -> Replica:
        return self.replicas[self.leader_id]

    def fail_replica(self, rid: int) -> None:
        self.replicas[rid].alive = False
        if rid == self.leader_id:
            self._elect()

    def recover_replica(self, rid: int) -> None:
        r = self.replicas[rid]
        r.alive = True
        donor = max((p for p in self.replicas if p.alive and p.rid != rid),
                    key=lambda p: p.commit_index)
        if donor.commit_index > r.commit_index:
            # The donor won on commit_index, which says nothing about its
            # APPLIED state: a pipelined follower (§11) may carry a stale
            # snapshot from an earlier compaction plus a deferred-apply
            # backlog — its log is shorter than its commit point. Drain the
            # backlog and refresh the snapshot so the recovering replica
            # installs fully-applied state and replays only the (empty)
            # suffix, instead of re-running the donor's whole backlog.
            donor.apply_pending()
            if donor.snapshot is None or donor.snapshot_index < donor.commit_index:
                donor.take_snapshot()
            r.restore_from(donor)

    def _elect(self) -> None:
        alive = [r for r in self.replicas if r.alive]
        if len(alive) * 2 <= len(self.replicas):
            raise NoQuorum("no quorum: metadata layer unavailable")
        self.elections += 1
        # most-up-to-date alive replica wins (Raft's log-completeness rule)
        winner = max(alive, key=lambda r: (len(r.log) + r.snapshot_index, -r.rid))
        self.leader_id = winner.rid
        self.term += 1
        # discard uncommitted suffix (never acked to clients)
        for r in alive:
            keep = winner.commit_index - r.snapshot_index
            r.log = r.log[:max(0, keep)]
        # a pipelined follower stepping up must serve linearizable reads:
        # drain its deferred-apply backlog before taking queries
        winner.apply_pending()

    # -- the SMR write path ------------------------------------------------------
    def propose(self, cmd: Tuple, replica_hint: Optional[int] = None) -> object:
        """Sequence `cmd`, commit at majority, apply everywhere, return the
        leader's apply result (or raise its deterministic error).

        With a fault plane attached (DESIGN.md §15) this is the full client
        submit path: the command is wrapped with a fresh idempotency token —
        deduplicated in the replicated state, so a retry after an ambiguous
        (committed-but-unacked) outcome applies at most once — and every
        transient :class:`Unavailable` is retried under the bounded backoff
        policy. Without a plane it is the plain synchronous path."""
        if replica_hint is not None and replica_hint != self.leader_id:
            raise NotLeader(f"replica {replica_hint} is not the leader")
        plane = self.faults
        if plane is None or not plane.enabled:
            return self._propose_once(cmd)
        token = f"t{self._token_seq}"
        self._token_seq += 1
        wrapped = ("idem", token, cmd)
        return run_with_retries(lambda _attempt: self._propose_once(wrapped),
                                self.retry, plane.rng, stats=self.retry_stats)

    def _propose_once(self, cmd: Tuple) -> object:
        plane = self.faults
        if plane is not None and plane.fire("leader_crash"):
            # the leader dies before appending the entry anywhere: nothing
            # committed. Failing it triggers the election (which may itself
            # find no quorum); the client retries against the new leader.
            dead = self.leader_id
            self.fail_replica(dead)
            raise Unavailable(
                f"leader replica {dead} crashed mid-operation (injected)")
        entry = _Entry(self.term, cmd)
        acked = []
        for r in self.replicas:
            if r.alive and r.append_entry(entry):
                acked.append(r)
        if len(acked) * 2 <= len(self.replicas):
            # roll back: the entry was never committed (nor applied anywhere),
            # so leaving it in minority logs would skew the global index of
            # every later proposal after recovery
            for r in acked:
                r.log.pop()
            raise NoQuorum("no quorum: append not committed")
        # global index of the just-appended entry: entries [0..snapshot_index]
        # are compacted, so global = snapshot_index + local_length
        index = self.leader.snapshot_index + len(self.leader.log)
        result: object = None
        error: Optional[Exception] = None
        for r in self.replicas:
            if not r.alive:
                continue
            if r is self.leader:
                # capture leader's apply result/error explicitly
                if r.applied_index < index - 1:
                    r.apply_to(index - 1)
                r.commit_index = index
                r.applied_index = index
                try:
                    result = r.state.apply(entry.cmd)
                except Exception as e:  # deterministic command error
                    error = e
            elif self.pipeline_apply:
                # pipelined (DESIGN.md §11): the follower's durable vote is
                # the log append above; advancing its commit index is all the
                # critical path needs — the state-machine apply is deferred
                r.commit_index = index
            else:
                r.apply_to(index)
        self.proposals += 1
        self._since_snapshot += 1
        if self.snapshot_every and self._since_snapshot >= self.snapshot_every:
            for r in self.replicas:
                if r.alive:
                    r.take_snapshot()
            self._since_snapshot = 0
        if plane is not None and plane.fire("propose_unacked"):
            # committed-but-unacked (DESIGN.md §15): the entry is committed
            # and applied, but the ack is lost. The client may retry ONLY
            # because the command rides an idempotency token — the replicated
            # dedup table returns this apply's cached outcome instead of
            # applying twice.
            raise AmbiguousProposal(
                "propose timed out after commit: outcome unacked (injected)")
        if error is not None:
            raise error
        return result

    # -- linearizable reads (leader-local) -------------------------------------
    @property
    def state(self) -> MetadataState:
        return self.leader.state

    def check_convergence(self) -> bool:
        """All alive replicas have identical applied state (test hook).

        The digest covers membership, tails, AND per-log index-run content
        (object ids + offsets/lengths, frozen stand-ins included): a replica
        that replayed a promote splice differently but landed on the same
        tails — same positions, different byte mapping — is caught, not just
        one that lost a whole log. With pipelined apply, every replica's
        deferred backlog is drained first: convergence is a statement about
        applied state, not about queued entries.
        """
        def digest(state: MetadataState) -> bytes:
            items = []
            for lid, m in sorted(state.logs.items()):
                tails = state.tails.get(lid) if state.tails.contains(lid) else None
                items.append((lid, m.kind, m.parent, m.fork_point, tails,
                              m.stands_for, sorted(m.hli_children),
                              sorted(m.promotable_forks.items()),
                              m.index.content_digest()))
            # segment-GC manifests (§13): replicas must agree not only on the
            # log forest but on refcounts, the candidate queue (order
            # included — it decides future reclaim order), and the reclaimed
            # set, or a failover would reclaim different objects
            gc_items = (sorted(state.object_refs.items()),
                        tuple(state._reclaimable),
                        sorted(state.reclaimed))
            # compaction + tiering manifests (§14): byte-granular refcounts,
            # learned object sizes, birth ticks (they decide future demotion
            # eligibility), and the replicated cold-placement set must match
            # too, or a failover would compact/demote different objects
            compact_items = (sorted(state.object_ref_bytes.items()),
                             sorted(state.object_bytes.items()),
                             sorted(state.object_birth.items()),
                             sorted(state.cold_objects),
                             state.op_seq, state.compact_epoch)
            # idempotency dedup table (§15): content AND order — insertion
            # order is consensus order, and it decides future FIFO evictions,
            # so replicas that agree on entries but not order would diverge
            # at the next eviction. Each outcome is pickled in ISOLATION:
            # cached results are live objects that may share identity with
            # other state on one replica but not another (e.g. after a
            # snapshot round-trip), and pickle's memoization would turn that
            # invisible identity difference into a digest mismatch.
            idem_items = tuple((tok, pickle.dumps(outcome))
                               for tok, outcome in state.idem_results.items())
            # The same isolation applies to the digest as a whole: two logs
            # on one replica may share a tails tuple or index-run object that
            # a snapshot-restored peer reconstructs as distinct (equal)
            # objects, so each component is pickled separately and the digest
            # is built from the independent byte strings.
            return pickle.dumps((tuple(pickle.dumps(it) for it in items),
                                 tuple(pickle.dumps(it) for it in gc_items),
                                 tuple(pickle.dumps(it) for it in compact_items),
                                 idem_items))

        blobs = set()
        for r in self.replicas:
            if not (r.alive and r.commit_index == self.leader.commit_index):
                continue
            r.apply_pending()
            blobs.add(digest(r.state))
        return len(blobs) <= 1
