"""Deterministic fault-injection plane + client retry policy (DESIGN.md §15).

The paper's availability story — stateless brokers can die without losing
data, the metadata layer is "a fault-tolerant group" (§5.2) — is only real if
the request path has defined behavior when things actually fail. This module
is the single switchboard for making them fail *on purpose, reproducibly*:

* :class:`FaultConfig` — per-site probabilities (store PUT/GET/DELETE errors,
  torn partial PUTs, committed-but-unacked propose ambiguity, leader crash
  mid-operation, broker crash between the segment PUT and its proposal) plus
  a DES-time **schedule** of discrete events (kill/recover a broker, replica,
  or the current leader at simulated time *t*).
* :class:`FaultPlane` — one seeded ``random.Random`` drives every probability
  draw in *consultation order*, so a given (seed, workload) pair replays the
  identical fault sequence; counters record what actually fired.
* :class:`RetryPolicy` / :func:`run_with_retries` — the client-side answer:
  bounded retries with exponential backoff + deterministic jitter. Every
  transient failure surfaces as :class:`~repro.core.errors.Unavailable`; the
  budget's end is a typed :class:`~repro.core.errors.RetryBudgetExhausted`.

Layering contract (who consults what):

* Object stores consult ``on_put``/``on_get``/``on_delete`` (attached via
  ``ObjectStore.attach_faults``). A *torn* PUT durably writes a prefix and
  then raises — the caller must treat the key as garbage until a full re-PUT
  lands (retries use fresh object ids; the torn orphan is swept by the §13
  reaper's ``resync``).
* ``MetadataService`` consults ``leader_crash`` (the leader dies mid-propose,
  before the entry is appended) and ``propose_unacked`` (the entry committed
  and applied, but the ack is lost — the client sees
  :class:`~repro.core.errors.AmbiguousProposal` and may retry **only** with
  the same idempotency token, deduplicated in the replicated state).
* Brokers consult ``broker_crash_flush``/``broker_crash_append`` (death in
  the window after the object PUT, before the metadata proposal: the PUT is
  an orphan, staged records fail over to a surviving broker).
* The metadata group's replication traffic consults the :class:`Network`
  (``plane.net``, DESIGN.md §16): every AppendEntries / vote / snapshot
  message and its ack traverses a directed link with per-link
  drop/delay/duplicate/reorder probabilities and partition blocks, so stale
  leaders, divergent suffixes, and lost-ack ambiguity are injectable and
  replay under one seed.

The plane is inert by default: a ``BoltSystem`` without ``faults=`` never
draws, never retries, replicates by direct call, and behaves byte-identically
to the pre-§15 system.
"""

from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .errors import RetryBudgetExhausted, StoreFault, Unavailable


#: Schedule event kinds understood by :meth:`FaultPlane.advance`. The
#: ``partition*``/``heal_network`` kinds drive the §16 message network and
#: need no bound system; the kill/recover kinds require :meth:`FaultPlane.bind`.
SCHEDULE_KINDS = ("kill_broker", "kill_leader", "kill_replica",
                  "recover_replica", "partition", "partition_oneway",
                  "heal_network")


@dataclass
class LinkFaults:
    """Per-link override of the §16 network probabilities. ``None`` fields
    inherit the global ``net_*`` value; a link named in
    ``FaultConfig.link_faults`` can therefore be made lossier (a flapping
    link) or cleaner than the fleet default without touching the others."""

    drop: Optional[float] = None
    delay: Optional[float] = None
    duplicate: Optional[float] = None
    reorder: Optional[float] = None


@dataclass
class FaultConfig:
    """Per-site fault probabilities + a DES-time event schedule (§15/§16).

    Probabilities are consulted per operation at the named site; ``0.0``
    disables the site without spending an RNG draw, so adding a site to a
    config never perturbs the fault sequence of the others. ``schedule`` is
    a tuple of ``(time, kind, target)`` events in simulated seconds —
    ``kind`` one of :data:`SCHEDULE_KINDS`, ``target`` the broker/replica id
    (ignored for ``kill_leader``; for ``partition`` a tuple of replica-id
    groups, for ``partition_oneway`` a ``(src_ids, dst_ids)`` pair, ignored
    for ``heal_network``). Events fire when :meth:`FaultPlane.advance` first
    observes a time >= theirs; events sharing a timestamp fire in their
    original schedule order (stable tiebreaker — replay-deterministic even
    when targets are not mutually comparable).

    The ``net_*`` sites are consulted per replication MESSAGE by the §16
    network (AppendEntries / votes / acks each traverse their directed link
    twice — request and reply leg, each drawn independently), so one seed
    replays one message-fault sequence. ``lease_duration`` is the leader
    lease horizon for fenced local reads, against the plane's DES clock."""

    seed: int = 0xFA177
    store_put_error: float = 0.0      # clean PUT failure: nothing written
    store_put_torn: float = 0.0       # torn PUT: a prefix lands, then error
    store_get_error: float = 0.0
    store_delete_error: float = 0.0
    propose_unacked: float = 0.0      # committed, applied, ack lost (§15)
    leader_crash: float = 0.0         # leader dies mid-propose (pre-append)
    broker_crash_flush: float = 0.0   # broker dies between seg PUT + proposal
    broker_crash_append: float = 0.0  # same window on the per-call path
    net_drop: float = 0.0             # message lost on a link leg (§16)
    net_delay: float = 0.0            # message held in flight, delivered late
    net_delay_time: float = 2e-3      # modeled seconds a delayed message waits
    net_duplicate: float = 0.0        # message delivered twice
    net_reorder: float = 0.0          # message overtaken by later traffic
    lease_duration: float = 0.5       # leader lease horizon (modeled seconds)
    link_faults: Optional[Dict[Tuple[int, int], LinkFaults]] = None
    schedule: Tuple[Tuple[float, str, object], ...] = ()


class Network:
    """Deterministic message-level network for the metadata group (§16).

    The raft layer routes every replication message (AppendEntries, vote
    requests, snapshot installs — and their acks) through :meth:`send`. Each
    directed link leg draws drop/delay/duplicate/reorder off the plane's
    seeded RNG (zero-probability sites never draw), and a set of directed
    partition blocks models symmetric and asymmetric partitions. Delayed and
    reordered messages sit in an in-flight queue until the DES clock reaches
    their delivery time (:meth:`pump`, driven by ``FaultPlane.advance``);
    their replies are stale by then and are discarded, which is exactly the
    asymmetric-ack failure the term/prev fencing in ``raft.py`` must absorb.

    With every ``net_*`` probability zero and no partitions armed, ``send``
    is a plain synchronous call — message-mode replication is then
    observationally identical to the pre-§16 direct path."""

    def __init__(self, plane: "FaultPlane") -> None:
        self.plane = plane
        self._blocks: set = set()          # directed (src, dst) blocked pairs
        self._inflight: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = 0
        self.partitions_armed = 0          # partition events applied (stat)
        self.msgs_sent = 0                 # total sends (not an injected fault)

    # -- partitions ----------------------------------------------------------
    def partition(self, *groups) -> None:
        """Symmetric partition: replicas in different ``groups`` cannot
        exchange messages in either direction (ids absent from every group
        keep full connectivity). Cumulative with earlier blocks."""
        for i, ga in enumerate(groups):
            for gb in groups[i + 1:]:
                for a in ga:
                    for b in gb:
                        self._blocks.add((a, b))
                        self._blocks.add((b, a))
        self.partitions_armed += 1

    def partition_oneway(self, srcs, dsts) -> None:
        """Asymmetric partition: messages ``src -> dst`` are blocked, the
        reverse direction still delivers (acks vanish, requests arrive)."""
        for s in srcs:
            for d in dsts:
                self._blocks.add((s, d))
        self.partitions_armed += 1

    def heal(self) -> None:
        """Remove every partition block (in-flight messages stay queued)."""
        self._blocks.clear()

    def blocked(self, src: int, dst: int) -> bool:
        return (src, dst) in self._blocks

    # -- fault draws ---------------------------------------------------------
    def _fire(self, site: str, src: int, dst: int) -> bool:
        """One per-link probability draw; link overrides beat the global
        ``net_<site>``. Zero-probability links never draw, so arming one
        link's faults never perturbs the message-fault sequence of others."""
        plane = self.plane
        if not plane.enabled:
            return False
        p = None
        overrides = plane.config.link_faults
        if overrides:
            lf = overrides.get((src, dst))
            if lf is not None:
                p = getattr(lf, site)
        if p is None:
            p = getattr(plane.config, "net_" + site)
        if p <= 0.0:
            return False
        if plane.rng.random() < p:
            plane.note("msgs_" + {"drop": "dropped", "delay": "delayed",
                                  "duplicate": "duplicated",
                                  "reorder": "reordered"}[site])
            return True
        return False

    # -- transport -----------------------------------------------------------
    def send(self, src: int, dst: int, handler: Callable[[tuple], object],
             payload: tuple):
        """One request/reply exchange over the ``src -> dst`` link. Returns
        the reply payload, or ``None`` when either leg failed: the request
        was blocked/dropped/held in flight, or the reply leg lost the ack
        (the destination then processed the request WITHOUT the source
        learning — the duplicate-suppression case the raft handlers absorb).
        """
        plane = self.plane
        self.msgs_sent += 1
        if self.blocked(src, dst):
            plane.note("msgs_dropped")
            plane.note("msgs_partitioned")
            return None
        if self._fire("drop", src, dst):
            return None
        if self._fire("duplicate", src, dst):
            # the extra copy arrives back-to-back with the original; its
            # reply is redundant and discarded
            handler(payload)
        if self._fire("delay", src, dst):
            jitter = 0.5 + plane.rng.random()
            self._hold(plane.now + plane.config.net_delay_time * jitter,
                       handler, payload)
            return None
        if self._fire("reorder", src, dst):
            # held at the CURRENT clock: delivered at the next pump, after
            # every message sent later in this round already executed —
            # genuine out-of-order arrival without a long delay
            self._hold(plane.now, handler, payload)
            return None
        reply = handler(payload)
        if reply is None:
            return None
        if self.blocked(dst, src):
            plane.note("msgs_dropped")
            plane.note("msgs_partitioned")
            return None
        if self._fire("drop", dst, src):
            return None
        if self._fire("delay", dst, src):
            # a late ack is a dead ack: the round moved on
            return None
        return reply

    def _hold(self, deliver_at: float, handler: Callable, payload: tuple) -> None:
        heapq.heappush(self._inflight, (deliver_at, self._seq, handler, payload))
        self._seq += 1

    def pump(self, now: float) -> int:
        """Deliver every in-flight message whose time has come (their replies
        are stale and discarded). Returns how many were delivered."""
        n = 0
        while self._inflight and self._inflight[0][0] <= now:
            _, _, handler, payload = heapq.heappop(self._inflight)
            handler(payload)
            n += 1
        return n

    def flush(self) -> int:
        """Deliver ALL in-flight messages (heal-time drain): late
        AppendEntries land on healed replicas and are absorbed — or
        truncated — by the term/prev checks."""
        return self.pump(math.inf)

    @property
    def in_flight(self) -> int:
        return len(self._inflight)


class FaultPlane:
    """Seeded switchboard the wired layers consult (DESIGN.md §15/§16).

    ``enabled`` gates every probability site (schedules still fire): the
    test harness heals the system by flipping it off before running the
    final oracles, without losing the counters of what was injected."""

    def __init__(self, config: Optional[FaultConfig] = None) -> None:
        self.config = config or FaultConfig()
        self.rng = random.Random(self.config.seed)
        self.enabled = True
        self.now = 0.0                # DES clock, advanced by advance()
        self.counters: Dict[str, int] = {}
        # stable tiebreaker (ISSUE 8 satellite): events sharing a DES
        # timestamp fire in their original schedule order — sorting the raw
        # (time, kind, target) triples compared kinds/targets, which is both
        # replay-fragile and a TypeError for mixed target types
        self._pending_events = sorted(
            ((t, seq, kind, target)
             for seq, (t, kind, target) in enumerate(self.config.schedule)),
            key=lambda ev: (ev[0], ev[1]))
        self.events_fired: list = []
        self._system = None           # bound BoltSystem (for schedules)
        self.net = Network(self)      # §16 message-level network
        self._timers: list = []       # heap of (time, seq, fn) callbacks
        self._timer_seq = 0

    # -- wiring --------------------------------------------------------------
    def bind(self, system) -> None:
        """Attach the BoltSystem whose brokers/replicas schedules target."""
        self._system = system

    def note(self, site: str, n: int = 1) -> None:
        self.counters[site] = self.counters.get(site, 0) + n

    @property
    def total_injected(self) -> int:
        return sum(self.counters.values())

    def fire(self, site: str) -> bool:
        """One probability draw at ``site``; counts and reports a hit.
        Zero-probability sites never draw, keeping fault sequences stable
        under config extension."""
        if not self.enabled:
            return False
        p = getattr(self.config, site)
        if p <= 0.0:
            return False
        if self.rng.random() < p:
            self.note(site)
            return True
        return False

    def heal(self) -> None:
        """Stop injecting (counters and remaining schedule are preserved).
        Partitions lift and in-flight delayed messages drain: their late
        delivery exercises the term/prev fencing one final time, after which
        the network is quiescent and reconciliation can run."""
        self.enabled = False
        self.net.heal()
        self.net.flush()

    # -- store sites ---------------------------------------------------------
    def on_put(self, key: str, data: bytes):
        """Consulted by the store before a PUT. Returns ``(payload, error)``:
        the bytes to durably write (``None`` for nothing) and the error to
        raise after writing them (``None`` for success)."""
        if self.fire("store_put_torn"):
            cut = self.rng.randrange(0, max(1, len(data)))
            return data[:cut], StoreFault(
                f"injected torn PUT of {key}: {cut}/{len(data)} bytes landed")
        if self.fire("store_put_error"):
            return None, StoreFault(f"injected PUT failure for {key}")
        return data, None

    def on_get(self, key: str) -> None:
        if self.fire("store_get_error"):
            raise StoreFault(f"injected GET failure for {key}")

    def on_delete(self, key: str) -> None:
        if self.fire("store_delete_error"):
            raise StoreFault(f"injected DELETE failure for {key}")

    # -- DES-time schedules --------------------------------------------------
    def call_at(self, time: float, fn) -> None:
        """Register a one-shot callback to fire when the DES clock reaches
        ``time`` (via :meth:`advance`). This is how layers turn *deadlines*
        into clock-driven actions — e.g. the group-commit ``max_delay`` flush
        (§9 bugfix): before this hook, an idle staged record's deadline only
        fired when the NEXT record happened to arrive. Callbacks at the same
        time fire in registration order; a ``time`` already in the past fires
        on the next ``advance()`` call."""
        heapq.heappush(self._timers, (time, self._timer_seq, fn))
        self._timer_seq += 1

    def advance(self, now: float) -> int:
        """Advance the DES clock: deliver due in-flight network messages,
        then fire every scheduled event with time <= ``now`` (kill/recover
        kinds require :meth:`bind`), then due :meth:`call_at` callbacks.
        Deliveries drain before events at the same clock reading (they were
        sent strictly earlier); events sharing a timestamp fire in original
        schedule order. Returns how many SCHEDULE events fired. Kills of
        already-dead targets are no-ops, so schedules compose with
        probabilistic crashes."""
        self.now = max(self.now, now)
        self.net.pump(self.now)
        fired = 0
        while self._pending_events and self._pending_events[0][0] <= now:
            t, _seq, kind, target = self._pending_events.pop(0)
            self._dispatch(kind, target)
            self.events_fired.append((t, kind, target))
            self.note("schedule_" + kind)
            fired += 1
        while self._timers and self._timers[0][0] <= now:
            _t, _seq, fn = heapq.heappop(self._timers)
            fn()
        return fired

    def _dispatch(self, kind: str, target) -> None:
        if kind == "partition":
            self.net.partition(*target)
            return
        if kind == "partition_oneway":
            self.net.partition_oneway(*target)
            return
        if kind == "heal_network":
            self.net.heal()
            return
        system = self._system
        assert system is not None, "FaultPlane.advance requires bind(system)"
        metadata = system.metadata
        if kind == "kill_broker":
            if target not in system._dead:
                system.fail_broker(target)
        elif kind == "kill_leader":
            metadata.fail_replica(metadata.leader_id)
        elif kind == "kill_replica":
            if metadata.replicas[target].alive:
                metadata.fail_replica(target)
        elif kind == "recover_replica":
            if not metadata.replicas[target].alive:
                metadata.recover_replica(target)
        else:
            raise ValueError(f"unknown fault-schedule kind {kind!r}")


# ---------------------------------------------------------------------------
# Client retry policy (DESIGN.md §15)
# ---------------------------------------------------------------------------

@dataclass
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``attempts`` bounds the total tries (first call included); delays follow
    ``base * multiplier**k`` capped at ``max_delay``, each scaled by a jitter
    factor drawn uniformly from ``[1-jitter, 1+jitter]`` off the fault
    plane's seeded RNG — so two retrying clients seeded differently desync
    (the point of jitter) while a fixed seed replays exactly."""

    attempts: int = 6
    base_delay: float = 1e-3          # simulated seconds (DES) per first retry
    multiplier: float = 2.0
    max_delay: float = 64e-3
    jitter: float = 0.5


@dataclass
class RetryStats:
    """What the retry layer actually did (fed into ``OpTally``)."""

    retries: int = 0                  # re-attempts after an Unavailable
    backoff_time: float = 0.0         # total simulated backoff slept
    budget_exhausted: int = 0         # RetryBudgetExhausted raised


def run_with_retries(fn: Callable[[int], object], policy: RetryPolicy,
                     rng: random.Random,
                     stats: Optional[RetryStats] = None,
                     on_backoff: Optional[Callable[[float], None]] = None,
                     on_retry: Optional[Callable[[Exception], None]] = None):
    """Run ``fn(attempt)`` (1-based) until it returns, retrying every
    :class:`Unavailable` except :class:`RetryBudgetExhausted` itself (a
    nested retry loop that already gave up must not be multiplied).
    ``on_backoff`` observes each simulated delay (the DES benchmarks charge
    it to the op's latency); ``on_retry`` observes the error *before* the
    backoff (e.g. to fail over a crashed broker)."""
    delay = policy.base_delay
    last: Optional[Exception] = None
    for attempt in range(1, policy.attempts + 1):
        try:
            return fn(attempt)
        except RetryBudgetExhausted:
            raise
        except Unavailable as e:
            last = e
            if attempt >= policy.attempts:
                break
            if on_retry is not None:
                on_retry(e)
            pause = min(delay, policy.max_delay)
            if policy.jitter > 0.0:
                pause *= 1.0 + policy.jitter * (2.0 * rng.random() - 1.0)
            if stats is not None:
                stats.retries += 1
                stats.backoff_time += pause
            if on_backoff is not None:
                on_backoff(pause)
            delay = min(delay * policy.multiplier, policy.max_delay)
    if stats is not None:
        stats.budget_exhausted += 1
    raise RetryBudgetExhausted(
        f"gave up after {policy.attempts} attempts: {last}",
        attempts=policy.attempts, last_error=last)
