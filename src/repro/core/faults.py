"""Deterministic fault-injection plane + client retry policy (DESIGN.md §15).

The paper's availability story — stateless brokers can die without losing
data, the metadata layer is "a fault-tolerant group" (§5.2) — is only real if
the request path has defined behavior when things actually fail. This module
is the single switchboard for making them fail *on purpose, reproducibly*:

* :class:`FaultConfig` — per-site probabilities (store PUT/GET/DELETE errors,
  torn partial PUTs, committed-but-unacked propose ambiguity, leader crash
  mid-operation, broker crash between the segment PUT and its proposal) plus
  a DES-time **schedule** of discrete events (kill/recover a broker, replica,
  or the current leader at simulated time *t*).
* :class:`FaultPlane` — one seeded ``random.Random`` drives every probability
  draw in *consultation order*, so a given (seed, workload) pair replays the
  identical fault sequence; counters record what actually fired.
* :class:`RetryPolicy` / :func:`run_with_retries` — the client-side answer:
  bounded retries with exponential backoff + deterministic jitter. Every
  transient failure surfaces as :class:`~repro.core.errors.Unavailable`; the
  budget's end is a typed :class:`~repro.core.errors.RetryBudgetExhausted`.

Layering contract (who consults what):

* Object stores consult ``on_put``/``on_get``/``on_delete`` (attached via
  ``ObjectStore.attach_faults``). A *torn* PUT durably writes a prefix and
  then raises — the caller must treat the key as garbage until a full re-PUT
  lands (retries use fresh object ids; the torn orphan is swept by the §13
  reaper's ``resync``).
* ``MetadataService`` consults ``leader_crash`` (the leader dies mid-propose,
  before the entry is appended) and ``propose_unacked`` (the entry committed
  and applied, but the ack is lost — the client sees
  :class:`~repro.core.errors.AmbiguousProposal` and may retry **only** with
  the same idempotency token, deduplicated in the replicated state).
* Brokers consult ``broker_crash_flush``/``broker_crash_append`` (death in
  the window after the object PUT, before the metadata proposal: the PUT is
  an orphan, staged records fail over to a surviving broker).

The plane is inert by default: a ``BoltSystem`` without ``faults=`` never
draws, never retries, and behaves byte-identically to the pre-§15 system.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

from .errors import RetryBudgetExhausted, StoreFault, Unavailable


#: Schedule event kinds understood by :meth:`FaultPlane.advance`.
SCHEDULE_KINDS = ("kill_broker", "kill_leader", "kill_replica",
                  "recover_replica")


@dataclass
class FaultConfig:
    """Per-site fault probabilities + a DES-time event schedule (§15).

    Probabilities are consulted per operation at the named site; ``0.0``
    disables the site without spending an RNG draw, so adding a site to a
    config never perturbs the fault sequence of the others. ``schedule`` is
    a tuple of ``(time, kind, target)`` events in simulated seconds —
    ``kind`` one of :data:`SCHEDULE_KINDS`, ``target`` the broker/replica id
    (ignored for ``kill_leader``). Events fire when :meth:`FaultPlane.advance`
    first observes a time >= theirs."""

    seed: int = 0xFA177
    store_put_error: float = 0.0      # clean PUT failure: nothing written
    store_put_torn: float = 0.0       # torn PUT: a prefix lands, then error
    store_get_error: float = 0.0
    store_delete_error: float = 0.0
    propose_unacked: float = 0.0      # committed, applied, ack lost (§15)
    leader_crash: float = 0.0         # leader dies mid-propose (pre-append)
    broker_crash_flush: float = 0.0   # broker dies between seg PUT + proposal
    broker_crash_append: float = 0.0  # same window on the per-call path
    schedule: Tuple[Tuple[float, str, Optional[int]], ...] = ()


class FaultPlane:
    """Seeded switchboard the wired layers consult (DESIGN.md §15).

    ``enabled`` gates every probability site (schedules still fire): the
    test harness heals the system by flipping it off before running the
    final oracles, without losing the counters of what was injected."""

    def __init__(self, config: Optional[FaultConfig] = None) -> None:
        self.config = config or FaultConfig()
        self.rng = random.Random(self.config.seed)
        self.enabled = True
        self.counters: Dict[str, int] = {}
        self._pending_events = sorted(self.config.schedule)
        self.events_fired: list = []
        self._system = None           # bound BoltSystem (for schedules)

    # -- wiring --------------------------------------------------------------
    def bind(self, system) -> None:
        """Attach the BoltSystem whose brokers/replicas schedules target."""
        self._system = system

    def note(self, site: str, n: int = 1) -> None:
        self.counters[site] = self.counters.get(site, 0) + n

    @property
    def total_injected(self) -> int:
        return sum(self.counters.values())

    def fire(self, site: str) -> bool:
        """One probability draw at ``site``; counts and reports a hit.
        Zero-probability sites never draw, keeping fault sequences stable
        under config extension."""
        if not self.enabled:
            return False
        p = getattr(self.config, site)
        if p <= 0.0:
            return False
        if self.rng.random() < p:
            self.note(site)
            return True
        return False

    def heal(self) -> None:
        """Stop injecting (counters and remaining schedule are preserved)."""
        self.enabled = False

    # -- store sites ---------------------------------------------------------
    def on_put(self, key: str, data: bytes):
        """Consulted by the store before a PUT. Returns ``(payload, error)``:
        the bytes to durably write (``None`` for nothing) and the error to
        raise after writing them (``None`` for success)."""
        if self.fire("store_put_torn"):
            cut = self.rng.randrange(0, max(1, len(data)))
            return data[:cut], StoreFault(
                f"injected torn PUT of {key}: {cut}/{len(data)} bytes landed")
        if self.fire("store_put_error"):
            return None, StoreFault(f"injected PUT failure for {key}")
        return data, None

    def on_get(self, key: str) -> None:
        if self.fire("store_get_error"):
            raise StoreFault(f"injected GET failure for {key}")

    def on_delete(self, key: str) -> None:
        if self.fire("store_delete_error"):
            raise StoreFault(f"injected DELETE failure for {key}")

    # -- DES-time schedules --------------------------------------------------
    def advance(self, now: float) -> int:
        """Fire every scheduled event with time <= ``now`` (requires
        :meth:`bind`). Returns how many fired. Kills of already-dead targets
        are no-ops, so schedules compose with probabilistic crashes."""
        fired = 0
        while self._pending_events and self._pending_events[0][0] <= now:
            t, kind, target = self._pending_events.pop(0)
            self._dispatch(kind, target)
            self.events_fired.append((t, kind, target))
            self.note("schedule_" + kind)
            fired += 1
        return fired

    def _dispatch(self, kind: str, target: Optional[int]) -> None:
        system = self._system
        assert system is not None, "FaultPlane.advance requires bind(system)"
        metadata = system.metadata
        if kind == "kill_broker":
            if target not in system._dead:
                system.fail_broker(target)
        elif kind == "kill_leader":
            metadata.fail_replica(metadata.leader_id)
        elif kind == "kill_replica":
            if metadata.replicas[target].alive:
                metadata.fail_replica(target)
        elif kind == "recover_replica":
            if not metadata.replicas[target].alive:
                metadata.recover_replica(target)
        else:
            raise ValueError(f"unknown fault-schedule kind {kind!r}")


# ---------------------------------------------------------------------------
# Client retry policy (DESIGN.md §15)
# ---------------------------------------------------------------------------

@dataclass
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``attempts`` bounds the total tries (first call included); delays follow
    ``base * multiplier**k`` capped at ``max_delay``, each scaled by a jitter
    factor drawn uniformly from ``[1-jitter, 1+jitter]`` off the fault
    plane's seeded RNG — so two retrying clients seeded differently desync
    (the point of jitter) while a fixed seed replays exactly."""

    attempts: int = 6
    base_delay: float = 1e-3          # simulated seconds (DES) per first retry
    multiplier: float = 2.0
    max_delay: float = 64e-3
    jitter: float = 0.5


@dataclass
class RetryStats:
    """What the retry layer actually did (fed into ``OpTally``)."""

    retries: int = 0                  # re-attempts after an Unavailable
    backoff_time: float = 0.0         # total simulated backoff slept
    budget_exhausted: int = 0         # RetryBudgetExhausted raised


def run_with_retries(fn: Callable[[int], object], policy: RetryPolicy,
                     rng: random.Random,
                     stats: Optional[RetryStats] = None,
                     on_backoff: Optional[Callable[[float], None]] = None,
                     on_retry: Optional[Callable[[Exception], None]] = None):
    """Run ``fn(attempt)`` (1-based) until it returns, retrying every
    :class:`Unavailable` except :class:`RetryBudgetExhausted` itself (a
    nested retry loop that already gave up must not be multiplied).
    ``on_backoff`` observes each simulated delay (the DES benchmarks charge
    it to the op's latency); ``on_retry`` observes the error *before* the
    backoff (e.g. to fail over a crashed broker)."""
    delay = policy.base_delay
    last: Optional[Exception] = None
    for attempt in range(1, policy.attempts + 1):
        try:
            return fn(attempt)
        except RetryBudgetExhausted:
            raise
        except Unavailable as e:
            last = e
            if attempt >= policy.attempts:
                break
            if on_retry is not None:
                on_retry(e)
            pause = min(delay, policy.max_delay)
            if policy.jitter > 0.0:
                pause *= 1.0 + policy.jitter * (2.0 * rng.random() - 1.0)
            if stats is not None:
                stats.retries += 1
                stats.backoff_time += pause
            if on_backoff is not None:
                on_backoff(pause)
            delay = min(delay * policy.multiplier, policy.max_delay)
    if stats is not None:
        stats.budget_exhausted += 1
    raise RetryBudgetExhausted(
        f"gave up after {policy.attempts} attempts: {last}",
        attempts=policy.attempts, last_error=last)
