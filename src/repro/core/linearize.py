"""General linearizability checker over forkable-log histories (DESIGN.md §16).

Porcupine-style (WGL: Wing & Gong with memoization, as used by Jepsen's knossos
and etcd's porcupine): given a concurrent history of client operations —
appends that returned positions, reads that returned records, cForks that
returned a child log id, and operations whose outcome is *unknown* (the client
saw a transient error after the effect may have landed) — search for a total
order that

  * respects real time: if op A's response preceded op B's invocation, A
    linearizes before B;
  * matches the sequential forkable-log spec: an append takes the next
    consecutive positions in its target log AND lands at the tail of every
    live descendant fork (the cFork sharing semantics: `_apply_append` range-
    adds the whole LTT subtree); a cFork snapshots the parent's content; a
    read returns exactly the records below its range bound;
  * places every unknown-outcome operation either at one point (it happened
    once) or nowhere (it never happened) — the §15 at-most-once contract.

The checker replaces the bespoke "acked positions hold, no duplicates"
assertions in ``tests/test_fault_tolerance_e2e.py`` with a strictly stronger
statement: those assertions follow from linearizability of the recorded
history, and the checker additionally rejects reorderings, lost acks that
resurface at the wrong position, and dedup failures (a retried ambiguous
append applying twice shifts every later append's positions — the mutation
test in the e2e suite pins that detection).

Concurrency in a single-threaded trace runner is real, not simulated: a
group-commit ``append_batch`` returns a *receipt* whose positions resolve at
flush time, so the operation's response event happens many client steps after
its invocation — reads in between legitimately miss it. The recorder stamps
invocation/response with a logical clock; receipt resolution is the response.

Squash needs no modeling: it discards a fork subtree without touching the
parent, and a squashed log is never read afterwards — any trailing unknown
append on it simply linearizes before the (unrecorded) squash or nowhere.
Promotable forks (withheld positions, promote splices) are outside the
recorded histories' scope.
"""

from __future__ import annotations

import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

INF = float("inf")


@dataclass
class Op:
    """One client-visible operation in a history.

    ``ret_time`` is +inf until the response resolves; an op whose outcome
    never resolved (``ok is None``) stays concurrent with everything after
    its invocation and may linearize anywhere after ``call`` — or nowhere.
    """
    opid: int
    kind: str                      # "append" | "read" | "cfork"
    log_id: int                    # target log (cfork: the parent)
    payload: tuple                 # append: records; read: (lo, hi); cfork: ()
    call: int                      # invocation timestamp (logical clock)
    ret_time: float = INF          # response timestamp (+inf = unresolved)
    ret: Optional[tuple] = None    # append: positions; read: records;
                                   # cfork: (child_log_id,)
    ok: Optional[bool] = None      # True=resolved, None=unknown, False=no-op


class History:
    """Recorder: ``invoke`` at the call site, then exactly one of ``resolve``
    (outcome known), ``unknown`` (transient error — effect may have landed),
    or ``discard`` (known no-effect, e.g. a deterministic command rejection:
    the op is dropped from the history)."""

    def __init__(self) -> None:
        self.ops: List[Op] = []
        self._clock = 0
        self._next_opid = 0
        self.base: Dict[int, int] = {}     # pre-existing log -> first known pos

    def tick(self) -> int:
        self._clock += 1
        return self._clock

    def register_log(self, log_id: int, base: int = 0) -> None:
        """Declare a log that exists BEFORE the history starts, with content
        below ``base`` unknown (and unchecked). Logs created mid-history are
        declared by their recorded cfork op instead — their full content,
        inherited prefix included, is then checked."""
        self.base[log_id] = base

    def invoke(self, kind: str, log_id: int, payload: tuple) -> Op:
        op = Op(self._next_opid, kind, log_id, payload, self.tick())
        self._next_opid += 1
        self.ops.append(op)
        return op

    def resolve(self, op: Op, ret: tuple) -> None:
        op.ret = tuple(ret)
        op.ok = True
        op.ret_time = self.tick()

    def unknown(self, op: Op) -> None:
        op.ok = None                       # at-most-once: may linearize 0 or 1 times

    def discard(self, op: Op) -> None:
        op.ok = False                      # known no-effect: drop from history

    def settle(self, log_id: int, content: tuple) -> None:
        """Post-trace settlement of unknown-outcome appends to ``log_id``
        against a final full read of ``content`` (the whole log from position
        0). Records are globally unique in the recorded workloads, so an
        unknown append whose records are absent definitely never landed (drop
        it) and one whose records sit at consecutive positions landed exactly
        there (resolve it, response = now). Both decisions are forced by the
        final read — this only prunes the search's branching, it cannot mask
        a violation: a record planted at inconsistent positions still fails
        ``check``."""
        index: Dict[object, int] = {}
        for i, rec in enumerate(content):
            index[rec] = i
        for op in self.ops:
            if op.log_id != log_id or op.kind != "append" or op.ok is not None:
                continue
            positions = [index.get(r) for r in op.payload]
            if all(p is None for p in positions):
                op.ok = False              # never landed
            elif None not in positions and positions == list(
                    range(positions[0], positions[0] + len(positions))):
                self.resolve(op, tuple(positions))

    # -- checking -----------------------------------------------------------
    def check(self) -> "LinearizeResult":
        ops = [op for op in self.ops if op.ok is not False]
        return check_history(ops, dict(self.base))


@dataclass
class LinearizeResult:
    ok: bool
    log_id: Optional[int]
    reason: Optional[str]

    def __bool__(self) -> bool:
        return self.ok


# ---------------------------------------------------------------------------
# the WGL search over the multi-log sequential model
# ---------------------------------------------------------------------------
#
# Model state: log_id -> (parent_id, base, entries) where positions
# [base, base+len(entries)) hold `entries` and [0, base) is unknown (only
# nonzero for pre-registered logs; cfork children inherit the parent's base).

def _is_descendant(logs: dict, y: int, x: int) -> bool:
    """Is y == x or a transitive fork of x (walking parent links)?"""
    seen = 0
    while y is not None:
        if y == x:
            return True
        y = logs[y][0]
        seen += 1
        assert seen <= len(logs), "parent-link cycle"
    return False


def _apply(logs: dict, op: Op) -> Optional[dict]:
    """Run ``op`` against the model. Returns the successor state, or None if
    the op's observed return value is impossible at this point in the order."""
    if op.log_id not in logs:
        return None
    parent, base, entries = logs[op.log_id]
    if op.kind == "append":
        records = tuple(op.payload)
        if op.ok and op.ret is not None:
            # resolved positions pin the linearization point exactly
            nxt = base + len(entries)
            if op.ret != tuple(range(nxt, nxt + len(records))):
                return None
        out = dict(logs)
        for lid, (p, b, e) in logs.items():
            # cFork sharing: the append lands in the target log AND at the
            # current tail of every live descendant fork
            if _is_descendant(logs, lid, op.log_id):
                out[lid] = (p, b, e + records)
        return out
    if op.kind == "read":
        lo, hi = op.payload
        next_pos = base + len(entries)
        if hi > next_pos:
            return None                    # read past the tail cannot succeed
        want = entries[max(lo, base) - base: hi - base]
        got = () if op.ret is None else tuple(op.ret[max(lo, base) - lo:])
        if got != want:
            return None                    # (prefix below a pre-registered
        return logs                        # log's `base` is unchecked)
    if op.kind == "cfork":
        if op.ret is None:
            return None                    # unresolved cforks aren't recorded
        child = op.ret[0]
        if child in logs:
            return None
        out = dict(logs)
        out[child] = (op.log_id, base, entries)   # snapshot the parent
        return out
    raise ValueError(f"unknown op kind {op.kind!r}")


def _freeze(logs: dict) -> tuple:
    return tuple(sorted((lid,) + logs[lid] for lid in logs))


def check_history(ops: List[Op], bases: Dict[int, int]) -> LinearizeResult:
    """WGL search over the whole history. Exponential in the worst case,
    memoized on (remaining-ops, model-state); the histories the e2e suite
    records have few concurrent windows, so the search is effectively linear
    there."""
    if not ops:
        return LinearizeResult(True, None, None)
    ops = sorted(ops, key=lambda o: (o.call, o.opid))
    init = {lid: (None, base, ()) for lid, base in bases.items()}
    seen = set()
    # the search keeps one frame per linearized op, so depth is linear in
    # history length — benchmark-scale traces (§18 lease-read histories run
    # thousands of ops) need headroom past the interpreter default
    limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(limit, 2 * len(ops) + 1000))

    def minimal(remaining: frozenset) -> List[Op]:
        """Ops that may linearize next: nothing still pending responded
        before their invocation."""
        pending = [o for o in ops if o.opid in remaining]
        horizon = min((o.ret_time for o in pending), default=INF)
        return [o for o in pending if o.call <= horizon]

    def search(remaining: frozenset, logs: dict) -> bool:
        if not remaining:
            return True
        key = (remaining, _freeze(logs))
        if key in seen:
            return False
        seen.add(key)
        for op in minimal(remaining):
            nxt = _apply(logs, op)
            if nxt is not None and search(remaining - {op.opid}, nxt):
                return True
            if op.ok is None:
                # unknown outcome: it may also have never happened — decide
                # "skipped" at its minimal point and move on
                if search(remaining - {op.opid}, logs):
                    return True
        return False

    try:
        ok = search(frozenset(o.opid for o in ops), init)
    finally:
        sys.setrecursionlimit(limit)
    if ok:
        return LinearizeResult(True, None, None)
    return LinearizeResult(
        False, None,
        f"no linearization of {len(ops)} ops over logs "
        f"{sorted({o.log_id for o in ops})} matches the sequential "
        "forkable-log spec")


def check_log(ops: List[Op], base: int = 0) -> LinearizeResult:
    """Single-log convenience wrapper (no forks in the op list)."""
    if not ops:
        return LinearizeResult(True, None, None)
    return check_history(ops, {ops[0].log_id: base})


def check_histories(history: History) -> LinearizeResult:
    """Convenience alias used by the tests."""
    return history.check()
