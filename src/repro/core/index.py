"""Hierarchical Log Index (HLI, §5.4-5.5) storage: per-log index structures.

Bolt's index maps log positions -> (object, byte-range) for *locally appended*
records only; inherited positions are resolved by recursing into the parent's
index after subtracting the cumulative local-append count (§5.5.1, Fig. 4b).

Two implementations:

* :class:`RunIndex` — Bolt's index, with a beyond-paper compression: one append
  batch (= one SMR command = one contiguous position run) is stored as a single
  *run entry* with numpy offset/length arrays, so memory is O(runs) dict
  entries + packed arrays instead of per-record boxed entries. The cumulative
  local count ("local count" in the paper) is stored per run and derived per
  record inside a run (positions in a run are consecutive, so the count is
  ``run.lcum_start + offset_in_run + 1``).

* :class:`NaiveIndex` — per-record dict entries; used by the BoltNaiveCF /
  BoltMetaCpy ablation variants (§6.4, §6.5) exactly because it duplicates and
  boxes aggressively.
"""

from __future__ import annotations

import bisect
import sys
from typing import Iterator, List, Optional, Tuple

import numpy as np

Span = Tuple[str, int, int]  # (object_id, offset, length)


class Run:
    __slots__ = ("start", "n", "object_id", "offsets", "lengths", "lcum_start")

    def __init__(self, start: int, object_id: str,
                 offsets: np.ndarray, lengths: np.ndarray, lcum_start: int) -> None:
        self.start = start
        self.n = len(offsets)
        self.object_id = object_id
        self.offsets = offsets
        self.lengths = lengths
        self.lcum_start = lcum_start

    @property
    def end(self) -> int:
        return self.start + self.n

    @property
    def lcum_end(self) -> int:
        return self.lcum_start + self.n

    def span(self, i: int, j: Optional[int] = None) -> List[Span]:
        """Byte spans for records [i, j) within this run (run-relative),
        coalescing contiguous byte ranges into one span (fewer GETs).
        Vectorized: group boundaries come from one numpy comparison instead of
        a per-record Python loop (DESIGN.md §10)."""
        j = self.n if j is None else j
        if j <= i:
            return []
        if j - i == 1:
            return [(self.object_id, int(self.offsets[i]), int(self.lengths[i]))]
        offs = self.offsets[i:j]
        lens = self.lengths[i:j]
        # a new span starts wherever a record is not byte-adjacent to its
        # predecessor: offs[k] != offs[k-1] + lens[k-1]
        breaks = np.flatnonzero(offs[1:] != offs[:-1] + lens[:-1]) + 1
        starts = np.empty(len(breaks) + 1, dtype=np.int64)
        starts[0] = 0
        starts[1:] = breaks
        ends = np.empty_like(starts)
        ends[:-1] = breaks
        ends[-1] = j - i
        cum = np.zeros(len(lens) + 1, dtype=np.int64)
        np.cumsum(lens, out=cum[1:])
        obj = self.object_id
        return [(obj, o, ln) for o, ln in zip(offs[starts].tolist(),
                                              (cum[ends] - cum[starts]).tolist())]

    def record_spans(self, i: int, j: Optional[int] = None) -> List[Span]:
        j = self.n if j is None else j
        obj = self.object_id
        return [(obj, o, ln) for o, ln in zip(self.offsets[i:j].tolist(),
                                              self.lengths[i:j].tolist())]

    def nbytes(self) -> int:
        return (sys.getsizeof(self.start) * 3 + len(self.object_id)
                + self.offsets.nbytes + self.lengths.nbytes)


class RunIndex:
    """Sorted run entries over strictly-increasing position ranges."""

    def __init__(self) -> None:
        self._starts: List[int] = []
        self._runs: List[Run] = []

    # -- writes -------------------------------------------------------------
    def append_run(self, start: int, object_id: str,
                   offsets: np.ndarray, lengths: np.ndarray) -> None:
        assert not self._runs or start >= self._runs[-1].end, "runs must advance"
        lcum = self._runs[-1].lcum_end if self._runs else 0
        self._runs.append(Run(start, object_id,
                              np.asarray(offsets, dtype=np.int64),
                              np.asarray(lengths, dtype=np.int64), lcum))
        self._starts.append(start)

    # -- queries --------------------------------------------------------------
    @property
    def total_local(self) -> int:
        return self._runs[-1].lcum_end if self._runs else 0

    @property
    def num_runs(self) -> int:
        return len(self._runs)

    def runs(self) -> List[Run]:
        return self._runs

    def first_start(self) -> Optional[int]:
        return self._starts[0] if self._starts else None

    def local_count_before(self, pos: int) -> int:
        """Number of local records at positions < pos (the paper's ``l``)."""
        i = bisect.bisect_right(self._starts, pos) - 1
        if i < 0:
            return 0
        r = self._runs[i]
        if pos >= r.end:
            return r.lcum_end
        return r.lcum_start + (pos - r.start)

    def segments(self, lo: int, hi: int) -> Iterator[Tuple[str, int, int, object]]:
        """Decompose [lo, hi) into ('local', a, b, run) and ('gap', a, b, lcount)
        segments in position order; gap segments carry the local count before
        the gap (for translating into the parent)."""
        pos = lo
        i = bisect.bisect_right(self._starts, lo) - 1
        if i < 0:
            i = 0
        while pos < hi:
            # skip runs that end at/before pos
            while i < len(self._runs) and self._runs[i].end <= pos:
                i += 1
            if i >= len(self._runs):
                yield ("gap", pos, hi, self.total_local)
                return
            r = self._runs[i]
            if r.start > pos:
                g_hi = min(r.start, hi)
                yield ("gap", pos, g_hi, r.lcum_start)
                pos = g_hi
                if pos >= hi:
                    return
            seg_hi = min(r.end, hi)
            if seg_hi > pos:
                yield ("local", pos, seg_hi, r)
                pos = seg_hi

    def content_digest(self) -> Tuple:
        """Hashable run-level content identity: positions, object ids, and
        exact byte ranges. Two indexes with equal tails can still differ here
        (e.g. a promote splice replayed differently), which is what the
        replica-convergence check must catch."""
        return tuple((r.start, r.object_id, r.offsets.tobytes(), r.lengths.tobytes())
                     for r in self._runs)

    def object_refcounts(self) -> dict:
        """Per-object reference multiset of this index: object id -> number of
        runs referencing it. This is the unit the segment-GC manifests count
        (DESIGN.md §13): an object is reclaimable only when the sum of these
        over every log (live or frozen) reaches zero."""
        out: dict = {}
        for r in self._runs:
            out[r.object_id] = out.get(r.object_id, 0) + 1
        return out

    def object_refbytes(self) -> dict:
        """Per-object referenced-byte multiset: object id -> total bytes this
        index's runs reference in it (overlaps counted once per run, shared
        runs once per attached index — same multiset semantics as
        ``object_refcounts``). This is what the compaction manifests
        (DESIGN.md §14) aggregate into per-object live-byte ratios."""
        out: dict = {}
        for r in self._runs:
            out[r.object_id] = out.get(r.object_id, 0) + int(r.lengths.sum())
        return out

    def snapshot(self) -> "RunIndex":
        """O(runs) snapshot sharing the (immutable) Run objects — used when a
        promote must preserve the old index for severed/frozen dependents."""
        s = RunIndex()
        s._starts = list(self._starts)
        s._runs = list(self._runs)
        return s

    def nbytes(self) -> int:
        return (sum(r.nbytes() for r in self._runs)
                + sys.getsizeof(self._starts) + sys.getsizeof(self._runs))


class NaiveIndex:
    """Per-record dict index (ablation variants)."""

    def __init__(self) -> None:
        self.entries: dict = {}       # pos -> (object_id, offset, length)
        self._local_positions: List[int] = []  # sorted; positions appended locally
        # For BoltNaiveCF, copied (inherited) entries are in ``entries`` but not
        # in ``_local_positions`` — lookups never need local counts there.

    def add_local(self, pos: int, span: Span) -> None:
        self.entries[pos] = span
        self._local_positions.append(pos)

    def add_copy(self, pos: int, span: Span) -> None:
        self.entries[pos] = span

    @property
    def total_local(self) -> int:
        return len(self._local_positions)

    def get(self, pos: int) -> Optional[Span]:
        return self.entries.get(pos)

    def content_digest(self) -> Tuple:
        return (tuple(sorted(self.entries.items())),
                tuple(sorted(self._local_positions)))

    def object_refcounts(self) -> dict:
        """Per-object reference multiset (DESIGN.md §13): one reference per
        entry, copies included — a BoltNaiveCF descendant's copied entries
        keep their object alive exactly as long as the descendant exists."""
        out: dict = {}
        for obj, _off, _ln in self.entries.values():
            out[obj] = out.get(obj, 0) + 1
        return out

    def object_refbytes(self) -> dict:
        """Per-object referenced-byte multiset (one contribution per entry,
        copies included) — see :meth:`RunIndex.object_refbytes`."""
        out: dict = {}
        for obj, _off, ln in self.entries.values():
            out[obj] = out.get(obj, 0) + ln
        return out

    def nbytes(self) -> int:
        n = sys.getsizeof(self.entries) + sys.getsizeof(self._local_positions)
        for k, v in self.entries.items():
            n += sys.getsizeof(k) + sys.getsizeof(v) + sum(sys.getsizeof(x) for x in v)
        return n
