"""Lazy Tail Tree (LTT, §5.5.2): tails of the inheritance tree under lazy range updates.

Logically the LTT is the inheritance tree with each log's current *tail* (and a
*blocked* counter used by promote semantics, §5.6) at its node. Physically it is
the Euler tour of that tree stored in a balanced BST (here: a treap with parent
pointers), so that

* an append of ``k`` records to log ``P`` becomes a **range add** of ``k`` over
  the contiguous Euler-tour range of ``P``'s subtree  — O(log n);
* reading a log's tail is a **point query**                     — O(log n);
* creating a cFork inserts an (enter, exit) marker pair just before the
  parent's exit marker                                          — O(log n);
* squash excises a subtree range; promote excises just the promoted child's
  two markers, which re-parents its children in O(log n).

The *blocked* value is an integer, range-added like tails: each active
promotable cFork of ``X`` contributes +1 over ``subtree(X)`` and -1 over the
promotable child's subtree, so "is this log blocked?" composes under any number
of concurrent promotable forks (a beyond-paper refinement of the paper's
boolean block/unblock; see DESIGN.md §4.5).

``EagerTailMap`` is the same interface with eager per-descendant updates: it is
both the Bolt-ET ablation variant (§6.4) and the oracle for property tests.
"""

from __future__ import annotations

import random
from typing import Dict, Iterator, List, Optional, Tuple


class _Node:
    __slots__ = ("prio", "left", "right", "parent", "size",
                 "tail", "blocked", "lz_tail", "lz_blk", "log_id", "is_enter")

    def __init__(self, prio: float, log_id: int, is_enter: bool,
                 tail: int = 0, blocked: int = 0) -> None:
        self.prio = prio
        self.left: Optional[_Node] = None
        self.right: Optional[_Node] = None
        self.parent: Optional[_Node] = None
        self.size = 1
        self.tail = tail        # value stored only meaningfully on enter markers
        self.blocked = blocked
        self.lz_tail = 0        # pending add for BOTH children's subtrees
        self.lz_blk = 0
        self.log_id = log_id
        self.is_enter = is_enter


def _size(x: Optional[_Node]) -> int:
    return x.size if x is not None else 0


def _push(x: _Node) -> None:
    if x.lz_tail or x.lz_blk:
        for c in (x.left, x.right):
            if c is not None:
                c.tail += x.lz_tail
                c.blocked += x.lz_blk
                c.lz_tail += x.lz_tail
                c.lz_blk += x.lz_blk
        x.lz_tail = 0
        x.lz_blk = 0


def _upd(x: _Node) -> None:
    x.size = 1 + _size(x.left) + _size(x.right)
    if x.left is not None:
        x.left.parent = x
    if x.right is not None:
        x.right.parent = x


def _merge(a: Optional[_Node], b: Optional[_Node]) -> Optional[_Node]:
    if a is None:
        return b
    if b is None:
        return a
    if a.prio > b.prio:
        _push(a)
        a.right = _merge(a.right, b)
        _upd(a)
        return a
    _push(b)
    b.left = _merge(a, b.left)
    _upd(b)
    return b


def _split(t: Optional[_Node], k: int) -> Tuple[Optional[_Node], Optional[_Node]]:
    """First k nodes in `a`, rest in `b`."""
    if t is None:
        return None, None
    _push(t)
    if _size(t.left) >= k:
        a, rest = _split(t.left, k)
        t.left = rest
        _upd(t)
        if a is not None:
            a.parent = None
        return a, t
    keep, b = _split(t.right, k - _size(t.left) - 1)
    t.right = keep
    _upd(t)
    if b is not None:
        b.parent = None
    return t, b


class LazyTailTree:
    """Forest of Euler-tour treaps keyed by log id."""

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)
        self._enter: Dict[int, _Node] = {}
        self._exit: Dict[int, _Node] = {}

    # -- internals ---------------------------------------------------------
    def _mk(self, log_id: int, is_enter: bool, tail: int, blocked: int) -> _Node:
        return _Node(self._rng.random(), log_id, is_enter, tail, blocked)

    @staticmethod
    def _root(x: _Node) -> _Node:
        while x.parent is not None:
            x = x.parent
        return x

    @staticmethod
    def _index(x: _Node) -> int:
        """0-based position of x in its tour (lazy values do not affect order)."""
        idx = _size(x.left)
        while x.parent is not None:
            if x is x.parent.right:
                idx += _size(x.parent.left) + 1
            x = x.parent
        return idx

    @staticmethod
    def _kth(root: _Node, k: int) -> _Node:
        """Node at tour index k (order-statistic walk; lazy values do not
        affect structure, so no push is needed)."""
        x = root
        while True:
            ls = _size(x.left)
            if k < ls:
                x = x.left
            elif k == ls:
                return x
            else:
                k -= ls + 1
                x = x.right

    @staticmethod
    def _value(x: _Node) -> Tuple[int, int]:
        tail, blk = x.tail, x.blocked
        p = x.parent
        while p is not None:
            tail += p.lz_tail
            blk += p.lz_blk
            p = p.parent
        return tail, blk

    def _range(self, root: _Node, i: int, j: int) -> Tuple[Optional[_Node], _Node, Optional[_Node]]:
        """Split root's tour into [0,i), [i,j], (j,end). Middle is non-empty."""
        a, bc = _split(root, i)
        b, c = _split(bc, j - i + 1)
        assert b is not None
        return a, b, c

    def _rejoin(self, a: Optional[_Node], b: Optional[_Node], c: Optional[_Node]) -> None:
        r = _merge(_merge(a, b), c)
        if r is not None:
            r.parent = None

    # -- public API --------------------------------------------------------
    def contains(self, log_id: int) -> bool:
        return log_id in self._enter

    def add_root(self, log_id: int, tail0: int = 0, blocked0: int = 0) -> None:
        assert log_id not in self._enter
        e = self._mk(log_id, True, tail0, blocked0)
        x = self._mk(log_id, False, 0, 0)
        self._enter[log_id] = e
        self._exit[log_id] = x
        r = _merge(e, x)
        assert r is not None
        r.parent = None

    def add_child(self, parent_id: int, child_id: int, tail0: int, blocked0: int) -> None:
        """Insert child's (enter, exit) just before parent's exit marker."""
        assert child_id not in self._enter
        pexit = self._exit[parent_id]
        root = self._root(pexit)
        k = self._index(pexit)
        a, b = _split(root, k)
        e = self._mk(child_id, True, tail0, blocked0)
        x = self._mk(child_id, False, 0, 0)
        self._enter[child_id] = e
        self._exit[child_id] = x
        self._rejoin(a, _merge(e, x), b)

    def get(self, log_id: int) -> Tuple[int, int]:
        """(tail, blocked) of log_id."""
        return self._value(self._enter[log_id])

    def range_add(self, log_id: int, d_tail: int = 0, d_blocked: int = 0) -> None:
        """Add to every log in subtree(log_id), inclusive."""
        if d_tail == 0 and d_blocked == 0:
            return
        e = self._enter[log_id]
        root = self._root(e)
        i = self._index(e)
        j = self._index(self._exit[log_id])
        a, b, c = self._range(root, i, j)
        b.tail += d_tail
        b.blocked += d_blocked
        b.lz_tail += d_tail
        b.lz_blk += d_blocked
        b.parent = None
        self._rejoin(a, b, c)

    def remove_subtree(self, log_id: int) -> List[int]:
        """Excise subtree(log_id); returns removed log ids (incl. log_id)."""
        e = self._enter[log_id]
        root = self._root(e)
        i = self._index(e)
        j = self._index(self._exit[log_id])
        a, b, c = self._range(root, i, j)
        self._rejoin(a, None, c)
        removed = []
        stack = [b]
        while stack:
            n = stack.pop()
            if n is None:
                continue
            if n.is_enter:
                removed.append(n.log_id)
                del self._enter[n.log_id]
                del self._exit[n.log_id]
            stack.append(n.left)
            stack.append(n.right)
        return removed

    def remove_node_keep_children(self, log_id: int) -> None:
        """Excise only log_id's own two markers; its children re-parent to its
        parent in the tour (used by promote, where the promoted child's
        children become the parent's children)."""
        for marker in ("enter", "exit"):
            node = (self._enter if marker == "enter" else self._exit)[log_id]
            root = self._root(node)
            i = self._index(node)
            a, b, c = self._range(root, i, i)
            assert b is node and b.left is None and b.right is None
            self._rejoin(a, None, c)
        del self._enter[log_id]
        del self._exit[log_id]

    def direct_children(self, log_id: int) -> List[int]:
        """Immediate children of log_id in tour order, O(children * log n):
        hop from each child's enter marker to just past its exit marker
        instead of touring the whole subtree (promote re-parents only the
        promoted child's direct children, DESIGN.md §11)."""
        e = self._enter[log_id]
        root = self._root(e)
        i = self._index(e)
        j = self._index(self._exit[log_id])
        out: List[int] = []
        k = i + 1
        while k < j:
            node = self._kth(root, k)
            assert node.is_enter, "tour structure corrupt: expected enter marker"
            out.append(node.log_id)
            k = self._index(self._exit[node.log_id]) + 1
        return out

    def subtree_ids(self, log_id: int) -> List[int]:
        """Log ids in subtree(log_id) in tour order (O(subtree); test/debug use)."""
        e = self._enter[log_id]
        root = self._root(e)
        i = self._index(e)
        j = self._index(self._exit[log_id])
        out: List[int] = []

        def visit(n: Optional[_Node], lo: int, hi: int, base: int) -> None:
            if n is None:
                return
            left_n = _size(n.left)
            my = base + left_n
            if lo < my:
                visit(n.left, lo, min(hi, my), base)
            if lo <= my < hi and n.is_enter:
                out.append(n.log_id)
            if hi > my + 1:
                visit(n.right, max(lo, my + 1), hi, my + 1)

        visit(root, i, j + 1, 0)
        return out


class EagerTailMap:
    """Eager-per-descendant variant: Bolt-ET (§6.4) and property-test oracle.

    Same interface as LazyTailTree; every range op walks the subtree.
    """

    def __init__(self, seed: int = 0) -> None:
        self.tail: Dict[int, int] = {}
        self.blocked: Dict[int, int] = {}
        self.children: Dict[int, List[int]] = {}
        self.parent: Dict[int, Optional[int]] = {}

    def contains(self, log_id: int) -> bool:
        return log_id in self.tail

    def add_root(self, log_id: int, tail0: int = 0, blocked0: int = 0) -> None:
        self.tail[log_id] = tail0
        self.blocked[log_id] = blocked0
        self.children[log_id] = []
        self.parent[log_id] = None

    def add_child(self, parent_id: int, child_id: int, tail0: int, blocked0: int) -> None:
        self.tail[child_id] = tail0
        self.blocked[child_id] = blocked0
        self.children[child_id] = []
        self.parent[child_id] = parent_id
        self.children[parent_id].append(child_id)

    def _walk(self, log_id: int) -> Iterator[int]:
        stack = [log_id]
        while stack:
            x = stack.pop()
            yield x
            stack.extend(self.children[x])

    def get(self, log_id: int) -> Tuple[int, int]:
        return self.tail[log_id], self.blocked[log_id]

    def range_add(self, log_id: int, d_tail: int = 0, d_blocked: int = 0) -> None:
        for x in self._walk(log_id):
            self.tail[x] += d_tail
            self.blocked[x] += d_blocked

    def remove_subtree(self, log_id: int) -> List[int]:
        removed = list(self._walk(log_id))
        p = self.parent[log_id]
        if p is not None:
            self.children[p].remove(log_id)
        for x in removed:
            del self.tail[x], self.blocked[x], self.children[x], self.parent[x]
        return removed

    def direct_children(self, log_id: int) -> List[int]:
        return list(self.children[log_id])

    def remove_node_keep_children(self, log_id: int) -> None:
        p = self.parent[log_id]
        kids = self.children[log_id]
        for k in kids:
            self.parent[k] = p
        if p is not None:
            idx = self.children[p].index(log_id)
            self.children[p][idx:idx + 1] = kids
        del self.tail[log_id], self.blocked[log_id], self.children[log_id], self.parent[log_id]

    def subtree_ids(self, log_id: int) -> List[int]:
        # pre-order; tour order of the treap version is also pre-order
        out = []
        def rec(x: int) -> None:
            out.append(x)
            for c in self.children[x]:
                rec(c)
        rec(log_id)
        return out
