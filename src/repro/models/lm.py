"""The composable LM: block assembly, scan-over-groups, train/prefill/decode.

Layer stacks are grouped by the config's block pattern and `lax.scan`ned over
stacked parameters: HLO size (and compile time at 512 fake devices) stays
O(pattern length), not O(n_layers). Remat wraps the group body per
``cfg.remat``. Encoder-decoder (whisper) and VLM (llava) wrap the same stack.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import shard
from .attention import (attention_block, init_attention, init_mla,
                        init_self_attn_cache, mla_block)
from .config import ModelConfig
from .layers import apply_mlp, dense_init, init_mlp, init_norm, pdtype, rms_norm
from .moe import apply_moe, init_moe
from .ssm import apply_mamba, init_mamba, init_mamba_state
from .xlstm import (apply_mlstm, apply_slstm, init_mlstm, init_mlstm_state,
                    init_slstm, init_slstm_state)


# ================================================================ block init
def init_block(key, blk: str, cfg: ModelConfig, cross: bool = False) -> Dict:
    mixer, _, ffn = blk.partition("+")
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_norm(cfg)}
    if mixer == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    elif mixer == "mla":
        p["mla"] = init_mla(ks[0], cfg)
    elif mixer == "mamba":
        p["mamba"] = init_mamba(ks[0], cfg)
    elif mixer == "mlstm":
        p["mlstm"] = init_mlstm(ks[0], cfg)
    elif mixer == "slstm":
        p["slstm"] = init_slstm(ks[0], cfg)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if cross:
        p["ln_c"] = init_norm(cfg)
        p["cross"] = init_attention(ks[2], cfg)
    if ffn == "mlp":
        p["ln2"] = init_norm(cfg)
        p["mlp"] = init_mlp(ks[1], cfg)
    elif ffn == "moe":
        p["ln2"] = init_norm(cfg)
        p["moe"] = init_moe(ks[1], cfg)
    return p


def init_block_cache(blk: str, cfg: ModelConfig, batch: int, max_len: int,
                     stack: int, cross_len: int = 0) -> Dict:
    mixer, _, _ = blk.partition("+")
    c: Dict[str, Any] = {}
    if mixer in ("attn", "mla"):
        c.update(init_self_attn_cache(cfg, batch, max_len, stack))
    elif mixer == "mamba":
        c.update(init_mamba_state(cfg, batch, stack))
    elif mixer == "mlstm":
        c.update(init_mlstm_state(cfg, batch, stack))
    elif mixer == "slstm":
        c.update(init_slstm_state(cfg, batch, stack))
    if cross_len:
        dt = pdtype(cfg)
        KH, Dh = cfg.n_kv_heads, cfg.head_dim_
        s = (stack,) if stack else ()
        c["cross_k"] = jnp.zeros(s + (batch, KH, cross_len, Dh), dt)
        c["cross_v"] = jnp.zeros(s + (batch, KH, cross_len, Dh), dt)
    return c


# ================================================================ block apply
def apply_block(blk: str, p: Dict, x: jax.Array, cfg: ModelConfig, *,
                positions: Optional[jax.Array] = None,
                cache: Optional[Dict] = None,
                cache_pos: Optional[jax.Array] = None,
                enc_out: Optional[jax.Array] = None,
                causal: bool = True,
                use_rope: bool = True,
                want_cache: bool = False,
                cross_len: int = 0,
                ) -> Tuple[jax.Array, jax.Array, Dict]:
    mixer, _, ffn = blk.partition("+")
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)

    mixer_cache = None
    if cache is not None:
        mixer_cache = {k: v for k, v in cache.items()
                       if not k.startswith("cross_")}
    if mixer == "attn":
        y, mc = attention_block(
            p["attn"], h, cfg, causal=causal, positions=positions,
            cache=mixer_cache, cache_pos=cache_pos, use_rope=use_rope,
            want_cache=want_cache)
        if mc:
            new_cache.update(mc)
    elif mixer == "mla":
        y, mc = mla_block(p["mla"], h, cfg, positions=positions,
                          cache=mixer_cache, cache_pos=cache_pos,
                          want_cache=want_cache)
        if mc:
            new_cache.update(mc)
    elif mixer == "mamba":
        y, mc = apply_mamba(p["mamba"], h, cfg, state=mixer_cache,
                            want_state=want_cache)
        if mc:
            new_cache.update(mc)
    elif mixer == "mlstm":
        y, mc = apply_mlstm(p["mlstm"], h, cfg, state=mixer_cache,
                            want_state=want_cache)
        if mc:
            new_cache.update(mc)
    elif mixer == "slstm":
        y, mc = apply_slstm(p["slstm"], h, cfg, state=mixer_cache,
                            want_state=want_cache)
        if mc:
            new_cache.update(mc)
    else:
        raise ValueError(mixer)
    x = x + y

    if "cross" in p:
        hc = rms_norm(x, p["ln_c"], cfg.norm_eps)
        if cache is not None and "cross_k" in cache:
            yc, _ = attention_block(
                p["cross"], hc, cfg, kv_x=None, use_rope=False,
                cache={"k": cache["cross_k"], "v": cache["cross_v"]},
                cache_pos=jnp.asarray(cross_len, jnp.int32), cross=True)
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            yc, cc = attention_block(p["cross"], hc, cfg, kv_x=enc_out,
                                     use_rope=False, want_cache=want_cache)
            if cc:
                new_cache["cross_k"] = cc["k"]
                new_cache["cross_v"] = cc["v"]
        x = x + yc

    if ffn == "mlp":
        x = x + apply_mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    elif ffn == "moe":
        y2, aux2 = apply_moe(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        x = x + y2
        aux = aux + aux2
    return shard(x, "data", None, None), aux, new_cache


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ================================================================ params init
def init_params(cfg: ModelConfig, key) -> Dict:
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype=dt),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab),
                                    dtype=dt)
    if cfg.vlm is not None:
        params["vision_proj"] = dense_init(ks[2], (cfg.d_model, cfg.d_model),
                                           dtype=dt)
    if cfg.first_layer_dense:
        mixer = cfg.block_pattern[0].partition("+")[0]
        params["first"] = init_block(ks[3], f"{mixer}+mlp", cfg)
    cross = cfg.is_encdec

    def stacked(key, blk, n, cross_):
        return jax.vmap(lambda k: init_block(k, blk, cfg, cross_))(
            jax.random.split(key, n))

    gks = jax.random.split(ks[4], len(cfg.block_pattern))
    params["groups"] = tuple(
        stacked(gks[j], blk, cfg.n_groups, cross)
        for j, blk in enumerate(cfg.block_pattern))
    if cfg.is_encdec:
        e = cfg.encdec
        params["encoder"] = {
            "pos_embed": dense_init(ks[5], (e.enc_len, cfg.d_model), dtype=dt),
            "groups": (stacked(ks[6], "attn+mlp", e.enc_layers, False),),
            "final_norm": init_norm(cfg),
        }
    return params


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> Tuple:
    cross_len = cfg.encdec.enc_len if cfg.is_encdec else 0
    caches = tuple(
        init_block_cache(blk, cfg, batch, max_len, stack=cfg.n_groups,
                         cross_len=cross_len)
        for blk in cfg.block_pattern)
    first = (init_block_cache(f"{cfg.block_pattern[0].partition('+')[0]}+mlp",
                              cfg, batch, max_len, stack=0)
             if cfg.first_layer_dense else None)
    return {"groups": caches, "first": first}


# ================================================================== forward
def _stack_forward(cfg: ModelConfig, groups, x, *, positions, enc_out,
                   causal, use_rope, want_caches):
    """Scan over layer groups; each group applies the whole block pattern."""

    def group_body(carry, group_params):
        x = carry
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for j, blk in enumerate(cfg.block_pattern):
            x, a, c = apply_block(
                blk, group_params[j], x, cfg,
                positions=positions, enc_out=enc_out, causal=causal,
                use_rope=use_rope, want_cache=want_caches)
            aux = aux + a
            caches.append(c)
        return x, (aux, tuple(caches))

    body = _remat(group_body, cfg)
    x, (auxs, caches) = jax.lax.scan(body, x, groups)
    return x, auxs.sum(), caches


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder: frames are precomputed conv-frontend embeddings
    (B, enc_len, D) — the modality stub per the assignment."""
    enc = params["encoder"]
    x = frames + enc["pos_embed"][None, :frames.shape[1], :].astype(frames.dtype)
    x = shard(x, "data", None, None)

    def enc_body(carry, gp):
        x = carry
        x, _, _ = apply_block("attn+mlp", gp, x, cfg, causal=False,
                              use_rope=False)
        return x, None

    x, _ = jax.lax.scan(_remat(enc_body, cfg), x, enc["groups"][0])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def embed_inputs(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, int]:
    """Token (+ vision-prefix) embedding. Returns (x, n_prefix)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    n_prefix = 0
    if cfg.vlm is not None and "vision_embeds" in batch:
        v = jnp.einsum("bpd,de->bpe", batch["vision_embeds"].astype(x.dtype),
                       params["vision_proj"])
        x = jnp.concatenate([v, x], axis=1)
        n_prefix = v.shape[1]
    return shard(x, "data", None, None), n_prefix


def forward(cfg: ModelConfig, params: Dict, batch: Dict, *,
            want_caches: bool = False):
    """Full-sequence forward. Returns (logits, aux, caches)."""
    x, n_prefix = embed_inputs(cfg, params, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(cfg, params, batch["frames"])

    first_cache = None
    if cfg.first_layer_dense:
        blk = f"{cfg.block_pattern[0].partition('+')[0]}+mlp"
        x, _, first_cache = apply_block(blk, params["first"], x, cfg,
                                        positions=positions,
                                        want_cache=want_caches)
    x, aux, caches = _stack_forward(
        cfg, params["groups"], x, positions=positions, enc_out=enc_out,
        causal=True, use_rope=not cfg.is_encdec, want_caches=want_caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = shard(logits, "data", None, "model")
    cache_tree = ({"groups": caches, "first": first_cache}
                  if want_caches else None)
    return logits, aux, cache_tree, n_prefix


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
    logits, aux, _, n_prefix = forward(cfg, params, batch)
    labels = batch["labels"]
    if n_prefix:
        logits = logits[:, n_prefix:]
    logits = logits[:, :labels.shape[1]].astype(jnp.float32)
    # mask vocab padding out of the partition function
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    logits = jnp.where(vmask[None, None, :], logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux}


# ================================================================== decode
def decode_step(cfg: ModelConfig, params: Dict, caches: Dict,
                tokens: jax.Array, pos: jax.Array):
    """One serving step: tokens (B, 1) at absolute position `pos` given the
    KV/state caches. Returns (logits, new_caches)."""
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    x = shard(x, "data", None, None)
    positions = pos + jnp.arange(x.shape[1])
    cross_len = cfg.encdec.enc_len if cfg.is_encdec else 0

    new_first = None
    if cfg.first_layer_dense:
        blk = f"{cfg.block_pattern[0].partition('+')[0]}+mlp"
        x, _, new_first = apply_block(blk, params["first"], x, cfg,
                                      positions=positions,
                                      cache=caches["first"], cache_pos=pos,
                                      cross_len=cross_len)

    def group_body(carry, inp):
        x = carry
        gp, gc = inp
        new_caches = []
        for j, blk in enumerate(cfg.block_pattern):
            x, _, nc = apply_block(blk, gp[j], x, cfg, positions=positions,
                                   cache=gc[j], cache_pos=pos,
                                   cross_len=cross_len)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_group_caches = jax.lax.scan(
        group_body, x, (params["groups"], caches["groups"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, {"groups": new_group_caches, "first": new_first}
