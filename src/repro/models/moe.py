"""Top-k MoE with group-local capacity dispatch and expert parallelism.

Dispatch evolution (measured in the dry-run; EXPERIMENTS.md §Perf):
  * "scatter" — global sort + scatter into an (E, C, D) buffer. SPMD lowers
    the cross-partition scatter into full-buffer partition reduces
    (23 TB/step of all-reduce for granite-moe). Kept as the ablation baseline.
  * "gather" (default) — GROUP-LOCAL dispatch: tokens reshape to
    (G, T/G, D) with G sharded over the data axes, so the sort, the capacity
    assignment, the dispatch gather and the combine gather are all
    partition-local; experts stay sharded over 'model' (EP) and the only
    cross-shard movement is the expert outputs crossing the model axis once.
    Per-group capacity drops tokens per data shard (better locality than the
    paper-classic global capacity; noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import axis_size, shard
from .config import ModelConfig
from .layers import apply_mlp, dense_init, init_mlp, pdtype


def init_moe(key, cfg: ModelConfig) -> Dict:
    m = cfg.moe
    E = cfg.n_experts_padded
    ks = jax.random.split(key, 5)
    dt = pdtype(cfg)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, E), std=0.006, dtype=jnp.float32),
        "e_in": dense_init(ks[1], (E, cfg.d_model, m.d_expert), dtype=dt),
        "e_gate": dense_init(ks[2], (E, cfg.d_model, m.d_expert), dtype=dt),
        "e_out": dense_init(ks[3], (E, m.d_expert, cfg.d_model),
                            std=0.02 / (2 * cfg.n_layers) ** 0.5, dtype=dt),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.n_shared * m.d_expert)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k * m.capacity_factor / cfg.n_experts_padded)
    return max(64, ((c + 127) // 128) * 128)  # MXU-aligned


def _route(xt: jax.Array, p: Dict, cfg: ModelConfig):
    """Router probs + top-k (xt: (..., D))."""
    m = cfg.moe
    E = cfg.n_experts_padded
    logits = xt.astype(jnp.float32) @ p["router"]
    if E > m.n_experts:
        logits = jnp.where(jnp.arange(E)[None, :] >= m.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return probs, gate, eidx


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = cfg.n_experts_padded, m.top_k
    T = B * S
    xt = x.reshape(T, D)

    if cfg.moe_dispatch != "gather":
        return _apply_moe_scatter(p, x, xt, cfg)

    # ---- group-local dispatch ---------------------------------------------
    G = 1
    for cand in (axis_size("pod") * axis_size("data"), 16, 8, 4, 2):
        if cand > 1 and T % cand == 0:
            G = cand
            break
    Tl = T // G
    C = _capacity(Tl, cfg)
    xg = shard(xt.reshape(G, Tl, D), "data", None, None)

    probs, gate, eidx = _route(xg, p, cfg)                     # (G,Tl,E/K)
    flat_e = eidx.reshape(G, Tl * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (G, TlK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    seg_start = jax.vmap(jnp.searchsorted)(sorted_e, jnp.broadcast_to(
        jnp.arange(E), (G, E)))                                # (G, E)
    pos_in_e = jnp.arange(Tl * K)[None, :] - jnp.take_along_axis(
        seg_start, sorted_e, axis=-1)
    keep = pos_in_e < C
    src_tok = order // K                                       # (G, TlK)

    counts = jnp.diff(jnp.concatenate(
        [seg_start, jnp.full((G, 1), Tl * K)], axis=-1), axis=-1)  # (G, E)
    slot_s = seg_start[:, :, None] + jnp.arange(C)[None, None, :]  # (G,E,C)
    valid = jnp.arange(C)[None, None, :] < jnp.minimum(counts, C)[:, :, None]
    slot_tok = jnp.where(
        valid,
        jnp.take_along_axis(src_tok, jnp.clip(slot_s, 0, Tl * K - 1)
                            .reshape(G, E * C), axis=-1).reshape(G, E, C),
        Tl)
    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)
    ebuf = jnp.take_along_axis(
        xg_pad, slot_tok.reshape(G, E * C, 1), axis=1).reshape(G, E, C, D)
    ebuf = shard(ebuf, "data", "model", None, None)

    # ---- expert FFN: E over 'model' (EP), groups over 'data' — all local ----
    h = jnp.einsum("gecd,edf->gecf", ebuf, p["e_in"])
    g_ = jnp.einsum("gecd,edf->gecf", ebuf, p["e_gate"])
    h = h * jax.nn.silu(g_.astype(jnp.float32)).astype(h.dtype)
    y = jnp.einsum("gecf,efd->gecd", h, p["e_out"])            # (G,E,C,D)
    y = shard(y, "data", None, None, None)   # expert outputs cross 'model' once

    # ---- combine: group-local gathers + unsort ------------------------------
    y_pad = jnp.concatenate([y.reshape(G, E * C, D),
                             jnp.zeros((G, 1, D), y.dtype)], axis=1)
    slot_sorted = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # (G,TlK)
    inv = jnp.argsort(order, axis=-1)
    slot_orig = jnp.take_along_axis(slot_sorted, inv, axis=-1)
    contrib = jnp.take_along_axis(
        y_pad, slot_orig.reshape(G, Tl * K, 1), axis=1)        # (G,TlK,D)
    contrib = contrib * gate.reshape(G, Tl * K, 1).astype(y.dtype)
    out = contrib.reshape(G, Tl, K, D).sum(axis=2).reshape(B, S, D)

    aux = _aux_loss(probs.reshape(T, E), eidx.reshape(T, K), cfg)
    if m.n_shared:
        out = out + apply_mlp(p["shared"], x)
    return shard(out, "data", None, None), aux


def _aux_loss(probs, eidx, cfg) -> jax.Array:
    E = cfg.n_experts_padded
    density = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    return jnp.sum(density * density_proxy) * E * cfg.moe.router_aux_coef


def _apply_moe_scatter(p: Dict, x: jax.Array, xt: jax.Array,
                       cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Global sort + scatter dispatch (ablation baseline; see module doc)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = cfg.n_experts_padded, m.top_k
    T = B * S
    C = _capacity(T, cfg)
    probs, gate, eidx = _route(xt, p, cfg)
    aux = _aux_loss(probs, eidx, cfg)

    flat_e = eidx.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(T * K) - seg_start[sorted_e]
    keep = pos_in_e < C
    src_tok = order // K
    dest_e = jnp.where(keep, sorted_e, E)
    dest_c = jnp.where(keep, pos_in_e, 0)
    buf = jnp.zeros((E + 1, C, D), xt.dtype)
    buf = buf.at[dest_e, dest_c].set(xt[src_tok])
    ebuf = shard(buf[:E], "model", "data", None)

    h = jnp.einsum("ecd,edf->ecf", ebuf, p["e_in"])
    g = jnp.einsum("ecd,edf->ecf", ebuf, p["e_gate"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["e_out"])
    y = shard(y, "model", "data", None)

    y_pad = jnp.concatenate([y, jnp.zeros((1, C, D), y.dtype)], axis=0)
    contrib = y_pad[dest_e, dest_c]
    contrib = contrib * gate.reshape(T * K)[order][:, None].astype(y.dtype)
    out = jnp.zeros((T, D), y.dtype).at[src_tok].add(contrib)
    out = out.reshape(B, S, D)
    if m.n_shared:
        out = out + apply_mlp(p["shared"], x)
    return shard(out, "data", None, None), aux
