"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM.

mLSTM recurrence (per head, stabilized):
    m_t = max(logsig(f~_t) + m_{t-1}, i~_t)
    C_t = exp(logsig(f~_t) + m_{t-1} - m_t) C_{t-1} + exp(i~_t - m_t) v_t k_t^T
    n_t likewise with k_t;   h_t = (C_t q_t) / max(|n_t.q_t|, exp(-m_t))

Training uses the *chunkwise* form (intra-chunk L×L matmuls + inter-chunk
state — the TPU-friendly linear-attention factorization; this is also the
Pallas kernel target, kernels/mlstm_chunk.py). Decode is the O(1) recurrence.
Tests assert chunked == recurrent.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import shard
from .config import ModelConfig
from .layers import dense_init, pdtype


QKV_BLOCK = 4  # xLSTM block-diagonal qkv projection block size


def _dims(cfg: ModelConfig) -> Tuple[int, int]:
    """mLSTM inner dims (proj factor 2)."""
    d_inner = 2 * cfg.d_model
    return d_inner, d_inner // cfg.n_heads


def _sdims(cfg: ModelConfig) -> Tuple[int, int]:
    """sLSTM inner dims (proj factor 1)."""
    return cfg.d_model, cfg.d_model // cfg.n_heads


# ===================================================================== mLSTM
def init_mlstm(key, cfg: ModelConfig) -> Dict:
    Di, _ = _dims(cfg)
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    nb = Di // QKV_BLOCK
    return {
        "x_up": dense_init(ks[0], (D, 2, Di), dtype=dt),
        # block-diagonal qkv (xLSTM qkv_proj_blocksize=4): (nb, bs, bs)
        "x_q": dense_init(ks[1], (nb, QKV_BLOCK, QKV_BLOCK), std=0.3, dtype=dt),
        "x_k": dense_init(ks[2], (nb, QKV_BLOCK, QKV_BLOCK), std=0.3, dtype=dt),
        "x_v": dense_init(ks[3], (nb, QKV_BLOCK, QKV_BLOCK), std=0.3, dtype=dt),
        "x_if": dense_init(ks[4], (Di, 2 * H), std=0.1, dtype=jnp.float32),
        "x_out": dense_init(ks[5], (Di, D),
                            std=0.02 / (2 * cfg.n_layers) ** 0.5, dtype=dt),
    }


def _mlstm_chunk_body(q, k, v, li, lf, C0, n0, m0):
    """One chunk. q,k,v: (B,H,L,Dh) fp32; li,lf: (B,H,L) fp32.
    State: C0 (B,H,Dh,Dh), n0 (B,H,Dh), m0 (B,H). Returns h, (C,n,m)."""
    L = q.shape[2]
    F = jnp.cumsum(lf, axis=-1)                     # inclusive log-decay
    g = li - F                                      # (B,H,L)
    run = jnp.maximum(m0[..., None], jax.lax.cummax(g, axis=2))
    m = F + run                                     # stabilizer per t
    # intra-chunk: W[t,s] = exp(F_t - F_s + li_s - m_t), s <= t
    logw = (F - m)[..., :, None] + g[..., None, :]  # (B,H,L,L) t,s
    mask = jnp.tril(jnp.ones((L, L), bool))
    W = jnp.where(mask, jnp.exp(logw), 0.0)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * W
    h_num = jnp.einsum("bhts,bhsd->bhtd", scores, v)
    n_intra = jnp.einsum("bhts,bhsd->bhtd", W, k)
    # inter-chunk: state contribution
    w_state = jnp.exp(F + m0[..., None] - m)        # (B,H,L)
    h_num = h_num + w_state[..., None] * jnp.einsum("bhtd,bhde->bhte", q, C0)
    n_t = n_intra + w_state[..., None] * n0[..., None, :]
    denom = jnp.abs(jnp.einsum("bhtd,bhtd->bht", q, n_t))
    h = h_num / jnp.maximum(denom, jnp.exp(-m))[..., None]
    # next state
    m_L = m[..., -1]
    wk = jnp.exp((F[..., -1:] - F) + li - m_L[..., None])   # (B,H,L)
    C = (jnp.exp(F[..., -1] + m0 - m_L)[..., None, None] * C0
         + jnp.einsum("bhs,bhsd,bhse->bhde", wk, k, v))
    n = (jnp.exp(F[..., -1] + m0 - m_L)[..., None] * n0
         + jnp.einsum("bhs,bhsd->bhd", wk, k))
    return h, (C, n, m_L)


def mlstm_sequence(q, k, v, li, lf, state=None, chunk: int = 64):
    """q,k,v: (B,H,S,Dh); li,lf: (B,H,S). Chunkwise scan; returns (h, state)."""
    B, H, S, Dh = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
        n0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, H, nc, chunk, *x.shape[3:]), 2, 0)

    def body(carry, inp):
        qc, kc, vc, lic, lfc = inp
        h, carry2 = _mlstm_chunk_body(qc, kc, vc, lic, lfc, *carry)
        return carry2, h

    (C, n, m), hs = jax.lax.scan(
        jax.checkpoint(body), (C0, n0, m0),
        (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(li), to_chunks(lf)))
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, S, Dh)
    return h, {"C": C, "n": n, "m": m}


def apply_mlstm(p: Dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict] = None,
                want_state: bool = False,
                chunk: int = 64) -> Tuple[jax.Array, Optional[Dict]]:
    Di, Dh = _dims(cfg)
    B, S, D = x.shape
    H = cfg.n_heads
    uz = jnp.einsum("bsd,dti->bsti", x, p["x_up"])
    u, z = uz[:, :, 0], uz[:, :, 1]
    ub = u.reshape(B, S, Di // QKV_BLOCK, QKV_BLOCK)

    def blockproj(w):
        # NOTE(§Perf bonus, refuted): a strided head layout (channel -> Dh-
        # major) makes q/k/v shardable over 'model' and removes XLA's
        # involuntary full remat — but the mLSTM state C = k v^T then wants
        # BOTH its dims on the same axis, and the induced gathers cost more
        # than they save (16x16 collective 4.2s -> 8.8s measured). Reverted:
        # xlstm keeps replicated heads; its TP parallelism comes from the
        # block-diagonal channel sharding of x_up/x_out instead.
        return jnp.einsum("bsnc,ncd->bsnd", ub, w).reshape(B, S, H, Dh)

    q, k, v = (blockproj(p[n]).swapaxes(1, 2).astype(jnp.float32)
               for n in ("x_q", "x_k", "x_v"))
    k = k * Dh ** -0.5
    gates = jnp.einsum("bsi,ig->bsg", u.astype(jnp.float32), p["x_if"])
    li = gates[..., :H].swapaxes(1, 2)                       # (B,H,S)
    lf = jax.nn.log_sigmoid(gates[..., H:]).swapaxes(1, 2)
    h, new_state = mlstm_sequence(q, k, v, li, lf, state, chunk)
    h = h.swapaxes(1, 2).reshape(B, S, Di).astype(x.dtype)
    h = shard(h, "data", None, "model")
    out = (h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = jnp.einsum("bsi,id->bsd", out, p["x_out"])
    keep = state is not None or want_state
    return shard(out, "data", None, None), (new_state if keep else None)


def init_mlstm_state(cfg: ModelConfig, batch: int, stack: int = 0) -> Dict:
    Di, Dh = _dims(cfg)
    H = cfg.n_heads
    s = (stack,) if stack else ()
    return {"C": jnp.zeros(s + (batch, H, Dh, Dh), jnp.float32),
            "n": jnp.zeros(s + (batch, H, Dh), jnp.float32),
            "m": jnp.full(s + (batch, H), -1e30, jnp.float32)}


# ===================================================================== sLSTM
def init_slstm(key, cfg: ModelConfig) -> Dict:
    Di, Dh = _sdims(cfg)
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 3)
    return {
        "s_gates": dense_init(ks[0], (D, 4, Di), dtype=jnp.float32),
        "s_rec": dense_init(ks[1], (4, H, Dh, Dh), std=Dh ** -0.5,
                            dtype=jnp.float32),
        "s_out": dense_init(ks[2], (Di, D),
                            std=0.02 / (2 * cfg.n_layers) ** 0.5,
                            dtype=pdtype(cfg)),
    }


def apply_slstm(p: Dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict] = None,
                want_state: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    Di, Dh = _sdims(cfg)
    B, S, D = x.shape
    H = cfg.n_heads
    pre = jnp.einsum("bsd,dgi->bsgi", x.astype(jnp.float32),
                     p["s_gates"]).reshape(B, S, 4, H, Dh)
    if state is None:
        c0 = jnp.zeros((B, H, Dh), jnp.float32)
        n0 = jnp.ones((B, H, Dh), jnp.float32)
        h0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.zeros((B, H, Dh), jnp.float32)
    else:
        c0, n0, h0, m0 = state["sc"], state["sn"], state["sh"], state["sm"]

    R = p["s_rec"]

    def step(carry, pre_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhd,ghde->bghe", h, R)             # (B,4,H,Dh)
        zi, zf, zz, zo = [pre_t[:, g] + rec[:, g] for g in range(4)]
        lf = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(lf + m, zi)
        i_ = jnp.exp(zi - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c_new = f_ * c + i_ * jnp.tanh(zz)
        n_new = f_ * n + i_
        h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0),
                                    jnp.moveaxis(pre, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(B, S, Di).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", out, p["s_out"])
    new_state = ({"sc": c, "sn": n, "sh": h, "sm": m}
                 if (state is not None or want_state) else None)
    return shard(out, "data", None, None), new_state


def init_slstm_state(cfg: ModelConfig, batch: int, stack: int = 0) -> Dict:
    Di, Dh = _sdims(cfg)
    H = cfg.n_heads
    s = (stack,) if stack else ()
    z = lambda: jnp.zeros(s + (batch, H, Dh), jnp.float32)  # noqa: E731
    return {"sc": z(), "sn": jnp.ones(s + (batch, H, Dh), jnp.float32),
            "sh": z(), "sm": z()}
