"""Shared layer primitives: init helpers, RMSNorm, RoPE, SwiGLU MLP."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import shard
from .config import ModelConfig


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, std: float = 0.02, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, Dh); positions: (S,) or broadcastable."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- SwiGLU MLP
def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = pdtype(cfg)
    p = {
        "w_in": dense_init(k1, (cfg.d_model, d_ff), dtype=dt),
        "w_out": dense_init(k3, (d_ff, cfg.d_model),
                            std=0.02 / (2 * cfg.n_layers) ** 0.5, dtype=dt),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(k2, (cfg.d_model, d_ff), dtype=dt)
    return p


def apply_mlp(p: Dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    h = shard(h, "data", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


def init_norm(cfg: ModelConfig) -> jax.Array:
    return jnp.zeros((cfg.d_model,), pdtype(cfg))
