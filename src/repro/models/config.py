"""Model configuration for the 10-architecture zoo.

One composable decoder stack parameterized by a per-layer *block pattern*;
pattern entries are "mixer+ffn" pairs:

  "attn+mlp"   — GQA attention + SwiGLU MLP          (llama-family)
  "attn+moe"   — GQA attention + top-k MoE
  "mla+mlp"    — Multi-head Latent Attention + MLP   (deepseek-v2)
  "mla+moe"    — MLA + MoE
  "mamba+mlp"  — Mamba selective SSM + MLP           (jamba)
  "mamba+moe"  — Mamba + MoE
  "mlstm"      — xLSTM matrix-memory block (no separate FFN)
  "slstm"      — xLSTM scalar-memory block

The pattern is cycled over the layer stack; homogeneous groups are
`lax.scan`ned over stacked params (bounded HLO at 512 devices).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0           # always-on shared experts (deepseek-v2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    q_lora_rank: int = 0        # 0 = full-rank queries (v2-lite)


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    expand: int = 2
    conv_width: int = 4
    dt_rank: int = 0            # 0 = auto (d_model / 16)


@dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int = 12
    enc_len: int = 1500         # audio frames after the (stubbed) conv frontend


@dataclass(frozen=True)
class VLMCfg:
    n_patches: int = 576        # precomputed anyres patch embeddings (stub)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 = d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn+mlp",)
    first_layer_dense: bool = False         # deepseek-v2: layer 0 uses dense MLP
    qk_norm: bool = False
    mlp_gated: bool = True              # SwiGLU (False: 2-matrix GELU MLP)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    encdec: Optional[EncDecCfg] = None
    vlm: Optional[VLMCfg] = None
    # ---- performance / distribution knobs (hillclimb targets) ----
    attn_chunk: int = 512                   # flash-attention KV chunk
    remat: str = "full"                     # none | dots | full
    use_pallas: bool = False                # TPU deploy: Pallas kernels
    pad_heads_to: int = 0                   # pad q-heads for TP divisibility
    kv_repeat: int = 1                      # compute-time kv-head replication
                                            # (MaxText-style; exact for TP>KH)
    pad_experts_to: int = 0                 # pad experts for EP divisibility
    moe_dispatch: str = "gather"            # gather | scatter (hillclimb knob:
                                            # scatter lowers to partition-wide
                                            # reduce; gather stays local)
    decode_seq_shards: int = 1              # flash-decode cache shards (model axis)
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_heads_padded(self) -> int:
        return max(self.n_heads, self.pad_heads_to)

    @property
    def n_kv_eff(self) -> int:
        """kv heads at compute/cache time (stored params keep n_kv_heads; the
        activation is repeated `kv_repeat`x so TP stays exact: q slot h maps
        to effective kv h // G_pad, whose source is h // (H_pad/KH) — the
        original grouping, provided pad q-slots are the last slot(s) of each
        KH-superblock (see init_attention's wo mask)."""
        kv = self.n_kv_heads * self.kv_repeat
        assert self.n_heads_padded % kv == 0, \
            f"{self.name}: padded heads {self.n_heads_padded} not divisible by kv_eff {kv}"
        return kv

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def n_experts_padded(self) -> int:
        assert self.moe is not None
        return max(self.moe.n_experts, self.pad_experts_to)

    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        """Pattern for the scanned portion of the stack."""
        n = self.n_layers - (1 if self.first_layer_dense else 0)
        assert n % len(self.block_pattern) == 0, \
            f"{self.name}: {n} layers not divisible by pattern {len(self.block_pattern)}"
        return self.block_pattern

    @property
    def n_groups(self) -> int:
        n = self.n_layers - (1 if self.first_layer_dense else 0)
        return n // len(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS and memory sanity) ----
    def count_params(self) -> Tuple[int, int]:
        """(total, active) parameter counts, embeddings included in total,
        excluded from active compute-FLOPs accounting (6ND uses non-embedding
        by convention for MoE 'active')."""
        D, Dh = self.d_model, self.head_dim_
        H, KH = self.n_heads, self.n_kv_heads
        total = active = 0

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                p = D * (m.kv_lora_rank + m.rope_head_dim)            # down kv
                p += m.kv_lora_rank * H * Dh * 2                      # up k, v
                p += D * H * (Dh + m.rope_head_dim)                   # q
                p += H * Dh * D                                       # out
                return p
            return D * H * Dh + 2 * D * KH * Dh + H * Dh * D

        def mlp_params() -> int:
            return (3 if self.mlp_gated else 2) * D * self.d_ff

        def moe_params() -> Tuple[int, int]:
            m = self.moe
            per = 3 * D * m.d_expert
            tot = m.n_experts * per + D * m.n_experts
            act = m.top_k * per + D * m.n_experts
            if m.n_shared:
                tot += m.n_shared * per
                act += m.n_shared * per
            return tot, act

        def mamba_params() -> int:
            c = self.mamba
            Di = c.expand * D
            dtr = c.dt_rank or D // 16
            return (D * 2 * Di + c.conv_width * Di + Di * (dtr + 2 * c.d_state)
                    + dtr * Di + Di * c.d_state + Di + Di * D)

        def xlstm_params(kind: str) -> int:
            if kind == "mlstm":
                Di = 2 * D   # block-diagonal qkv (blocksize 4): ~0 params
                return D * 2 * Di + 3 * Di * 4 + Di * 2 * H + Di * D
            Di = D
            return D * 4 * Di + 4 * H * (Di // H) ** 2 + Di * D

        layers = ([("attn+mlp" if self.moe is None else "attn+mlp")]
                  if self.first_layer_dense else [])
        layers += list(self.block_pattern) * self.n_groups
        for blk in layers:
            mixer, _, ffn = blk.partition("+")
            if mixer in ("attn", "mla"):
                p = attn_params()
                total += p
                active += p
            elif mixer == "mamba":
                p = mamba_params()
                total += p
                active += p
            elif mixer in ("mlstm", "slstm"):
                p = xlstm_params(mixer)
                total += p
                active += p
            if ffn == "mlp" or (blk == "attn+mlp" and self.d_ff):
                total += mlp_params()
                active += mlp_params()
            elif ffn == "moe":
                t, a = moe_params()
                total += t
                active += a
        if self.is_encdec:
            enc = (attn_params() + mlp_params()) * self.encdec.enc_layers
            cross = attn_params() * self.n_layers
            total += enc + cross
            active += enc + cross
        emb = self.padded_vocab * D * (1 if self.tie_embeddings else 2)
        return total + emb, active
