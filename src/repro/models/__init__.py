"""The 10-architecture model zoo in pure JAX."""

from .config import (EncDecCfg, MambaCfg, MLACfg, ModelConfig, MoECfg, VLMCfg)
from .lm import (decode_step, forward, init_caches, init_params, loss_fn)

__all__ = ["ModelConfig", "MoECfg", "MLACfg", "MambaCfg", "EncDecCfg",
           "VLMCfg", "init_params", "init_caches", "forward", "loss_fn",
           "decode_step"]
