"""Attention: chunked flash (train/prefill), sharded flash-decode, GQA, MLA.

TPU-native choices (DESIGN.md §3):
  * train/prefill attention is an online-softmax scan over KV chunks — the
    XLA-level flash formulation (fp32 accumulators, chunk sized for VMEM); the
    explicit Pallas kernel (kernels/flash_attention.py) is selected with
    ``cfg.use_pallas`` on real TPUs.
  * decode shards the KV cache's *sequence* dim over the 'model' axis and
    combines per-shard partial attention with a log-sum-exp reduction — flash-
    decoding expressed in pure SPMD (the cross-shard combine lowers to small
    all-reduces over ICI).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import shard
from .config import ModelConfig
from .layers import apply_rope, dense_init, pdtype, rms_norm


# ------------------------------------------------------------------ init
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    D, Dh = cfg.d_model, cfg.head_dim_
    H, KH = cfg.n_heads_padded, cfg.n_kv_heads   # params store ORIGINAL kv heads
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    p = {
        "wq": dense_init(ks[0], (D, H, Dh), dtype=dt),
        "wk": dense_init(ks[1], (D, KH, Dh), dtype=dt),
        "wv": dense_init(ks[2], (D, KH, Dh), dtype=dt),
        "wo": dense_init(ks[3], (H, Dh, D),
                         std=0.02 / (2 * cfg.n_layers) ** 0.5, dtype=dt),
    }
    if cfg.n_heads_padded > cfg.n_heads:
        # pad q-slots are the LAST slots of each kv superblock, so real head j
        # keeps its original kv group (permutation-equivalent, exact geometry);
        # their wo rows are zeroed so they cannot affect the output
        sb = H // KH                       # slots per original kv head
        real = cfg.n_heads // KH           # real q heads per kv head
        mask = ((jnp.arange(H) % sb) < real).astype(dt)[:, None, None]
        p["wo"] = p["wo"] * mask
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dt)
        p["k_norm"] = jnp.zeros((Dh,), dt)
    return p


def init_mla(key, cfg: ModelConfig) -> Dict:
    m = cfg.mla
    D, Dh, H = cfg.d_model, cfg.head_dim_, cfg.n_heads_padded
    ks = jax.random.split(key, 5)
    dt = pdtype(cfg)
    return {
        "w_dkv": dense_init(ks[0], (D, m.kv_lora_rank + m.rope_head_dim), dtype=dt),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dt),
        "w_uk": dense_init(ks[1], (m.kv_lora_rank, H, Dh), dtype=dt),
        "w_uv": dense_init(ks[2], (m.kv_lora_rank, H, Dh), dtype=dt),
        "wq": dense_init(ks[3], (D, H, Dh + m.rope_head_dim), dtype=dt),
        "wo": dense_init(ks[4], (H, Dh, D),
                         std=0.02 / (2 * cfg.n_layers) ** 0.5, dtype=dt),
    }


# ------------------------------------------------------------- flash (train)
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool, chunk: int,
                    q_offset: int = 0) -> jax.Array:
    """q: (B, H, Sq, Dhk); k: (B, KH, Sk, Dhk); v: (B, KH, Sk, Dhv) with
    H = KH * G (Dhk may exceed Dhv, e.g. MLA rope-extended keys).
    Online-softmax scan over KV chunks; fp32 accumulators."""
    B, H, Sq, Dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    Dhv = v.shape[-1]
    G = H // KH
    qg = q.reshape(B, KH, G, Sq, Dh)
    scale = Dh ** -0.5
    chunk = min(chunk, Sk)
    kv_len = Sk
    pad = (-Sk) % chunk
    if pad:  # non-divisible kv length (e.g. whisper's 1500 frames): pad + mask
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Sk = Sk + pad
    n_chunks = Sk // chunk
    kc = jnp.moveaxis(k.reshape(B, KH, n_chunks, chunk, Dh), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, KH, n_chunks, chunk, Dhv), 2, 0)
    q_pos = jnp.arange(Sq) + q_offset

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, ci = inp
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = ci * chunk + jnp.arange(chunk)
        if causal:
            mask = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(mask, s, -jnp.inf)
        if pad:
            s = jnp.where(kv_pos[None, :] < kv_len, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - jnp.where(jnp.isfinite(m_new), m_new, 0.0)[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m),
                         jnp.exp(m - jnp.where(jnp.isfinite(m_new), m_new, 0.0)),
                         0.0)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, Dhv), jnp.float32)
    body = jax.checkpoint(body)  # flash backward: recompute p per chunk
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, Dhv).astype(q.dtype)


# ------------------------------------------------------- flash-decode (serve)
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, n_shards: int) -> jax.Array:
    """q: (B, H, 1, Dh); caches: (B, KH, L, Dh) with L sharded over 'model'
    as `n_shards` blocks. Per-shard partials + LSE combine (pure SPMD)."""
    B, H, _, Dh = q.shape
    KH, L = k_cache.shape[1], k_cache.shape[2]
    Dhv = v_cache.shape[-1]
    G = H // KH
    pad = (-L) % n_shards
    if pad:  # non-divisible cache length (e.g. whisper's 1500-frame cross kv):
        # zero-pad; padded positions sit beyond cache_len and are masked out
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        L = L + pad
    Lc = L // n_shards
    qg = q.reshape(B, KH, G, Dh)
    kb = shard(k_cache.reshape(B, KH, n_shards, Lc, Dh),
               "data", None, "model", None, None)
    vb = shard(v_cache.reshape(B, KH, n_shards, Lc, Dhv),
               "data", None, "model", None, None)
    scale = Dh ** -0.5
    s = jnp.einsum("bkgd,bknld->bkngl", qg, kb,
                   preferred_element_type=jnp.float32) * scale
    pos = (jnp.arange(n_shards) * Lc)[:, None] + jnp.arange(Lc)[None, :]
    s = jnp.where(pos[None, None, :, None, :] < cache_len, s, -jnp.inf)
    m_i = s.max(-1)                                          # (B,KH,n,G)
    p = jnp.exp(s - m_i[..., None])
    p = jnp.where(jnp.isfinite(m_i)[..., None], p, 0.0)
    l_i = p.sum(-1)
    o_i = jnp.einsum("bkngl,bknld->bkngd", p.astype(vb.dtype), vb,
                     preferred_element_type=jnp.float32)
    m_g = m_i.max(2, keepdims=True)
    w = jnp.exp(m_i - m_g)
    l_g = (l_i * w).sum(2)
    o_g = (o_i * w[..., None]).sum(2) / jnp.maximum(l_g, 1e-30)[..., None]
    return o_g.reshape(B, H, 1, Dhv).astype(q.dtype)


# ------------------------------------------------------------------ GQA block
def _project_q(p, x, cfg):
    q = jnp.einsum("bsd,dhq->bhsq", x, p["wq"])
    return q


def _kv_repeat(kv: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Compute-time kv-head replication (kv stays exact: repeated heads are
    tied copies). Makes KH_eff divisible by the TP axis."""
    if cfg.kv_repeat == 1:
        return kv
    return jnp.repeat(kv, cfg.kv_repeat, axis=1)


def attention_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                    causal: bool = True,
                    positions: Optional[jax.Array] = None,
                    kv_x: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None,
                    cache: Optional[Dict] = None,
                    cache_pos: Optional[jax.Array] = None,
                    use_rope: bool = True,
                    want_cache: bool = False,
                    cross: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """Full attention over x (self) or kv_x (cross). With `cache` (arrays-only
    dict, scan-friendly), runs one decode step: x is (B, 1, D) and k/v are
    appended at `cache_pos`."""
    B, S, D = x.shape
    src = x if kv_x is None else kv_x
    if positions is None:
        positions = jnp.arange(S)
    q = _project_q(p, x, cfg)                                 # (B,H,S,Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    q = shard(q, "data", "model", None, None)

    if cache is not None and cross:
        # cross-attention decode: kv precomputed at prefill
        out = decode_attention(q, cache["k"], cache["v"], cache_pos,
                               cfg.decode_seq_shards)
        new_cache = None
    elif cache is not None:
        # self-attention decode: append new kv, attend over the cache.
        # The cache stores the ORIGINAL kv heads (no kv_repeat): the repeat
        # only exists so training-time kv projections TP-shard; decode shards
        # the cache on the sequence dim, and GQA math needs only KH | H —
        # storing repeated heads would double cache bytes (§Perf decode).
        k_new = jnp.einsum("bsd,dhq->bhsq", x, p["wk"])
        v_new = jnp.einsum("bsd,dhq->bhsq", x, p["wv"])
        if cfg.qk_norm:
            k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
        if use_rope:
            k_new = apply_rope(k_new, positions, cfg.rope_theta)
        pos = cache_pos
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, pos, 0))
        out = decode_attention(q, k_cache, v_cache, pos + S,
                               cfg.decode_seq_shards)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        k_raw = jnp.einsum("bsd,dhq->bhsq", src, p["wk"])
        v_raw = jnp.einsum("bsd,dhq->bhsq", src, p["wv"])
        k = _kv_repeat(k_raw, cfg)
        v = _kv_repeat(v_raw, cfg)
        if cfg.qk_norm:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if use_rope:
            kp = kv_positions if kv_positions is not None else jnp.arange(src.shape[1])
            k = apply_rope(k, kp, cfg.rope_theta)
        k = shard(k, "data", "model", None, None)
        v = shard(v, "data", "model", None, None)
        out = flash_attention(q, k, v, causal=causal and kv_x is None,
                              chunk=cfg.attn_chunk)
        new_cache = {"k": k_raw, "v": v_raw} if want_cache else None
    y = jnp.einsum("bhsq,hqd->bsd", out, p["wo"])
    return shard(y, "data", None, None), new_cache


# ------------------------------------------------------------------ MLA block
def mla_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
              positions: Optional[jax.Array] = None,
              cache: Optional[Dict] = None,
              cache_pos: Optional[jax.Array] = None,
              want_cache: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head Latent Attention (deepseek-v2). Cache stores the compressed
    c_kv (r) + rope key (rope_dim) per position — the whole point of MLA."""
    m = cfg.mla
    B, S, D = x.shape
    Dh, H = cfg.head_dim_, cfg.n_heads_padded
    if positions is None:
        positions = jnp.arange(S)
    qfull = jnp.einsum("bsd,dhq->bhsq", x, p["wq"])          # (B,H,S,Dh+rope)
    q_nope, q_rope = qfull[..., :Dh], qfull[..., Dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])           # (B,S,r+rope)
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, m.kv_lora_rank:].swapaxes(1, 2),
                        positions, cfg.rope_theta)            # (B,1,S,rope)

    if cache is not None:
        pos = cache_pos
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        krope_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype), (0, pos, 0))
        # absorbed decode: q_nope' = q_nope @ w_uk  -> scores in latent space
        q_lat = jnp.einsum("bhsq,rhq->bhsr", q_nope, p["w_uk"])  # (B,H,1,r)
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)        # (B,H,1,r+rope)
        k_cat = jnp.concatenate([ckv_c, krope_c], axis=-1)[:, None]  # (B,1,L,r+rope)
        out_lat = decode_attention(q_cat, k_cat, ckv_c[:, None],
                                   pos + S, cfg.decode_seq_shards)  # (B,H,1,r)
        out = jnp.einsum("bhsr,rhq->bhsq", out_lat, p["w_uv"])
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c}
    else:
        k_nope = jnp.einsum("bsr,rhq->bhsq", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhq->bhsq", c_kv, p["w_uv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (B, H, S, m.rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = shard(q, "data", "model", None, None)
        k = shard(k, "data", "model", None, None)
        out = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        new_cache = ({"c_kv": c_kv, "k_rope": k_rope[:, 0]}
                     if want_cache else None)
    y = jnp.einsum("bhsq,hqd->bsd", out, p["wo"])
    return shard(y, "data", None, None), new_cache


def init_self_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                         stack: int = 0) -> Dict:
    """Abstract-friendly cache init (works under jax.eval_shape).
    Caches store original (unrepeated) kv heads — see attention_block."""
    Dh, KH = cfg.head_dim_, cfg.n_kv_heads
    dt = pdtype(cfg)
    shp = (batch, KH, max_len, Dh)
    if stack:
        shp = (stack,) + shp
    if cfg.mla is not None:
        m = cfg.mla
        base = (batch, max_len)
        if stack:
            base = (stack,) + base
        return {"c_kv": jnp.zeros(base + (m.kv_lora_rank,), dt),
                "k_rope": jnp.zeros(base + (m.rope_head_dim,), dt)}
    return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}
