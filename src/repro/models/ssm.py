"""Mamba selective SSM block (jamba's mixer), TPU-adapted.

Training/prefill uses an associative scan over the sequence (log-depth on the
TPU vector units); decode is the O(1) recurrent step carrying (conv window,
SSM state). Channels (d_inner) are sharded over 'model' — every op is
per-channel except the small x_proj/dt projections (row-parallel + psum).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import shard
from .config import ModelConfig
from .layers import dense_init, pdtype


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    c = cfg.mamba
    d_inner = c.expand * cfg.d_model
    dt_rank = c.dt_rank or max(1, cfg.d_model // 16)
    return d_inner, dt_rank, c.d_state


def init_mamba(key, cfg: ModelConfig) -> Dict:
    c = cfg.mamba
    Di, dtr, N = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 7)
    dt = pdtype(cfg)
    # S4-style A init: -[1..N] per channel
    a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))
    return {
        "m_in": dense_init(ks[0], (D, 2, Di), dtype=dt),
        "m_conv": dense_init(ks[1], (c.conv_width, Di), std=0.1, dtype=dt),
        "m_xproj": dense_init(ks[2], (Di, dtr + 2 * N), dtype=dt),
        "m_dt": dense_init(ks[3], (dtr, Di), std=dtr ** -0.5, dtype=dt),
        "m_dtb": jnp.full((Di,), -4.6, dt),   # softplus^-1(0.01)
        "m_alog": jnp.log(a),
        "m_d": jnp.ones((Di,), dt),
        "m_out": dense_init(ks[5], (Di, D),
                            std=0.02 / (2 * cfg.n_layers) ** 0.5, dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, S, Di), w: (W, Di).
    Returns (out, new_state (B, W-1, Di))."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+W-1, Di)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out, xp[:, -(W - 1):, :]


def apply_mamba(p: Dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict] = None,
                want_state: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, D). With `state` ({'ssm': (B,Di,N), 'conv': (B,W-1,Di)}),
    runs recurrent decode (S small, typically 1)."""
    Di, dtr, N = _dims(cfg)
    B, S, D = x.shape
    xz = jnp.einsum("bsd,dti->bsti", x, p["m_in"])       # (B,S,2,Di)
    x_in, z = xz[:, :, 0], xz[:, :, 1]
    x_in = shard(x_in, "data", None, "model")

    conv_state = state["conv"] if state is not None else None
    x_c, new_conv = _causal_conv(x_in, p["m_conv"], conv_state)
    u = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    dbc = jnp.einsum("bsi,ir->bsr", u, p["m_xproj"])     # (B,S,dtr+2N)
    dt_in, Bc, Cc = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, p["m_dt"]).astype(jnp.float32)
        + p["m_dtb"].astype(jnp.float32))                # (B,S,Di)
    A = -jnp.exp(p["m_alog"])                            # (Di,N)
    dA = jnp.exp(dt[..., None] * A)                      # (B,S,Di,N)
    dBx = (dt * u.astype(jnp.float32))[..., None] * Bc[:, :, None, :].astype(jnp.float32)

    if state is None:
        # associative scan over S: h_t = dA_t h_{t-1} + dBx_t
        def combine(a, b):
            a1, b1 = a
            a2, b2 = b
            return a1 * a2, b1 * a2 + b2
        _, h = jax.lax.associative_scan(combine, (dA, dBx.astype(dA.dtype)), axis=1)
        new_state = ({"ssm": h[:, -1], "conv": new_conv}
                     if want_state else None)
    else:
        hs = []
        h_prev = state["ssm"]
        for t in range(S):  # decode: S is 1 (or tiny)
            h_prev = dA[:, t] * h_prev + dBx[:, t]
            hs.append(h_prev)
        h = jnp.stack(hs, axis=1)
        new_state = {"ssm": h_prev, "conv": new_conv}

    y = jnp.einsum("bsin,bsn->bsi", h.astype(jnp.float32),
                   Cc.astype(jnp.float32))
    y = y + p["m_d"].astype(jnp.float32) * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, "data", None, "model")
    out = jnp.einsum("bsi,id->bsd", y, p["m_out"])
    return shard(out, "data", None, None), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, stack: int = 0) -> Dict:
    Di, _, N = _dims(cfg)
    W = cfg.mamba.conv_width
    dt = pdtype(cfg)
    s = (stack,) if stack else ()
    return {"ssm": jnp.zeros(s + (batch, Di, N), jnp.float32),
            "conv": jnp.zeros(s + (batch, W - 1, Di), dt)}
