"""Ambient mesh context.

Model code calls ``shard(x, axes...)`` for activation sharding constraints; on
a single device (smoke tests) these are no-ops, under ``use_mesh`` they become
``with_sharding_constraint`` with the ambient mesh (MaxText-style). Axis names
that don't exist on the active mesh are dropped (so the same model code runs
on (data, model) and (pod, data, model) meshes).
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE_MESH: Optional[jax.sharding.Mesh] = None

AxisName = Union[str, Sequence[str], None]


@contextlib.contextmanager
def use_mesh(mesh: Optional[jax.sharding.Mesh]):
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        if mesh is not None:
            from ..launch.mesh import activate_mesh
            with activate_mesh(mesh):
                yield mesh
        else:
            yield None
    finally:
        _ACTIVE_MESH = prev


def get_mesh() -> Optional[jax.sharding.Mesh]:
    return _ACTIVE_MESH


def axis_size(name: str) -> int:
    mesh = _ACTIVE_MESH
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _filter_axes(axes, shape=None) -> Optional[P]:
    """Drop axis names not on the active mesh; widen 'data' to ('pod','data')
    (batch-like dims span both data-parallel axes — constraining to 'data'
    alone forces XLA to reshard pod-sharded inputs, a multi-pod bug the
    dry-run exposed as per-token KV-cache collective-permutes); drop axes
    whose product doesn't divide the dim."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return None
    names = set(mesh.axis_names)

    def keep(i: int, a: AxisName):
        if a is None:
            return None
        if a == "data" or (isinstance(a, tuple) and a == ("data",)):
            a = ("pod", "data")
        if isinstance(a, str):
            a = (a,)
        kept = tuple(x for x in a if x in names)
        if not kept:
            return None
        if shape is not None:
            size = 1
            for x in kept:
                size *= mesh.shape[x]
            if shape[i] % size != 0:
                # try the suffix (e.g. batch=16 divisible by data but not
                # pod*data)
                while kept and shape[i] % size != 0:
                    size //= mesh.shape[kept[0]]
                    kept = kept[1:]
                if not kept:
                    return None
        return kept if len(kept) > 1 else kept[0]

    return P(*[keep(i, a) for i, a in enumerate(axes)])


def shard(x, *axes: AxisName):
    """Apply a sharding constraint if a mesh is active, else no-op."""
    spec = _filter_axes(axes, getattr(x, "shape", None))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def pspec(*axes: AxisName) -> P:
    """PartitionSpec filtered to the active mesh (P() when no mesh)."""
    spec = _filter_axes(axes)
    return spec if spec is not None else P()
