"""Parameter sharding rules (TP over 'model'; ZeRO over 'data'×'pod').

Rules are name-based with divisibility-aware fallback: a dim is sharded over
an axis only when evenly divisible, otherwise that dim stays replicated (small
archs like smollm/whisper simply replicate attention heads — their parameter
bytes are negligible; big archs are constructed so the TP-critical dims divide,
via head/expert padding knobs in the config).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# leaf-name -> per-dim axis *preference* for the trailing dims (leading stack
# dims from scan are always unsharded). None = replicate that dim.
_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed":   ("model", None),          # (V, D)
    "pos_embed": (None, None),
    "head":    (None, "model"),          # (D, V)
    "vision_proj": (None, None),
    # attention
    "wq":      (None, "model", None),    # (D, H, Dh)
    "wk":      (None, "model", None),    # (D, KH, Dh)
    "wv":      (None, "model", None),
    "wo":      ("model", None, None),    # (H, Dh, D)
    "w_dkv":   (None, None),             # (D, r+rope)  [MLA, small]
    "w_uk":    (None, "model", None),    # (r, H, Dh)
    "w_uv":    (None, "model", None),
    "q_norm":  (None,),
    "k_norm":  (None,),
    "kv_norm": (None,),
    # dense MLP
    "w_in":    (None, "model"),          # (D, F)
    "w_gate":  (None, "model"),
    "w_out":   ("model", None),          # (F, D)
    # MoE (leading E dim)
    "router":  (None, None),             # (D, E) small
    "e_in":    ("model", None, None),    # (E, D, Fe)
    "e_gate":  ("model", None, None),
    "e_out":   ("model", None, None),    # (E, Fe, D)
    # mamba
    "m_in":    (None, None, "model"),    # (D, 2, Di)
    "m_conv":  (None, "model"),          # (W, Di)
    "m_xproj": ("model", None),          # (Di, dtr+2N)
    "m_dt":    (None, "model"),          # (dtr, Di)
    "m_dtb":   ("model",),               # (Di,)
    "m_alog":  ("model", None),          # (Di, N)
    "m_d":     ("model",),               # (Di,)
    "m_out":   ("model", None),          # (Di, D)
    # xLSTM
    "x_up":    (None, None, "model"),    # (D, 2, Di)
    "x_q":     ("model", None, None),    # block-diag (nb, bs, bs): channel-local
    "x_k":     ("model", None, None),
    "x_v":     ("model", None, None),
    "x_if":    ("model", None),          # (Di, 2H) row -> psum
    "x_out":   ("model", None),          # (Di, D)
    "s_gates": (None, None, None),       # sLSTM small: replicate
    "s_rec":   (None, None, None, None),
    "s_out":   (None, None),
}


def _spec_for(name: str, shape: Tuple[int, ...],
              mesh: jax.sharding.Mesh) -> P:
    rule = _RULES.get(name)
    if rule is None:
        return P()  # norms, biases, anything unmatched: replicate
    ndim = len(shape)
    n_lead = ndim - len(rule)
    axes: list = [None] * ndim
    for i, pref in enumerate(rule):
        dim = n_lead + i
        if pref is not None and pref in mesh.axis_names \
                and shape[dim] % mesh.shape[pref] == 0:
            axes[dim] = pref
    return P(*axes)


def zero_extend(spec: P, shape: Tuple[int, ...], mesh: jax.sharding.Mesh,
                axes: Tuple[str, ...] = ("data", "pod")) -> P:
    """ZeRO-1: extend a param spec with data/pod sharding on the largest
    still-unsharded divisible dim (for optimizer state / master weights)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for ax in axes:
        if ax not in mesh.axis_names or mesh.shape[ax] == 1:
            continue
        cand = [(shape[i], i) for i in range(len(shape))
                if parts[i] is None and shape[i] % mesh.shape[ax] == 0]
        if not cand:
            continue
        _, best = max(cand)
        parts[best] = ax
    return P(*parts)


def param_shardings(param_shapes: Any, mesh: jax.sharding.Mesh,
                    zero: bool = False) -> Any:
    """Map a pytree of ShapeDtypeStructs to NamedShardings by leaf path."""

    def one(path, leaf) -> NamedSharding:
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", getattr(entry, "name", None))
            if isinstance(key, str):
                name = key
                break
        spec = _spec_for(name or "", leaf.shape, mesh)
        if zero:
            spec = zero_extend(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, param_shapes)
