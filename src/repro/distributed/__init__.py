"""Distribution layer: mesh context, activation sharding helpers, parameter
sharding rules with divisibility-aware fallbacks."""

from .context import axis_size, get_mesh, shard, use_mesh
from .sharding import param_shardings

__all__ = ["use_mesh", "get_mesh", "shard", "axis_size", "param_shardings"]
