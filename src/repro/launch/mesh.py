"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

Defined as functions (not module constants) so importing never touches jax
device state. Single pod: 16x16 = 256 chips (data, model); multi-pod:
2x16x16 = 512 chips (pod, data, model) — 'pod' is the slow DCI axis carrying
the outer data-parallel dimension.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """`jax.make_mesh` with explicit Auto axis types where the installed jax
    supports them (>=0.5); older versions have Auto-only meshes anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def activate_mesh(mesh: jax.sharding.Mesh):
    """Context manager making `mesh` ambient across jax versions:
    ``jax.set_mesh`` (>=0.6), ``jax.sharding.use_mesh`` (0.5), or the Mesh
    object itself (<=0.4, where Mesh is a context manager)."""
    setter = getattr(jax, "set_mesh", None) or getattr(jax.sharding, "use_mesh", None)
    return setter(mesh) if setter is not None else mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally (smoke/benchmarks: 1 CPU device)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
