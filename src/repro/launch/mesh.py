"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

Defined as functions (not module constants) so importing never touches jax
device state. Single pod: 16x16 = 256 chips (data, model); multi-pod:
2x16x16 = 512 chips (pod, data, model) — 'pod' is the slow DCI axis carrying
the outer data-parallel dimension.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally (smoke/benchmarks: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
