"""Input specs (ShapeDtypeStruct stand-ins) and sharding assignments per
(architecture × shape) dry-run cell. No device allocation happens here.

Shapes (assignment):
    train_4k     seq=4096,   global_batch=256   -> train_step
    prefill_32k  seq=32768,  global_batch=32    -> prefill (forward + caches)
    decode_32k   seq=32768,  global_batch=128   -> serve_step (1 new token)
    long_500k    seq=524288, global_batch=1     -> serve_step; sub-quadratic
                 archs only (jamba: 9 attention layers w/ seq-sharded cache +
                 O(1) mamba states; xlstm: O(1) states). Skipped for the 8
                 pure-full-attention archs (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig
from ..models.lm import init_caches, init_params

SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

SUBQUADRATIC = {"jamba-1.5-large-398b", "xlstm-1.3b"}


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""


# -------------------------------------------------------------------- helpers
def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_axes(mesh, batch: int):
    """Largest prefix of ('pod','data') that divides `batch`."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = 1
    used = []
    for a in axes:
        if batch % (total * mesh.shape[a]) == 0:
            used.append(a)
            total *= mesh.shape[a]
    return tuple(used) if used else None


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len))


def batch_specs(cfg: ModelConfig, mesh, seq: int, batch: int,
                with_labels: bool, decode: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    ba = _batch_axes(mesh, batch)
    out: Dict[str, Any] = {}
    s_text = seq
    if decode:
        # one new token; modality prefixes already live in the cache
        out["tokens"] = _sds((batch, seq), jnp.int32, mesh, P(ba, None))
        return out
    if cfg.vlm is not None:
        s_text = seq - cfg.vlm.n_patches
        out["vision_embeds"] = _sds((batch, cfg.vlm.n_patches, cfg.d_model),
                                    jnp.bfloat16, mesh, P(ba, None, None))
    if cfg.is_encdec:
        out["frames"] = _sds((batch, cfg.encdec.enc_len, cfg.d_model),
                             jnp.bfloat16, mesh, P(ba, None, None))
    out["tokens"] = _sds((batch, s_text), jnp.int32, mesh, P(ba, None))
    if with_labels:
        out["labels"] = _sds((batch, s_text), jnp.int32, mesh, P(ba, None))
    return out


# --------------------------------------------------------- cache shardings
_CACHE_RANK = {"k": 4, "v": 4, "cross_k": 4, "cross_v": 4,
               "c_kv": 3, "k_rope": 3, "ssm": 3, "conv": 3,
               "C": 4, "n": 3, "m": 2,
               "sc": 3, "sn": 3, "sh": 3, "sm": 3}
# per-dim axes from the END of the array (after the batch dim)
_CACHE_TAIL = {"k": (None, "model", None), "v": (None, "model", None),
               "cross_k": (None, "model", None), "cross_v": (None, "model", None),
               "c_kv": ("model", None), "k_rope": ("model", None),
               "ssm": ("model", None), "conv": (None, "model"),
               "C": (None, None, "model"), "n": (None, None), "m": (None,),
               "sc": (None, None), "sn": (None, None),
               "sh": (None, None), "sm": (None, None)}


def cache_shardings(cache_shapes: Any, mesh, batch: int) -> Any:
    ba = _batch_axes(mesh, batch)

    def one(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", getattr(entry, "name", None))
            if isinstance(key, str):
                name = key
                break
        rank = _CACHE_RANK.get(name)
        if rank is None:
            return NamedSharding(mesh, P())
        tail = _CACHE_TAIL[name]
        ndim = leaf.ndim
        axes = [None] * ndim
        axes[ndim - rank] = ba          # batch dim
        for i, a in enumerate(tail):
            dim = ndim - len(tail) + i
            if a is not None and a in mesh.axis_names \
                    and leaf.shape[dim] % mesh.shape[a] == 0:
                axes[dim] = a
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def sds_with(shapes: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda sh, s: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=s),
        shapes, shardings)
