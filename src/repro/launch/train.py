"""End-to-end training driver: streaming-log data plane -> JAX train loop.

The full production story on one box: documents are ingested into an AgileLog
topic; the training job consumes exactly-resumable host-sharded batches; a
synthetic-data agent can inject validated curriculum via a promotable cFork;
checkpoints (params + optimizer + data cursor) commit atomically to the same
object store; crash/restart resumes the identical batch stream.

Usage (CPU-scale, examples/train_e2e.py wraps this):
    PYTHONPATH=src python -m repro.launch.train --steps 200 --d-model 256
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import BoltSystem
from ..data import LogDataPipeline, TokenStreamWriter, synthetic_token_docs
from ..models.config import ModelConfig
from ..models.lm import init_params
from ..streams import Topic
from ..train.checkpoint import CheckpointManager
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.step import make_train_step


def small_config(d_model: int, n_layers: int, vocab: int) -> ModelConfig:
    return ModelConfig(
        name=f"train-e2e-{d_model}", n_layers=n_layers, d_model=d_model,
        n_heads=max(2, d_model // 64), n_kv_heads=max(1, d_model // 128),
        d_ff=d_model * 4, vocab_size=vocab, tie_embeddings=True,
        remat="none", attn_chunk=128)


def run(steps: int = 100, d_model: int = 128, n_layers: int = 4,
        batch: int = 4, seq: int = 128, vocab: int = 2048,
        resume: bool = False, store=None, system=None, log_every: int = 20,
        ckpt_every: int = 50, seed: int = 0):
    cfg = small_config(d_model, n_layers, vocab)
    total, _ = cfg.count_params()
    print(f"model: {cfg.name} ({total/1e6:.1f}M params)")

    # ---- data plane: the forkable shared log --------------------------------
    # The log is a durable shared SERVICE; the training job is a client.
    # Crash/resume means the job re-attaches to the same BoltSystem (pass
    # `system=`), finds its token stream and checkpoint catalog by name, and
    # resumes — checkpoints are log forks now (DESIGN.md §17), so their
    # lineage lives in the log's metadata, not in ad-hoc store keys.
    system = system if system is not None else BoltSystem(n_brokers=4,
                                                          store=store)
    existing = system.find_log("train-tokens")
    if existing is None:
        topic = Topic.create(system, "train-tokens")
        writer = TokenStreamWriter(topic, batch_docs=64)
        for doc in synthetic_token_docs(4000, vocab=vocab, min_len=64,
                                        max_len=512, seed=seed):
            writer.write_doc(doc)
        writer.flush()
    else:
        topic = Topic("train-tokens", existing)
    pipe = LogDataPipeline(topic, batch_size=batch, seq_len=seq)

    # ---- model + optimizer ----------------------------------------------------
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)
    ckpt = CheckpointManager(system, prefix="ckpt")
    start_step = 0
    if resume and ckpt.latest_step() is not None:
        orphans = ckpt.recover()    # reclaim forks a crashed save left behind
        if orphans:
            print(f"recovered {len(orphans)} orphaned checkpoint fork(s)")
        start_step, params, opt_state, extra = ckpt.restore()
        pipe.restore(tuple(extra["cursor"]))
        print(f"resumed from step {start_step}, cursor {extra['cursor']}")
    else:
        params = init_params(cfg, jax.random.key(seed))
        opt_state = adamw_init(params, opt_cfg)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum=1),
                      donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        block = next(pipe)
        batch_dict = {"tokens": jnp.asarray(block[:, :-1]),
                      "labels": jnp.asarray(block[:, 1:])}
        params, opt_state, metrics = step_fn(params, opt_state, batch_dict)
        losses.append(float(metrics["loss"]))
        if (step + 1) % log_every == 0:
            tput = batch * seq * log_every / (time.time() - t0)
            print(f"step {step + 1:5d} loss {np.mean(losses[-log_every:]):.4f} "
                  f"({tput:.0f} tok/s)")
            t0 = time.time()
        if (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state,
                      extra={"cursor": list(pipe.cursor())})
    return losses, params, system


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    losses, _, _ = run(steps=args.steps, d_model=args.d_model,
                       n_layers=args.layers, batch=args.batch, seq=args.seq,
                       resume=args.resume)
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
