import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles every (architecture × shape) cell against the production
mesh — 16x16 single pod and 2x16x16 multi-pod — and extracts the roofline
terms from the compiled artifact:

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = modeled link-bytes (per collective op, ring formulas) / ICI_bw

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --out results/
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, get_config  # noqa: E402
from ..distributed.context import use_mesh  # noqa: E402
from ..distributed.sharding import param_shardings  # noqa: E402
from ..models.lm import decode_step, forward  # noqa: E402
from ..train.optimizer import (AdamWConfig, adamw_init,  # noqa: E402
                               opt_state_shardings)
from ..train.step import make_train_step  # noqa: E402
from .hlo_cost import analyze  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import (SHAPES, abstract_caches, abstract_params,  # noqa: E402
                    batch_specs, cache_shardings, cell_supported, sds_with)

# ---- TPU v5e hardware constants (assignment §ROOFLINE) ----
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

def model_flops(cfg, shape_name: str) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (per step)."""
    seq, batch, kind = SHAPES[shape_name]
    _total, active = cfg.count_params()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * active * tokens
    return 2.0 * active * batch  # decode: one token per sequence


def build_cell(cfg, shape_name: str, mesh, accum: int):
    """Returns (fn, arg_sds) for the cell's step function."""
    seq, batch, kind = SHAPES[shape_name]
    p_shapes = abstract_params(cfg)
    p_shard = param_shardings(p_shapes, mesh)
    p_sds = sds_with(p_shapes, p_shard)

    if kind == "train":
        opt_cfg = AdamWConfig(
            moments_dtype="bfloat16" if cfg.count_params()[0] > 2e11 else "float32")
        o_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), p_shapes)
        o_shard = opt_state_shardings(p_shapes, mesh, opt_cfg)
        o_sds = sds_with(o_shapes, o_shard)
        b_sds = batch_specs(cfg, mesh, seq, batch, with_labels=True)
        step = make_train_step(cfg, opt_cfg, accum=accum)
        return jax.jit(step, donate_argnums=(0, 1)), (p_sds, o_sds, b_sds)

    if kind == "prefill":
        b_sds = batch_specs(cfg, mesh, seq, batch, with_labels=False)

        def prefill(params, batch):
            logits, _aux, caches, _ = forward(cfg, params, batch,
                                              want_caches=True)
            return logits[:, -1:], caches

        return jax.jit(prefill), (p_sds, b_sds)

    # decode
    c_shapes = abstract_caches(cfg, batch, seq)
    c_shard = cache_shardings(c_shapes, mesh, batch)
    c_sds = sds_with(c_shapes, c_shard)
    tok = batch_specs(cfg, mesh, 1, batch, with_labels=False,
                      decode=True)["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = partial(decode_step, cfg)
    return jax.jit(step, donate_argnums=(1,)), (p_sds, c_sds, tok, pos)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict) -> dict:
    ok, why = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch).with_(
        decode_seq_shards=mesh.shape["model"],
        **{k: v for k, v in overrides.items() if k in
           ("attn_chunk", "remat", "moe_dispatch") and v is not None})
    accum = overrides.get("accum") or default_accum(arch)
    t0 = time.time()
    with use_mesh(mesh):
        fn, args = build_cell(cfg, shape_name, mesh, accum)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
    # trip-count-aware cost model (XLA's cost_analysis counts scan bodies
    # once; see hlo_cost.py) — all values are per device. The roofline terms
    # use TPU-dtype-corrected accounting (CPU legalizes bf16 to f32; those
    # buffers/collectives do not exist on the TPU target); raw CPU-HLO
    # numbers are kept alongside.
    cost = analyze(hlo, tpu_dtype_correction=True)
    cost_raw = analyze(hlo)
    flops_dev = cost.flops
    bytes_dev = cost.bytes
    coll_bytes_dev = cost.collective_bytes
    n_dev = mesh.size
    mf = model_flops(cfg, shape_name)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_bytes_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "accum": accum,
        "remat": cfg.remat,
        "attn_chunk": cfg.attn_chunk,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "devices": n_dev,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      + ma.output_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": bytes_dev,
        "raw_cpu_hlo": {"hbm_bytes": cost_raw.bytes,
                        "collective_bytes": cost_raw.collective_bytes},
        "collectives": {k: {"count": v[0], "link_bytes": v[1]}
                        for k, v in sorted(cost.coll.items())},
        "collective_bytes_per_device": coll_bytes_dev,
        "roofline": terms,
        "dominant": dominant,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops_dev if flops_dev else 0.0,
    }
    return result


def default_accum(arch: str) -> int:
    big = {"deepseek-67b": 8, "jamba-1.5-large-398b": 8,
           "llava-next-34b": 8, "starcoder2-15b": 8, "qwen3-8b": 4,
           "deepseek-v2-lite-16b": 4}
    return big.get(arch, 1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--moe-dispatch", dest="moe_dispatch", default=None)
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = {"accum": args.accum, "attn_chunk": args.attn_chunk,
                 "remat": args.remat, "moe_dispatch": args.moe_dispatch}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                res = run_cell(arch, shape, mp, overrides)
                line = json.dumps(res)
                print(line, flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    mesh_tag = "2x16x16" if mp else "16x16"
                    fn = f"{args.out}/{arch}__{shape}__{mesh_tag}__{args.tag}.json"
                    with open(fn, "w") as f:
                        f.write(line)


if __name__ == "__main__":
    main()
