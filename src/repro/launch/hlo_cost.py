"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
useless for scanned layer stacks (the whole point of scan-over-groups). This
analyzer parses the HLO module, memoizes per-computation costs, and multiplies
``while`` bodies by their trip counts (read from the loop-condition's compare
bound), giving:

  * flops            — dot-general flops (2*M*N*K, batched), trip-aware;
  * bytes            — HBM-traffic proxy: operand+result bytes of top-level
                       (post-fusion) instructions; dynamic-update-slice counts
                       the update slice only (in-place);
  * collective bytes — per-device link bytes per collective kind with ring
                       coefficients (all-reduce 2x, others 1x), trip-aware.

Elementwise flops are ignored (dot-dominated workloads; noted in
EXPERIMENTS.md). Validated against hand-computed cases in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_HEAD_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\(.*?\)|[\w\[\],]+(?:\{[\d,]*\})?))\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s->\s.+\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")

_COLL_COEF = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0,
              "ragged-all-to-all": 1.0}


def _parse_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


_F32_AS_BF16 = False  # module switch set by HloCostModel (TPU dtype correction)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_dims(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        nbytes = _DTYPE_BYTES[dt]
        if _F32_AS_BF16 and dt == "f32":
            nbytes = 2
        total += n * nbytes
    return total


def _split_operands(args: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in args:
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            depth += ch in "({["
            depth -= ch in ")}]"
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [o for o in out if o]


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclass
class _Computation:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, List[float]] = field(default_factory=dict)  # kind -> [count, link_bytes]

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k, (c, b) in other.coll.items():
            e = self.coll.setdefault(k, [0.0, 0.0])
            e[0] += c * times
            e[1] += b * times

    @property
    def collective_bytes(self) -> float:
        return sum(b for _c, b in self.coll.values())


class HloCostModel:
    """`tpu_dtype_correction` models the TPU-target dtypes: the CPU backend
    legalizes bf16 compute to f32 (phantom converts/buffers that do not exist
    on TPU), and donated buffers get entry copies that TPU aliases. With the
    flag: f32 buffers count at bf16 width and copies are free. Genuinely-f32
    state (optimizer moments, flash accumulators) is then undercounted 2x —
    a small share, noted in EXPERIMENTS.md."""

    def __init__(self, hlo_text: str, tpu_dtype_correction: bool = False) -> None:
        self.computations: Dict[str, _Computation] = {}
        self.entry: Optional[str] = None
        self.tpu_corr = tpu_dtype_correction
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str) -> None:
        cur: Optional[_Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_RE.match(line)
                if m:
                    cur = _Computation(m.group(1))
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = m.group(1)
                continue
            if line.strip() == "}":
                self.computations[cur.name] = cur
                cur = None
                continue
            m = _INSTR_HEAD_RE.match(line)
            if m:
                name, tstr, opcode = m.groups()
                # balance-scan the operand list (attrs may contain parens
                # inside quoted metadata)
                start = m.end()
                depth, i = 1, start
                while i < len(line) and depth:
                    depth += line[i] == "("
                    depth -= line[i] == ")"
                    i += 1
                args = line[start:i - 1]
                attrs = line[i:]
                ins = _Instr(name, tstr, opcode, _split_operands(args), attrs)
                cur.instrs.append(ins)
                cur.shapes[name] = tstr

    # ------------------------------------------------------------------ cost
    def _operand_shape(self, comp: _Computation, operand: str) -> str:
        if "[" in operand:
            return operand   # older HLO prints typed operands: "f32[2,3]{1,0} %x"
        name = operand.lstrip("%")
        return comp.shapes.get(name, "")

    def _trip_count(self, cond_name: str) -> int:
        seen, stack, best = set(), [cond_name], 1
        while stack:
            cn = stack.pop()
            if cn in seen or cn not in self.computations:
                continue
            seen.add(cn)
            comp = self.computations[cn]
            for ins in comp.instrs:
                if ins.opcode == "constant":
                    mm = _CONST_RE.search(f"= {ins.type_str} constant({ins.operands[0] if ins.operands else ''})")
                    # simpler: match on the raw type/operand
                    if ins.type_str == "s32[]" and ins.operands:
                        try:
                            best = max(best, int(ins.operands[0]))
                        except ValueError:
                            pass
                cm = _CALLS_RE.search(ins.attrs)
                if cm:
                    stack.append(cm.group(1))
        return best

    def comp_cost(self, name: str, count_bytes: bool = True) -> Cost:
        """count_bytes=False inside fused computations: a fusion's traffic is
        its boundary I/O; internal ops only contribute flops/collectives."""
        key = (name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        comp = self.computations.get(name)
        if comp is None:
            return self._memo[key]
        total = Cost()
        for ins in comp.instrs:
            total.add(self._instr_cost(comp, ins, count_bytes))
        self._memo[key] = total
        return total

    def _instr_cost(self, comp: _Computation, ins: _Instr,
                    count_bytes: bool = True) -> Cost:
        c = Cost()
        op = ins.opcode
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id"):
            return c
        if op == "copy":
            # loop-carried copies are CPU-HLO artifacts (TPU aliases them);
            # only entry-level staging copies count, and none at all under
            # TPU dtype correction (donation aliases them)
            if count_bytes and comp.name == self.entry and not self.tpu_corr:
                c.bytes += self._io_bytes(comp, ins)
            return c
        if op == "while":
            m = _COND_BODY_RE.search(ins.attrs)
            if m:
                trips = self._trip_count(m.group(1))
                c.add(self.comp_cost(m.group(2), count_bytes), times=trips)
                c.add(self.comp_cost(m.group(1), count_bytes), times=trips)
            return c
        if op in ("call", "fusion", "async-start"):
            m = _CALLS_RE.search(ins.attrs)
            sub = Cost()
            if m:
                # internals: flops + collectives only
                sub = self.comp_cost(m.group(1), count_bytes=False)
            c.add(sub)
            if count_bytes:
                # traffic: fusion boundary = result + effective operand reads.
                # An operand consumed only through dynamic-slice/gather reads a
                # slice; a dynamic-update-slice root writes (and aliases) only
                # the update window, not the whole carried buffer.
                result_bytes = float(_type_bytes(ins.type_str))
                eff = {}
                if m:
                    eff, dus_bytes = self._fusion_effective_io(
                        m.group(1), ins.type_str)
                    if dus_bytes is not None:
                        result_bytes = float(dus_bytes)
                c.bytes += result_bytes
                for i, o in enumerate(ins.operands):
                    full = (_type_bytes(self._operand_shape(comp, o))
                            if (o.startswith("%") or re.match(r"^[\w.\-]+$", o))
                            else _type_bytes(o))
                    c.bytes += float(min(full, eff.get(i, full))
                                     if i in eff else full)
            return c
        if op == "conditional":
            for m in re.finditer(r"%?([\w.\-]+)", ins.attrs):
                if m.group(1) in self.computations:
                    c.add(self.comp_cost(m.group(1), count_bytes))
            if count_bytes:
                c.bytes += self._io_bytes(comp, ins)
            return c
        if op == "dot":
            out_elems = 1
            for _dt, dims in _parse_dims(ins.type_str):
                for d in dims:
                    out_elems *= d
            k = 1
            mdim = _DIMS_RE.search(ins.attrs)
            lhs_shape = _parse_dims(self._operand_shape(comp, ins.operands[0]))
            if mdim and lhs_shape:
                dims = lhs_shape[0][1]
                for i in [int(x) for x in mdim.group(1).split(",") if x]:
                    if i < len(dims):
                        k *= dims[i]
            c.flops += 2.0 * out_elems * k
            if count_bytes:
                c.bytes += self._io_bytes(comp, ins)
            return c
        base = op.replace("-start", "")
        if base in _COLL_COEF:
            b = _type_bytes(ins.type_str) * _COLL_COEF[base]
            e = c.coll.setdefault(base, [0.0, 0.0])
            e[0] += 1
            e[1] += b
            if count_bytes:
                c.bytes += self._io_bytes(comp, ins)
            return c
        if op == "dynamic-update-slice":
            if count_bytes and len(ins.operands) > 1:
                upd = self._operand_shape(comp, ins.operands[1])
                c.bytes += 2.0 * _type_bytes(upd)
            return c
        if op in ("dynamic-slice", "gather", "slice"):
            # reads only the sliced window, not the source buffer (scan over
            # stacked params would otherwise count the whole stack per trip)
            if count_bytes:
                c.bytes += 2.0 * _type_bytes(ins.type_str)
            return c
        if op == "scatter":
            if count_bytes:
                c.bytes += 3.0 * _type_bytes(ins.type_str)
            return c
        if op in ("all-reduce-done", "all-gather-done", "async-done",
                  "collective-permute-done", "copy-done"):
            return c
        # generic instruction: operands + result traffic
        if count_bytes:
            c.bytes += self._io_bytes(comp, ins)
        return c

    def _fusion_effective_io(self, comp_name: str, result_type: str):
        """(per-parameter effective read bytes, root-DUS write bytes or None).

        Traces through view/convert chains (the CPU backend legalizes bf16 by
        wrapping ops in converts; on TPU those don't exist):
          * a parameter consumed only through slicing ops reads slice bytes;
          * a parameter that is the in-place target of a result-shaped
            dynamic-update-slice is aliased (reads ~nothing);
          * if the fusion produces a result-shaped DUS, write traffic is the
            update window, not the whole carried buffer.
        """
        comp = self.computations.get(comp_name)
        if comp is None:
            return {}, None
        users: Dict[str, List[_Instr]] = {}
        for ins in comp.instrs:
            for o in ins.operands:
                users.setdefault(o.lstrip("%"), []).append(ins)
        res_dims = [d for _t, d in _parse_dims(result_type)][:1]
        dus_bytes = None
        for ins in comp.instrs:
            if ins.opcode == "dynamic-update-slice":
                d = [x for _t, x in _parse_dims(ins.type_str)][:1]
                if d == res_dims and len(ins.operands) > 1:
                    upd = comp.shapes.get(ins.operands[1].lstrip("%"), "")
                    dus_bytes = (dus_bytes or 0) + _type_bytes(upd)

        _VIEW = ("convert", "bitcast", "copy", "reshape", "transpose",
                 "broadcast")
        _SLICE = ("dynamic-slice", "slice", "gather")

        def effective(pname: str):
            total, stack, seen = 0, [pname], set()
            while stack:
                nm = stack.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                for u in users.get(nm, []):
                    if u.opcode in _VIEW:
                        stack.append(u.name)
                    elif u.opcode in _SLICE:
                        total += _type_bytes(u.type_str)
                    elif (u.opcode == "dynamic-update-slice"
                          and u.operands
                          and u.operands[0].lstrip("%") == nm):
                        pass  # aliased in-place carry target
                    else:
                        return None  # consumed at full size somewhere
            return total

        out: Dict[int, int] = {}
        for ins in comp.instrs:
            if ins.opcode != "parameter":
                continue
            try:
                idx = int(ins.operands[0])
            except (ValueError, IndexError):
                continue
            r = effective(ins.name)
            if r is not None:
                out[idx] = r
        return out, dus_bytes

    def _io_bytes(self, comp: _Computation, ins: _Instr) -> float:
        b = float(_type_bytes(ins.type_str))
        for o in ins.operands:
            if o.startswith("%") or re.match(r"^[\w.\-]+$", o):
                b += _type_bytes(self._operand_shape(comp, o))
            else:
                b += _type_bytes(o)
        return b

    def total(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        global _F32_AS_BF16
        prev = _F32_AS_BF16
        _F32_AS_BF16 = self.tpu_corr
        try:
            return self.comp_cost(self.entry)
        finally:
            _F32_AS_BF16 = prev


def analyze(hlo_text: str, tpu_dtype_correction: bool = False) -> Cost:
    return HloCostModel(hlo_text, tpu_dtype_correction).total()
