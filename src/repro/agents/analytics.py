"""Ad-hoc IoT analytics agent (§6.8, Figure 12) — works on an sFork.

Task: "look for anomalies in the first N records". The replayed plan:
  1. probe: sample records to infer the schema,
  2. fan out parallel investigations (per-metric scans: range stats,
     spike detection, status correlation),
  3. correlate anomalies across metrics and report.

Each investigation issues bulk reads against the fork — the load pattern the
isolation benchmark measures. The agent never touches the root log.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..streams.records import decode_record
from ..streams.topics import Topic


@dataclass
class Investigation:
    name: str
    reads: int = 0
    findings: List[str] = field(default_factory=list)


class AnalyticsAgent:
    def __init__(self, topic: Topic, scan_limit: int = 1_000_000,
                 chunk: int = 4096) -> None:
        self.source = topic
        self.scan_limit = scan_limit
        self.chunk = chunk
        self.fork: Optional[Topic] = None
        self.investigations: List[Investigation] = []
        self.tool_calls: List[str] = []

    # -- tools -------------------------------------------------------------------
    def _tool_read(self, lo: int, hi: int) -> List[dict]:
        self.tool_calls.append(f"read[{lo}:{hi})")
        raw = self.fork.log.read(lo, hi)
        return [decode_record(b) for b in raw]

    # -- the replayed plan ----------------------------------------------------------
    def run(self) -> Dict[str, object]:
        # step 0: isolate on a severed fork (point-in-time task: sFork suffices)
        self.fork = self.source.sfork(dedicated=True)
        self.tool_calls.append("sfork")
        n = min(self.scan_limit, self.fork.tail)

        # step 1: probe schema from a sample
        sample = self._tool_read(0, min(16, n))
        metrics = sorted({k for r in sample for k in r
                          if isinstance(r[k], (int, float)) and k != "ts"})

        # step 2: parallel investigations (one scan per metric + status scan)
        stats: Dict[str, List[float]] = {m: [] for m in metrics}
        spikes: Dict[str, List[int]] = {m: [] for m in metrics}
        running: Dict[str, tuple] = {m: (0.0, 0.0, 0) for m in metrics}  # sum, sumsq, k
        invs = {m: Investigation(f"scan:{m}") for m in metrics}
        status_inv = Investigation("scan:status")
        self.investigations = list(invs.values()) + [status_inv]
        bad_status_at: List[int] = []

        for lo in range(0, n, self.chunk):
            hi = min(lo + self.chunk, n)
            recs = self._tool_read(lo, hi)
            for m in metrics:
                invs[m].reads += 1
                s, s2, k = running[m]
                for i, r in enumerate(recs):
                    v = r.get(m)
                    if v is None:
                        continue
                    if k > 32:
                        mean = s / k
                        var = max(s2 / k - mean * mean, 1e-12)
                        if abs(v - mean) > 6 * var ** 0.5:
                            spikes[m].append(lo + i)
                            invs[m].findings.append(
                                f"spike {m}={v:.3g} at {lo + i} (mean {mean:.3g})")
                    s += v
                    s2 += v * v
                    k += 1
                running[m] = (s, s2, k)
            status_inv.reads += 1
            for i, r in enumerate(recs):
                if r.get("status") not in (None, "ok"):
                    bad_status_at.append(lo + i)

        # step 3: correlate spikes with status anomalies
        correlated = []
        bad = set(bad_status_at)
        for m in metrics:
            for pos in spikes[m]:
                near = [b for b in bad if abs(b - pos) <= 2]
                if near:
                    correlated.append((m, pos, sorted(near)))
        return {
            "metrics": metrics,
            "spikes": {m: v for m, v in spikes.items() if v},
            "bad_status_positions": bad_status_at,
            "correlated": correlated,
            "tool_calls": len(self.tool_calls),
        }

    def cleanup(self) -> None:
        if self.fork is not None:
            self.fork.log.squash()
            self.fork = None
