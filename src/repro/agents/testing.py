"""Stream-processor testing agent (§6.8, Figure 13) — non-promotable cForks.

The agent tests a tumbling-window StreamProcessor under corner cases (late,
malformed, duplicate records) by injecting test events into cForks of the
production stream — so every test sees *real* data with the synthetic events
linearizably interleaved — then running a processor copy on the fork and
collecting failures. Each test case = one cFork, run, squash.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List

from ..streams.topics import StreamProcessor, Topic


@dataclass
class TestReport:
    name: str
    injected: int
    crashed: bool
    error: str = ""
    windows: int = 0
    notes: List[str] = field(default_factory=list)


class StreamTestingAgent:
    def __init__(self, topic: Topic, window_ms: float = 5.0) -> None:
        self.source = topic
        self.window_ms = window_ms
        self.reports: List[TestReport] = []

    # -- the test-case tool (create cFork, inject, run processor, squash) -------
    def _run_case(self, name: str, inject: Callable[[Topic], int]) -> TestReport:
        fork = self.source.cfork(promotable=False)
        injected = inject(fork)
        report = TestReport(name, injected, crashed=False)
        proc = StreamProcessor(fork, window_ms=self.window_ms)
        try:
            proc.run_to_tail()
            report.windows = len(proc.results)
        except Exception as e:
            report.crashed = True
            report.error = f"{type(e).__name__}: {e}"
        finally:
            fork.log.squash()
        self.reports.append(report)
        return report

    # -- recorded test plan ------------------------------------------------------
    def run(self) -> Dict[str, object]:
        from ..streams.records import encode_record

        def inject_late(fork: Topic) -> int:
            # events with timestamps far in the past (straggler window)
            for i in range(8):
                fork.log.append(encode_record({"ts": 0.0 + i * 0.1, "value": 1.0}))
            return 8

        def inject_malformed(fork: Topic) -> int:
            fork.log.append(encode_record({"ts": "not-a-number", "value": 1.0}))
            fork.log.append(encode_record({"value": 2.0}))           # missing ts
            fork.log.append(encode_record({"ts": 1.0, "value": "NaN?"}))
            return 3

        def inject_duplicates(fork: Topic) -> int:
            for _ in range(5):
                fork.log.append(encode_record({"ts": 3.0, "value": 7.0, "key": "dup"}))
            return 5

        def inject_schema_evolution(fork: Topic) -> int:
            fork.log.append(encode_record(
                {"ts": 4.0, "value": 1.0, "new_field": {"nested": True}}))
            return 1

        self._run_case("late-records", inject_late)
        self._run_case("malformed-records", inject_malformed)
        self._run_case("duplicate-records", inject_duplicates)
        self._run_case("schema-evolution", inject_schema_evolution)
        return {
            "cases": len(self.reports),
            "bugs_found": [r.name for r in self.reports if r.crashed],
            "reports": self.reports,
        }
