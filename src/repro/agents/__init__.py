"""The paper's three agentic applications (§6.8), as deterministic agents.

The paper captures one LLM run and replays the trace for determinism; we do
the same one step further — the 'LLM plan' is a recorded decision sequence,
and the *system-side* tool calls (read / fork / inject / run-processor /
promote / squash) are fully real against Bolt.
"""

from .analytics import AnalyticsAgent
from .testing import StreamTestingAgent
from .supplychain import SupplyChainAgent

__all__ = ["AnalyticsAgent", "StreamTestingAgent", "SupplyChainAgent"]
