"""Supply-chain restocking agent (§6.8, Figure 14) — speculative commit.

The stream carries `order` events from non-agentic producers; the agent
evaluates demand and proactively writes `restock` events. In safe mode it
opens a *speculation session* (DESIGN.md §12) — a promotable cFork under the
hood — validates by running a stateful copy of the downstream inventory
consumer on the speculative fork (which contains previous records AND live
non-agentic orders linearizably interleaved with the agent's writes — the
stateful-validation story of §4.1), then `commit()`s or `abort()`s; a commit
that races a concurrent producer auto-rebases, re-validating the delta via
the session's `on_rebase` hook. In direct mode (the Kafka-style baseline) it
writes straight to the main stream.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core.errors import ConflictError
from ..streams.records import decode_record, encode_record
from ..streams.topics import Topic


class InventoryConsumer:
    """Downstream stateful application: tracks per-item inventory.
    Deliberately strict about schema (crashes on malformed events)."""

    def __init__(self, initial: Optional[Dict[str, int]] = None) -> None:
        self.inventory: Dict[str, int] = dict(initial or {})
        self.offset = 0
        self.processed = 0
        self.crashed = False

    def process(self, topic: Topic, upto: Optional[int] = None) -> int:
        hi = topic.log.visible_tail if upto is None else upto
        if hi <= self.offset:
            return 0
        n = 0
        for raw in topic.log.read(self.offset, hi):
            rec = decode_record(raw)
            kind = rec["kind"]              # KeyError on malformed -> crash
            item = rec["item"]
            qty = int(rec["qty"])           # ValueError on bad qty  -> crash
            if kind == "order":
                self.inventory[item] = self.inventory.get(item, 0) - qty
            elif kind == "restock":
                self.inventory[item] = self.inventory.get(item, 0) + qty
            else:
                raise ValueError(f"unknown event kind {kind!r}")
            n += 1
        self.offset = hi
        self.processed += n
        return n

    def snapshot(self) -> "InventoryConsumer":
        c = InventoryConsumer(self.inventory)
        c.offset = self.offset
        return c


@dataclass
class RestockDecision:
    item: str
    qty: int


class SupplyChainAgent:
    def __init__(self, topic: Topic, inject_mistake: bool = False) -> None:
        self.topic = topic
        self.inject_mistake = inject_mistake
        self.promotes = 0
        self.squashes = 0

    # -- the 'LLM' plan: demand heuristic over recent history --------------------
    def decide(self, lookback: int = 256) -> List[RestockDecision]:
        tail = self.topic.log.visible_tail
        lo = max(0, tail - lookback)
        demand: Dict[str, int] = {}
        for raw in self.topic.log.read(lo, tail):
            rec = decode_record(raw)
            if rec.get("kind") == "order":
                demand[rec["item"]] = demand.get(rec["item"], 0) + int(rec["qty"])
        return [RestockDecision(item, qty * 2) for item, qty in
                sorted(demand.items()) if qty > 4]

    def _restock_events(self, decisions: List[RestockDecision]) -> List[bytes]:
        events = []
        for i, d in enumerate(decisions):
            rec = {"kind": "restock", "item": d.item, "qty": d.qty}
            if self.inject_mistake and i == 0:
                rec = {"kind": "restock", "item": d.item, "quantity": d.qty}  # schema error
            events.append(encode_record(rec))
        return events

    # -- safe mode: speculation session (validate -> commit/abort, §12) -----------
    def _validates(self, validator_state: InventoryConsumer,
                   fork_topic: Topic) -> bool:
        """Stateful validation: run a COPY of the downstream consumer on the
        speculative fork — it sees history + live orders + agent writes,
        linearizably interleaved."""
        probe = validator_state.snapshot()
        try:
            probe.process(fork_topic)
            # the replay must not crash AND the restocked inventory must not
            # end negative — the business invariant safe mode exists to hold
            return all(v >= 0 for v in probe.inventory.values())
        except Exception:
            return False

    def run_safe(self, validator_state: InventoryConsumer) -> bool:
        decisions = self.decide()
        if not decisions:
            return False

        def revalidate(spec, lo, hi):
            # a producer raced the commit: the rebase replayed our restocks;
            # re-run the downstream probe over the rebased fork before the
            # retried promote (delta [lo, hi) now sits below the fork point)
            return self._validates(
                validator_state,
                Topic(f"{self.topic.name}/speculate", spec.log,
                      self.topic.registry))

        with self.topic.speculate(on_rebase=revalidate) as s:
            s.append_batch(self._restock_events(decisions))
            valid = self._validates(
                validator_state,
                Topic(f"{self.topic.name}/speculate", s.log, self.topic.registry))
            if valid:
                try:
                    s.commit()
                    self.promotes += 1
                except ConflictError:
                    # rebase budget exhausted or revalidation vetoed the
                    # rebased state: the session already squashed itself
                    self.squashes += 1
                    valid = False
            else:
                s.abort()
                self.squashes += 1
        return valid

    # -- direct mode (Kafka baseline): write straight to the main stream ---------
    def run_direct(self) -> int:
        decisions = self.decide()
        events = self._restock_events(decisions)
        for ev in events:
            self.topic.log.append(ev)
        return len(events)
