"""Training data plane: token streams ingested into the shared log, consumed
as deterministic, exactly-resumable, host-sharded batches."""

from .pipeline import LogDataPipeline, TokenStreamWriter, synthetic_token_docs

__all__ = ["LogDataPipeline", "TokenStreamWriter", "synthetic_token_docs"]
