"""Log-backed training data pipeline.

Documents (token sequences) are ingested into an AgileLog topic; training jobs
consume fixed-shape ``(batch, seq_len)`` batches. Because the log is totally
ordered and append-only, the pair ``(log position, intra-record offset)`` is an
exact resume cursor: checkpoint it and a restarted (or re-sharded, for elastic
scaling) job reproduces the identical batch sequence.

Host sharding: host ``h`` of ``H`` reads records ``pos % H == h`` — disjoint,
deterministic, no coordination. Data-quality / synthetic-data agents operate on
cForks of the same topic and `promote` validated mixtures (DESIGN.md §2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from ..streams.topics import Topic


class TokenStreamWriter:
    """Ingests token documents into the log (one record per document)."""

    def __init__(self, topic: Topic, batch_docs: int = 64) -> None:
        self.topic = topic
        self.batch_docs = batch_docs
        self._buf: List[bytes] = []

    def write_doc(self, tokens: np.ndarray) -> None:
        self._buf.append(np.asarray(tokens, dtype=np.int32).tobytes())
        if len(self._buf) >= self.batch_docs:
            self.flush()

    def flush(self) -> None:
        if self._buf:
            self.topic.log.append_batch(self._buf)
            self._buf.clear()


@dataclass
class PipelineCursor:
    position: int = 0        # next log position to read
    carry_tokens: int = 0    # tokens already consumed from the carry buffer


class LogDataPipeline:
    """Packs documents from the log into fixed (batch, seq_len+1) token blocks
    (inputs = [:, :-1], labels = [:, 1:]). Deterministic and exactly resumable
    via `cursor()` / `restore()`."""

    def __init__(self, topic: Topic, batch_size: int, seq_len: int,
                 host_id: int = 0, num_hosts: int = 1,
                 bos_token: int = 1) -> None:
        assert 0 <= host_id < num_hosts
        self.topic = topic
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.bos = bos_token
        self._cursor = PipelineCursor()
        self._carry = np.zeros((0,), dtype=np.int32)

    # -- resume support ------------------------------------------------------------
    def cursor(self) -> Tuple[int, int]:
        return (self._cursor.position, self._cursor.carry_tokens)

    def restore(self, cursor: Tuple[int, int]) -> None:
        """Re-derive state deterministically: re-read the record the carry came
        from (the previous host-owned record) and drop the consumed prefix."""
        pos, carry_consumed = cursor
        self._cursor = PipelineCursor(pos, carry_consumed)
        self._carry = np.zeros((0,), dtype=np.int32)
        if carry_consumed > 0:
            prev = self._prev_owned(pos)
            if prev is not None:
                doc = self._with_bos(self.topic.log.read(prev, prev + 1)[0])
                self._carry = doc[carry_consumed:]

    def _prev_owned(self, pos: int) -> Optional[int]:
        p = pos - 1
        while p >= 0:
            if p % self.num_hosts == self.host_id:
                return p
            p -= 1
        return None

    def _with_bos(self, raw: bytes) -> np.ndarray:
        return np.concatenate([np.array([self.bos], np.int32),
                               np.frombuffer(raw, dtype=np.int32)])

    # -- batch iterator ---------------------------------------------------------------
    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        need = self.batch_size * (self.seq_len + 1)
        parts: List[np.ndarray] = []
        have = 0
        if len(self._carry):
            parts.append(self._carry)
            have += len(self._carry)
        pos = self._cursor.position
        consumed = self._cursor.carry_tokens   # consumed prefix of prev owned record
        tail = self.topic.log.visible_tail
        last_len = None
        while have < need:
            while pos < tail and pos % self.num_hosts != self.host_id:
                pos += 1
            if pos >= tail:
                raise StopIteration  # live stream exhausted; caller retries later
            doc = self._with_bos(self.topic.log.read(pos, pos + 1)[0])
            parts.append(doc)
            have += len(doc)
            last_len = len(doc)
            pos += 1
        flat = np.concatenate(parts)
        block = flat[:need].reshape(self.batch_size, self.seq_len + 1)
        leftover = flat[need:]
        if last_len is None:
            consumed += need                      # batch served purely from carry
        elif len(leftover):
            consumed = last_len - len(leftover)   # carry = suffix of last record
        else:
            consumed = 0                          # no carry at all
        self._carry = leftover
        self._cursor = PipelineCursor(pos, consumed if len(leftover) else 0)
        return block


def synthetic_token_docs(n_docs: int, vocab: int, min_len: int = 32,
                         max_len: int = 512, seed: int = 0,
                         structured: bool = True) -> List[np.ndarray]:
    """Synthetic documents. `structured` makes them a noisy linear-congruential
    walk (a learnable bigram process), so e2e training shows a real loss curve
    instead of flat ln(vocab)."""
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(min_len, max_len + 1))
        if not structured:
            docs.append(rng.integers(2, vocab, size=n, dtype=np.int32))
            continue
        a = int(rng.choice([1, 3, 5, 7]))
        b = int(rng.integers(1, 97))
        t = int(rng.integers(2, vocab))
        out = np.empty(n, np.int32)
        for i in range(n):
            out[i] = t
            noise = int(rng.integers(0, 3)) if rng.random() < 0.1 else 0
            t = (a * t + b + noise) % (vocab - 2) + 2
        docs.append(out)
    return docs
