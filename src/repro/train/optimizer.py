"""AdamW with ZeRO-1 sharded state and optional low-precision moments.

No optax in this environment — implemented from scratch:
  * fp32 master weights (params stay bf16 for compute),
  * m/v moments in fp32 or bf16 (``moments_dtype`` — the knob that fits
    jamba-398B's optimizer on one 256-chip v5e pod, EXPERIMENTS.md §Dry-run),
  * global-norm clipping, decoupled weight decay, bias correction,
  * ZeRO-1: every optimizer-state leaf is additionally sharded over the
    'data' (and 'pod') mesh axes via ``zero_extend`` — XLA turns the update
    into reduce-scatter + all-gather around the sharded state.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..distributed.sharding import param_shardings, zero_extend


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"      # 'bfloat16' halves m/v memory
    master_weights: bool = True


def adamw_init(params: Any, cfg: AdamWConfig) -> Dict:
    mdt = jnp.dtype(cfg.moments_dtype)
    state = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params: Any, grads: Any, state: Dict,
                 cfg: AdamWConfig) -> Tuple[Any, Dict, jax.Array]:
    step = state["step"] + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)
    masters = state.get("master", params)

    class _Pack(tuple):
        """Marker so tuple-structured params (e.g. 'groups') don't collide."""

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        w32 = w.astype(jnp.float32)
        w32 = w32 - cfg.lr * (u + cfg.weight_decay * w32)
        return _Pack((w32.astype(p.dtype), m32.astype(mdt),
                      v32.astype(mdt), w32))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    sel = lambda i: jax.tree.map(  # noqa: E731
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, _Pack))
    new_params = sel(0)
    new_state = {"m": sel(1), "v": sel(2), "step": step}
    if "master" in state:
        new_state["master"] = sel(3)
    return new_params, new_state, gnorm


def opt_state_shardings(param_shapes: Any, mesh, cfg: AdamWConfig) -> Dict:
    """NamedShardings for the optimizer state: the param's TP spec extended
    with 'data'/'pod' sharding (ZeRO-1)."""
    base = param_shardings(param_shapes, mesh)

    def z(sh_leaf, shape_leaf):
        return NamedSharding(mesh, zero_extend(sh_leaf.spec, shape_leaf.shape, mesh))

    zeroed = jax.tree.map(z, base, param_shapes)
    state = {"m": zeroed, "v": zeroed, "step": NamedSharding(mesh, jax.P())}
    if cfg.master_weights:
        state["master"] = zeroed
    return state
