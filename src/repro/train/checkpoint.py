"""Checkpointing on the diskless substrate (DESIGN.md §2/§6).

Checkpoints use the SAME storage architecture as the log's data plane: workers
write per-leaf objects to the shared object store, then commit an atomic
manifest. A crash mid-write leaves the previous manifest intact (the
FileObjectStore's atomic rename / the memory store's put are all-or-nothing),
so restart always sees a consistent (step, params, opt, data-cursor) tuple.

Restore is mesh-shape agnostic: leaves are stored unsharded (gathered), so a
job restarted at a different DP width (elastic scaling) reshards on load; the
data-pipeline cursor makes the batch stream resume exactly.
"""

from __future__ import annotations

import io
import json
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}

from ..core.objectstore import ObjectStore


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key(prefix: str, step: int, i: int) -> str:
    return f"{prefix}/step-{step:08d}/leaf-{i:05d}.npy"


class CheckpointManager:
    def __init__(self, store: ObjectStore, prefix: str = "ckpt",
                 keep: int = 3) -> None:
        self.store = store
        self.prefix = prefix
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, params: Any, opt_state: Any,
             extra: Optional[Dict] = None) -> None:
        state = {"params": params, "opt": opt_state}
        leaves, treedef = _flatten(state)
        names = []
        dtypes = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dtypes.append(str(arr.dtype))
            if str(arr.dtype) in _EXOTIC:   # numpy can't serialize bf16
                arr = arr.view(_EXOTIC[str(arr.dtype)][1])
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            key = _key(self.prefix, step, i)
            self.store.put(key, buf.getvalue())
            names.append(key)
        manifest = {
            "step": step,
            "leaves": names,
            "dtypes": dtypes,
            "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex(),
            "extra": extra or {},
        }
        # atomic commit: the manifest PUT is the linearization point
        self.store.put(f"{self.prefix}/step-{step:08d}/MANIFEST.json",
                       json.dumps(manifest).encode())
        self.store.put(f"{self.prefix}/LATEST",
                       str(step).encode())
        self._gc(step)

    def _gc(self, latest: int) -> None:
        steps = sorted({int(k.split("step-")[1][:8])
                        for k in self.store.list(self.prefix + "/")
                        if "step-" in k})
        for s in steps[:-self.keep]:
            for k in self.store.list(f"{self.prefix}/step-{s:08d}/"):
                self.store.delete(k)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        if not self.store.exists(f"{self.prefix}/LATEST"):
            return None
        return int(self.store.get(f"{self.prefix}/LATEST"))

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any, Any, Dict]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        manifest = json.loads(
            self.store.get(f"{self.prefix}/step-{step:08d}/MANIFEST.json"))
        from jax.tree_util import PyTreeDef
        td = PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(manifest["treedef"]))
        leaves = []
        for key, dt in zip(manifest["leaves"], manifest["dtypes"]):
            arr = np.load(io.BytesIO(self.store.get(key)), allow_pickle=False)
            if dt in _EXOTIC:
                arr = arr.view(_EXOTIC[dt][0])
            leaves.append(jax.numpy.asarray(arr))
        state = jax.tree_util.tree_unflatten(td, leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return step, state["params"], state["opt"], manifest["extra"]
