"""Checkpoint-as-fork: training state as log lineage (DESIGN.md §17).

The seed CheckpointManager PUT per-leaf ``.npy`` objects and pruned them with
direct ``store.delete`` calls — bytes the §13 refcount manifests never saw,
invisible to the byte-liveness oracle and leaked outright by a crash between
the leaf PUTs and the manifest PUT. This rewrite makes checkpoints log-native,
so every checkpoint byte flows through the same GC/compaction/tiering
machinery as stream data:

* ``{prefix}``        — the **catalog**: a root log of JSON manifest records
  (``save`` / ``prune`` ops). Appending the save record IS the atomic commit
  point; replaying the catalog yields the checkpoint index, so the catalog is
  also the audit trail.
* ``{prefix}/data``   — an empty root whose **cForks hold the bytes**: one
  non-promotable fork per checkpoint, leaf ``.npy`` bytes chunked into
  records. Pruning a checkpoint = ``squash`` its fork — the records die in
  metadata, §13 hands the segments to the reaper, §14 compaction squeezes
  survivors. No direct store deletes anywhere.
* **fork-per-experiment**: ``experiment(name)`` opens a *promotable* cFork of
  the catalog. Its saves are manifest records on the fork (visible to the
  experiment, withheld from the trunk per §4.1 — an open experiment holds the
  trunk catalog). ``merge()`` promotes the fork — squash-on-merge lands the
  experiment's manifests in the trunk atomically; ``abandon()`` squashes the
  fork and the experiment's data forks, and chain-GC reclaims every byte.
* **crash orphans**: a crash between the data-fork flush and the catalog
  append leaves a live, unreferenced data fork. ``recover()`` squashes every
  data fork no visible save record references — the §13 reaper path, covered
  by the oracle, replaces the seed's leak.

Restore stays mesh-shape agnostic (leaves stored gathered; a job restarted at
a different DP width reshards on load) and the data-pipeline cursor still
rides ``extra``.
"""

from __future__ import annotations

import io
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from ..core.api import AgileLog, BoltSystem
from ..core.errors import AgileLogError
from ..streams.records import decode_record, encode_record

_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree: Any):
    return jax.tree_util.tree_flatten(tree)


def _leaf_bytes(leaf: Any) -> Tuple[bytes, str]:
    arr = np.asarray(jax.device_get(leaf))
    dt = str(arr.dtype)
    if dt in _EXOTIC:                 # numpy can't serialize bf16
        arr = arr.view(_EXOTIC[dt][1])
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue(), dt


def _leaf_restore(raw: bytes, dt: str):
    arr = np.load(io.BytesIO(raw), allow_pickle=False)
    if dt in _EXOTIC:
        arr = arr.view(_EXOTIC[dt][0])
    return jax.numpy.asarray(arr)


class CheckpointManager:
    """Checkpoints as forks of a shared log (see module docstring).

    ``catalog=None`` opens (or creates) the trunk catalog; experiments pass
    their catalog fork explicitly via :meth:`experiment`. ``exp`` tags this
    manager's save records — pruning and abandon only ever squash data forks
    tagged with the manager's own lineage, so an experiment can never
    destroy trunk checkpoints (squash is irreversible even if the catalog
    fork is later abandoned)."""

    def __init__(self, system: BoltSystem, prefix: str = "ckpt",
                 keep: int = 3, chunk_bytes: int = 1 << 20,
                 catalog: Optional[AgileLog] = None, exp: str = "") -> None:
        if isinstance(system, BoltSystem):
            self.system = system
        else:   # the seed signature took a bare ObjectStore — fail loudly
            raise TypeError(
                "CheckpointManager now checkpoints onto the log (DESIGN.md "
                "§17) and needs the BoltSystem, not a bare ObjectStore")
        self.prefix = prefix
        self.keep = keep
        self.chunk_bytes = max(1, chunk_bytes)
        self.exp = exp
        self.catalog = catalog if catalog is not None else self._open(prefix)
        self.data_root = self._open(f"{prefix}/data")

    def _open(self, name: str) -> AgileLog:
        log = self.system.find_log(name)
        return log if log is not None else self.system.create_log(name)

    # ------------------------------------------------------------- catalog
    def _replay(self) -> Dict[int, Dict]:
        """Visible checkpoint index: replay the catalog's save/prune records
        in position order. Under an open experiment the trunk's view caps at
        the fork point (§4.1) — trunk saves sequenced during the experiment
        become visible when it merges or abandons."""
        index: Dict[int, Dict] = {}
        for raw in self.catalog.scan():
            rec = decode_record(raw)
            if rec.get("op") == "save":
                index[rec["step"]] = rec
            elif rec.get("op") == "prune":
                for s in rec["steps"]:
                    index.pop(s, None)
        return index

    # ---------------------------------------------------------------- save
    def save(self, step: int, params: Any, opt_state: Any,
             extra: Optional[Dict] = None) -> int:
        """Write one checkpoint; returns the data fork's log id.

        Leaf bytes go to a fresh cFork of the data root first; the catalog
        append is the linearization point (a crash before it leaves only an
        unreferenced fork for :meth:`recover`)."""
        state = {"params": params, "opt": opt_state}
        leaves, treedef = _flatten(state)
        fork = self.data_root.cfork(promotable=False)
        spans: List[List[int]] = []
        dtypes: List[str] = []
        pos = 0
        for leaf in leaves:
            raw, dt = _leaf_bytes(leaf)
            chunks = [raw[o:o + self.chunk_bytes]
                      for o in range(0, len(raw), self.chunk_bytes)] or [b""]
            fork.append_batch(chunks).wait()
            spans.append([pos, pos + len(chunks)])
            dtypes.append(dt)
            pos += len(chunks)
        fork.flush()
        manifest = {
            "op": "save",
            "step": step,
            "data_log": fork.log_id,
            "exp": self.exp,
            "spans": spans,
            "dtypes": dtypes,
            "treedef": jax.tree_util.tree_structure(
                state).serialize_using_proto().hex(),
            "extra": extra or {},
        }
        # atomic commit: this catalog append is the linearization point
        # (withheld-but-sequenced under an open experiment's hold, §4.1)
        self.catalog.append(encode_record(manifest)).wait()
        self._prune()
        return fork.log_id

    def _prune(self) -> List[int]:
        """Keep the newest ``keep`` checkpoints OF THIS LINEAGE: squash the
        data forks of the rest (§13 chain-GC — the reaper deletes, not us)
        and record the retirement in the catalog."""
        if self.keep is None or self.keep <= 0:
            return []
        index = self._replay()
        mine = sorted(s for s, rec in index.items()
                      if rec.get("exp", "") == self.exp)
        victims = mine[:-self.keep]
        if not victims:
            return []
        for s in victims:
            self._squash_data(index[s]["data_log"])
        self.catalog.append(
            encode_record({"op": "prune", "steps": victims})).wait()
        self.system._gc_nudge()
        return victims

    def _squash_data(self, log_id: int) -> None:
        try:
            self.system.open_log(log_id).squash()
        except AgileLogError:
            pass                     # already squashed (re-entrant recover)

    # -------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        return sorted(self._replay())

    def latest_step(self) -> Optional[int]:
        index = self._replay()
        return max(index) if index else None

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any, Any, Dict]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        rec = self._replay().get(step)
        assert rec is not None, f"no checkpoint at step {step}"
        fork = self.system.open_log(rec["data_log"])
        records = list(fork.scan())
        from jax.tree_util import PyTreeDef
        td = PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(rec["treedef"]))
        leaves = []
        for (lo, hi), dt in zip(rec["spans"], rec["dtypes"]):
            leaves.append(_leaf_restore(b"".join(records[lo:hi]), dt))
        state = jax.tree_util.tree_unflatten(td, leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return step, state["params"], state["opt"], rec["extra"]

    # ---------------------------------------------------------- experiments
    def experiment(self, name: str) -> "ExperimentCheckpoints":
        """Open a fork-per-experiment (promotable cFork of the catalog).
        While open it holds the trunk catalog (§4.1): trunk saves stay
        sequenced-but-withheld until the experiment merges or abandons."""
        fork = self.catalog.cfork(promotable=True)
        return ExperimentCheckpoints(self, name, fork)

    # -------------------------------------------------------------- recover
    def recover(self) -> List[int]:
        """Squash every live data fork that no visible save record —
        in the trunk catalog or any live experiment fork of it — references:
        the crash-orphan path (a save that died before its catalog append).
        Returns the squashed fork ids; the §13 reaper reclaims the bytes."""
        referenced = {rec["data_log"] for rec in self._replay().values()}
        logs = self.system.metadata.state.logs
        for log_id, meta in logs.items():
            if meta.parent == self.catalog.log_id and meta.alive:
                exp_cat = self.system.open_log(log_id)
                for raw in exp_cat.scan():
                    rec = decode_record(raw)
                    if rec.get("op") == "save":
                        referenced.add(rec["data_log"])
        orphans = [log_id for log_id, meta in logs.items()
                   if meta.parent == self.data_root.log_id and meta.alive
                   and log_id not in referenced]
        for log_id in orphans:
            self._squash_data(log_id)
        if orphans:
            self.system._gc_nudge()
        return orphans


class ExperimentCheckpoints(CheckpointManager):
    """A CheckpointManager whose catalog is a promotable experiment fork.

    Saves land on the fork (trunk checkpoints remain visible through the
    fork's flattened view, so an experiment restores from trunk state and
    checkpoints its own). ``merge()`` promotes — the experiment's manifest
    records join the trunk catalog atomically and the fork squashes
    (squash-on-merge). ``abandon()`` squashes the fork AND the experiment's
    own data forks, handing the whole lineage to chain-GC."""

    def __init__(self, trunk: CheckpointManager, name: str,
                 fork: AgileLog) -> None:
        super().__init__(trunk.system, prefix=trunk.prefix, keep=trunk.keep,
                         chunk_bytes=trunk.chunk_bytes, catalog=fork,
                         exp=name)
        self.trunk = trunk
        self.name = name
        self._state = "open"          # open | merged | abandoned

    def _require_open(self) -> None:
        if self._state != "open":
            raise AgileLogError(f"experiment {self.name!r} already "
                                f"{self._state}")

    def save(self, step: int, params: Any, opt_state: Any,
             extra: Optional[Dict] = None) -> int:
        self._require_open()
        return super().save(step, params, opt_state, extra)

    def merge(self) -> None:
        """Squash-on-merge: promote the catalog fork into the trunk —
        every save/prune record this experiment wrote becomes trunk-visible
        in one atomic restructure; the data forks are already shared (they
        hang off the data root), so no bytes move."""
        self._require_open()
        self.catalog.promote()
        self._state = "merged"

    def abandon(self) -> None:
        """Drop the experiment: squash its catalog fork and its own data
        forks — abandon = chain-GC (§13/§17). Trunk checkpoints it could
        see through the fork view are untouched (the ``exp`` tag scopes the
        squash to this lineage)."""
        self._require_open()
        index = self._replay()
        for s, rec in index.items():
            if rec.get("exp", "") == self.exp:
                self._squash_data(rec["data_log"])
        self.catalog.squash()
        self._state = "abandoned"
        self.system._gc_nudge()

    # an experiment left open at block exit held the trunk — resolve it
    def __enter__(self) -> "ExperimentCheckpoints":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._state == "open":
            if exc_type is None:
                self.merge()
            else:
                self.abandon()
