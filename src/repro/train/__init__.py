"""Training substrate: optimizer, train step, checkpoint-as-fork (§17)."""

from .checkpoint import CheckpointManager, ExperimentCheckpoints
from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_shardings
from .step import make_train_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "opt_state_shardings", "make_train_step",
           "CheckpointManager", "ExperimentCheckpoints"]
