"""Train-step builder: microbatch gradient accumulation + AdamW + bf16 grads.

Gradient accumulation is a `lax.scan` over microbatches (activation memory is
one microbatch); the cross-microbatch accumulator and the all-reduce happen in
bf16 when ``grad_dtype`` says so (gradient compression — halves DP traffic).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import shard
from ..models.config import ModelConfig
from ..models.lm import loss_fn
from .optimizer import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    accum: int = 1, grad_dtype: str = "bfloat16"):
    gdt = jnp.dtype(grad_dtype)

    def split_batch(batch: Dict) -> Dict:
        def rs(x):
            b = x.shape[0]
            out = x.reshape((accum, b // accum) + x.shape[1:])
            return shard(out, None, ("pod", "data"), *((None,) * (x.ndim - 1)))
        return jax.tree.map(rs, batch)

    def train_step(params: Any, opt_state: Dict, batch: Dict):
        grad_fn = jax.value_and_grad(
            lambda p, mb: loss_fn(cfg, p, mb), has_aux=True)

        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(gdt), grads)
        else:
            mbs = split_batch(batch)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _metrics), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, x: a + x.astype(gdt), acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            inv = 1.0 / accum
            grads = jax.tree.map(lambda g: g * jnp.asarray(inv, gdt), grads)
            loss = loss_sum * inv
            metrics = {}
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, out_metrics

    return train_step
