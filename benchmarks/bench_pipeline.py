"""Training data-plane benchmark: log-backed pipeline throughput + exact
resume, and checkpoint substrate round-trip."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import BoltSystem
from repro.data import LogDataPipeline, TokenStreamWriter, synthetic_token_docs
from repro.streams import Topic

from .common import Row


def bench_pipeline() -> List[Row]:
    rows: List[Row] = []
    sys_ = BoltSystem(n_brokers=4)
    topic = Topic.create(sys_, "tokens")
    writer = TokenStreamWriter(topic, batch_docs=64)
    docs = synthetic_token_docs(3000, vocab=32_000, min_len=128, max_len=1024,
                                seed=1)
    t0 = time.perf_counter()
    for d in docs:
        writer.write_doc(d)
    writer.flush()
    ingest_s = time.perf_counter() - t0
    total_tokens = sum(len(d) for d in docs)
    rows.append(("pipeline/ingest", ingest_s * 1e6,
                 f"{total_tokens / ingest_s / 1e6:.2f} Mtok/s into the log"))

    pipe = LogDataPipeline(topic, batch_size=8, seq_len=1024)
    t0 = time.perf_counter()
    n_batches = 0
    try:
        while True:
            next(pipe)
            n_batches += 1
    except StopIteration:
        pass
    read_s = time.perf_counter() - t0
    toks = n_batches * 8 * 1025
    rows.append(("pipeline/batch_read", read_s * 1e6,
                 f"{toks / read_s / 1e6:.2f} Mtok/s out ({n_batches} batches)"))

    # exact resume
    pipe1 = LogDataPipeline(topic, batch_size=8, seq_len=1024)
    for _ in range(10):
        next(pipe1)
    cur = pipe1.cursor()
    a = next(pipe1)
    pipe2 = LogDataPipeline(topic, batch_size=8, seq_len=1024)
    pipe2.restore(cur)
    b = next(pipe2)
    rows.append(("pipeline/exact_resume", 0.0,
                 f"identical_after_restore={bool((a == b).all())}"))
    return rows
