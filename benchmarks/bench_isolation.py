"""Fig 7 isolation benchmark (deterministic DES; see core/sim.py).

A latency-critical (lc) workload appends at a fixed rate while an analytics
agent issues bursts of bulk reads. In Bolt the agent's fork lives on its own
broker and bulk data comes from the scalable shared store (64-wide service
pool, ~2% utilization); in the Kafka-like baseline both workloads share one
stateful broker and its disk (~70% utilization during bursts). Metadata-layer
costs are measured for real elsewhere; here *contention* is what is modeled.
"""

from __future__ import annotations

from typing import List

from repro.core.sim import Resource, ServiceTimes, summarize

from .common import Row

S = ServiceTimes()
LC_RATE = 2000.0          # lc ops/s
LC_OPS = 4000             # simulated lc ops (2 s window)
REC_KB = 4.0
BULK_KB = 256.0
AGENT_BURSTS = 8
BURST_READS = 200
BURST_SPACING = 2e-4      # 5k req/s within a burst (open loop)


def _run(shared: bool, with_agent: bool):
    """Events MUST be processed in arrival order (the Resource queues are
    chronological), so the lc and agent streams are merged before submission."""
    lc_broker = Resource()
    disk = Resource() if shared else None
    ag_broker = lc_broker if shared else Resource()
    store = Resource(servers=64)   # S3-like: scales with demand (§5.1)
    window = LC_OPS / LC_RATE
    events = [(i / LC_RATE, "lc") for i in range(LC_OPS)]
    if with_agent:
        for b in range(AGENT_BURSTS):
            t0 = b * window / AGENT_BURSTS
            events += [(t0 + i * BURST_SPACING, "agent")
                       for i in range(BURST_READS)]
    events.sort()
    lat = []
    for arr, kind in events:
        if kind == "agent":
            t = ag_broker.submit(arr, S.broker_cpu_per_req
                                 + S.broker_cpu_per_kb * BULK_KB)
            if shared:
                disk.submit(t, S.disk_seek + S.disk_read_per_kb * BULK_KB)
            else:
                store.submit(t, S.store_get_base + S.store_get_per_kb * BULK_KB)
        else:
            t = lc_broker.submit(arr, S.broker_cpu_per_req
                                 + S.broker_cpu_per_kb * REC_KB)
            if shared:
                t = disk.submit(t, S.disk_seek + S.disk_read_per_kb * REC_KB)
            else:
                t = store.submit(t, S.store_put_base
                                 + S.store_put_per_kb * REC_KB)
            t += S.metadata_op + S.net_rtt
            lat.append(t - arr)
    return summarize(lat)


def bench_isolation() -> List[Row]:
    rows: List[Row] = []
    mean0, _p, p99_0 = _run(shared=False, with_agent=False)
    rows.append(("fig7/lc_alone/mean", mean0 * 1e6, "diskless, no agent"))
    rows.append(("fig7/lc_alone/p99", p99_0 * 1e6, ""))

    mean_b, _p, p99_b = _run(shared=False, with_agent=True)
    rows.append(("fig7/bolt_with_agent/mean", mean_b * 1e6,
                 f"{mean_b / mean0:.2f}x of alone"))
    rows.append(("fig7/bolt_with_agent/p99", p99_b * 1e6,
                 f"{p99_b / p99_0:.2f}x of alone"))

    mean_k, _p, p99_k = _run(shared=True, with_agent=True)
    rows.append(("fig7/kafka_with_agent/mean", mean_k * 1e6,
                 f"{mean_k / mean_b:.1f}x of Bolt"))
    rows.append(("fig7/kafka_with_agent/p99", p99_k * 1e6,
                 f"{p99_k / p99_b:.1f}x of Bolt"))
    return rows
