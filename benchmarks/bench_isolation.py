"""Fig 7 isolation benchmark (deterministic DES; see core/sim.py).

A latency-critical (lc) workload appends at a fixed rate while an analytics
agent issues bursts of bulk reads. In Bolt the agent's fork lives on its own
broker and bulk data comes from the scalable shared store (64-wide service
pool, ~2% utilization); in the Kafka-like baseline both workloads share one
stateful broker and its disk (~70% utilization during bursts). Metadata-layer
costs are measured for real elsewhere; here *contention* is what is modeled.
"""

from __future__ import annotations

from typing import List

from repro.core.sim import Resource, ServiceTimes, summarize

from .common import Row

S = ServiceTimes()
LC_RATE = 2000.0          # lc ops/s
LC_OPS = 4000             # simulated lc ops (2 s window)
REC_KB = 4.0
BULK_KB = 256.0
AGENT_BURSTS = 8
BURST_READS = 200
BURST_SPACING = 2e-4      # 5k req/s within a burst (open loop)


def _run(shared: bool, with_agent: bool, gc_batch: int = 1):
    """Events MUST be processed in arrival order (the Resource queues are
    chronological), so the lc and agent streams are merged before submission.

    ``gc_batch > 1`` models the group-commit pipeline (DESIGN.md §9) on the
    diskless path: every batch of lc appends shares ONE object PUT (of the
    combined payload) and ONE metadata sequencing round; each record's latency
    still runs from its own arrival, so the batching delay is *charged*, not
    hidden. Returns (summary, store_put_count, bulk_resource_utilization) —
    utilization of the resource serving bulk data (shared disk / store pool).
    """
    lc_broker = Resource()
    disk = Resource() if shared else None
    ag_broker = lc_broker if shared else Resource()
    store = Resource(servers=64)   # S3-like: scales with demand (§5.1)
    window = LC_OPS / LC_RATE
    events = [(i / LC_RATE, "lc") for i in range(LC_OPS)]
    if with_agent:
        for b in range(AGENT_BURSTS):
            t0 = b * window / AGENT_BURSTS
            events += [(t0 + i * BURST_SPACING, "agent")
                       for i in range(BURST_READS)]
    events.sort()
    lat = []
    puts = 0
    staged = []   # (arrival, broker-done) of staged lc appends awaiting flush

    def flush():
        nonlocal puts
        if not staged:
            return
        ready = max(t for _, t in staged)
        done = store.submit(ready, S.store_put_base
                            + S.store_put_per_kb * REC_KB * len(staged))
        done += S.metadata_op + S.net_rtt
        puts += 1
        lat.extend(done - a for a, _ in staged)
        staged.clear()

    for arr, kind in events:
        if kind == "agent":
            t = ag_broker.submit(arr, S.broker_cpu_per_req
                                 + S.broker_cpu_per_kb * BULK_KB)
            if shared:
                disk.submit(t, S.disk_seek + S.disk_read_per_kb * BULK_KB)
            else:
                store.submit(t, S.store_get_base + S.store_get_per_kb * BULK_KB)
        else:
            t = lc_broker.submit(arr, S.broker_cpu_per_req
                                 + S.broker_cpu_per_kb * REC_KB)
            if shared:
                t = disk.submit(t, S.disk_seek + S.disk_read_per_kb * REC_KB)
                t += S.metadata_op + S.net_rtt
                lat.append(t - arr)
            elif gc_batch > 1:
                staged.append((arr, t))
                if len(staged) >= gc_batch:
                    flush()
            else:
                t = store.submit(t, S.store_put_base
                                 + S.store_put_per_kb * REC_KB)
                t += S.metadata_op + S.net_rtt
                puts += 1
                lat.append(t - arr)
    flush()
    bulk = disk if shared else store
    return summarize(lat), puts, bulk.utilization(window)


GC_BATCH = 16


def bench_isolation() -> List[Row]:
    rows: List[Row] = []
    (mean0, _p, p99_0), _, _ = _run(shared=False, with_agent=False)
    rows.append(("fig7/lc_alone/mean", mean0 * 1e6, "diskless, no agent"))
    rows.append(("fig7/lc_alone/p99", p99_0 * 1e6, ""))

    (mean_b, _p, p99_b), puts_b, util_b = _run(shared=False, with_agent=True)
    rows.append(("fig7/bolt_with_agent/mean", mean_b * 1e6,
                 f"{mean_b / mean0:.2f}x of alone; store util {util_b:.1%}"))
    rows.append(("fig7/bolt_with_agent/p99", p99_b * 1e6,
                 f"{p99_b / p99_0:.2f}x of alone"))

    (mean_g, _p, p99_g), puts_g, _ = _run(shared=False, with_agent=True,
                                          gc_batch=GC_BATCH)
    rows.append(("fig7/bolt_gc_with_agent/mean", mean_g * 1e6,
                 f"batch={GC_BATCH}: {puts_b / puts_g:.0f}x fewer PUTs"))
    rows.append(("fig7/bolt_gc_with_agent/p99", p99_g * 1e6,
                 f"{p99_g / p99_b:.2f}x of per-call Bolt"))

    (mean_k, _p, p99_k), _, util_k = _run(shared=True, with_agent=True)
    rows.append(("fig7/kafka_with_agent/mean", mean_k * 1e6,
                 f"{mean_k / mean_b:.1f}x of Bolt; disk util {util_k:.1%}"))
    rows.append(("fig7/kafka_with_agent/p99", p99_k * 1e6,
                 f"{p99_k / p99_b:.1f}x of Bolt"))
    return rows
