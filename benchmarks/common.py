"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]  # (name, us_per_call, derived)

RECORD = b"x" * 256  # benchmark record payload (paper uses 4KB; scaled for CPU)


def timeit(fn: Callable[[], None], n: int, warmup: int = 1) -> float:
    """Mean wall-clock microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def fill_root(system, name: str, n_records: int, batch: int = 1024):
    log = system.create_log(name)
    rec = RECORD
    full, rem = divmod(n_records, batch)
    for _ in range(full):
        log.append_batch([rec] * batch)
    if rem:
        log.append_batch([rec] * rem)
    return log


def fmt(rows: List[Row]) -> str:
    return "\n".join(f"{n},{v:.3f},{d}" for n, v, d in rows)
