"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import atexit
import os
import shutil
import tempfile
import time
from typing import Callable, Dict, List, Tuple

Row = Tuple[str, float, str]  # (name, us_per_call, derived)

RECORD = b"x" * 256  # benchmark record payload (paper uses 4KB; scaled for CPU)


def backend_kwargs() -> Dict[str, str]:
    """``BoltSystem`` kwargs for the ``BENCH_STORE`` env override.

    CI's fast lane runs the append/read smokes with ``BENCH_STORE=file`` so
    the wall-clock paths exercise the real fsync'ing backend (DESIGN.md §18);
    the file root is tmpdir-scoped and reaped at interpreter exit. Unset (the
    default) keeps the seed's in-memory store.
    """
    backend = os.environ.get("BENCH_STORE", "")
    if not backend:
        return {}
    kw = {"store_backend": backend}
    if backend == "file":
        root = tempfile.mkdtemp(prefix="agilelog-bench-")
        atexit.register(shutil.rmtree, root, ignore_errors=True)
        kw["store_root"] = root
    return kw


def timeit(fn: Callable[[], None], n: int, warmup: int = 1) -> float:
    """Mean wall-clock microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def fill_root(system, name: str, n_records: int, batch: int = 1024):
    log = system.create_log(name)
    rec = RECORD
    full, rem = divmod(n_records, batch)
    for _ in range(full):
        log.append_batch([rec] * batch)
    if rem:
        log.append_batch([rec] * rem)
    return log


def fmt(rows: List[Row]) -> str:
    return "\n".join(f"{n},{v:.3f},{d}" for n, v, d in rows)
