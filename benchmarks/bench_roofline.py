"""Roofline table reader: summarizes results/*.json from the dry-run."""

from __future__ import annotations

import glob
import json
import os
from typing import List

from .common import Row

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results")


def bench_roofline() -> List[Row]:
    rows: List[Row] = []
    files = sorted(glob.glob(f"{RESULTS_DIR}/*.json"))
    if not files:
        return [("roofline/none", 0.0,
                 "no dry-run results (run repro.launch.dryrun first)")]
    for fn in files:
        with open(fn) as f:
            d = json.load(f)
        tag = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        if "baseline" not in fn:
            tag += "/" + os.path.basename(fn).rsplit("__", 1)[1].replace(".json", "")
        if d.get("status") == "skip":
            rows.append((f"roofline/{tag}", 0.0, f"SKIP: {d['reason']}"))
            continue
        r = d["roofline"]
        dom = d["dominant"].replace("_s", "")
        step = max(r.values())
        frac = d["roofline"]["compute_s"] * d["useful_flops_ratio"] / step
        rows.append((
            f"roofline/{tag}",
            step * 1e6,
            f"dom={dom} compute={r['compute_s']:.2f}s mem={r['memory_s']:.2f}s "
            f"coll={r['collective_s']:.2f}s useful={d['useful_flops_ratio']:.2f} "
            f"roofline_frac={frac:.3f} peakGB={d['memory']['peak_bytes_per_device'] / 1e9:.1f}"))
    return rows
