"""Group-commit append amortization benchmark (DESIGN.md §9).

Appends the same record stream — round-robin across several logs co-located on
one broker — once through the per-call append path and once with group commit,
and reports metadata proposals and object PUTs *per appended record*, wall-
clock throughput, and the amortization factor. The two streams must read back
byte-identical; a mismatch aborts the benchmark (it would mean the batched
proposal assigned different positions than per-call sequencing).
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

from repro.core import BoltSystem, GroupCommitConfig
from repro.core.sim import OpTally

from .common import RECORD, Row

N_LOGS = 4
N_RECORDS = 4096
BATCH = 64


def _run(group_commit: Optional[GroupCommitConfig]
         ) -> Tuple[OpTally, float, List[List[bytes]]]:
    system = BoltSystem(n_brokers=2, group_commit=group_commit)
    logs = [system.create_log(f"log{i}") for i in range(N_LOGS)]
    before = OpTally.capture(system)
    start = time.perf_counter()
    pending = []
    for i in range(N_RECORDS):
        out = logs[i % N_LOGS].append(RECORD)
        if group_commit is not None:
            pending.append(out)
    system.flush()
    for p in pending:
        assert p.positions() is not None
    elapsed = time.perf_counter() - start
    tally = OpTally.capture(system, records=N_RECORDS).delta(before)
    reads = [log.read(0, N_RECORDS // N_LOGS) for log in logs]
    return tally, elapsed, reads


def bench_append() -> List[Row]:
    pc_tally, pc_elapsed, pc_reads = _run(None)
    gc_tally, gc_elapsed, gc_reads = _run(GroupCommitConfig(max_records=BATCH))
    if pc_reads != gc_reads:
        raise RuntimeError("group-commit read-back differs from per-call append")

    rows: List[Row] = []
    for label, tally, elapsed in [("per_call", pc_tally, pc_elapsed),
                                  ("group_commit", gc_tally, gc_elapsed)]:
        krec_s = N_RECORDS / elapsed / 1e3
        rows.append((f"append/{label}/proposals_per_record",
                     tally.proposals_per_record, f"{tally.proposals} proposals"))
        rows.append((f"append/{label}/puts_per_record",
                     tally.puts_per_record, f"{tally.puts} puts"))
        rows.append((f"append/{label}/us_per_record",
                     elapsed / N_RECORDS * 1e6, f"{krec_s:.1f} krec/s"))
    rows.append(("append/amortization/proposals",
                 pc_tally.proposals_per_record / gc_tally.proposals_per_record,
                 f"batch={BATCH}, logs={N_LOGS}"))
    rows.append(("append/amortization/puts",
                 pc_tally.puts_per_record / gc_tally.puts_per_record,
                 f"{gc_tally.bytes_put / max(1, gc_tally.puts):.0f} B/object"))
    rows.append(("append/amortization/throughput",
                 pc_elapsed / gc_elapsed, "wall-clock speedup"))
    return rows
