"""Group-commit append amortization + append-ack latency (DESIGN.md §9, §18).

Two parts:

* **Amortization** — appends the same record stream — round-robin across
  several logs co-located on one broker — once through the per-call append
  path and once with group commit, and reports metadata proposals and object
  PUTs *per appended record*, wall-clock throughput, and the amortization
  factor. The two streams must read back byte-identical; a mismatch aborts
  the benchmark (it would mean the batched proposal assigned different
  positions than per-call sequencing).
* **Ack-p99 sweep (§18)** — modeled append-ack p99 on the DES clock across
  the store backends (memory / file-with-fsync / S3-style ranged), each run
  sequentially (PUT, then propose) and pipelined (the broker overlaps the
  segment PUT with the metadata propose; ack = both landed). The overlap
  hides the propose under the PUT, so pipelined p99 must beat sequential on
  every backend (CI ``--key-min`` on the speedup keys). Backend cost
  profiles come from ``StoreProfile`` (§18); memory books the global
  ``ServiceTimes`` rates — the byte-identical pre-§18 model.

``BENCH_QUICK=1`` shrinks the sweep ~4x for CI smoke. ``BENCH_STORE=file``
(CI) additionally runs the wall-clock amortization part against the
tmpdir-scoped fsync'ing backend.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List, Optional, Tuple

from repro.core import BoltSystem, GroupCommitConfig
from repro.core.sim import (OpTally, Resource, ServiceTimes, Simulator,
                            summarize)

from .common import RECORD, Row, backend_kwargs

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")

N_LOGS = 4
N_RECORDS = 4096
BATCH = 64

DES_OPS = 512 if QUICK else 2048  # ack-p99 sweep: appends per (backend, mode)
DES_RATE = 600.0                  # arrivals per modeled second
DES_BACKENDS = ("memory", "file", "ranged")


def _ack_p99(backend: str, pipelined: bool, root: Optional[str]) -> float:
    """Modeled append-ack p99 (seconds) for one (backend, pipeline) cell."""
    kw = {"store_backend": backend}
    if backend == "file":
        kw["store_root"] = os.path.join(root, "pipe" if pipelined else "seq")
    system = BoltSystem(n_brokers=2, pipelined_io=pipelined, **kw)
    sim = Simulator()
    service = ServiceTimes()
    store_res = Resource(servers=64)
    for b in system.brokers:
        b.sim = sim
        b.service = service
        b.store_resource = store_res
    log = system.create_log("p99")
    broker = log.broker
    lat: List[float] = []
    for i in range(DES_OPS):
        t = i / DES_RATE
        _, done = broker.append(log.log_id, [RECORD], arrival=t)
        lat.append(done - t)
    return summarize(sorted(lat))[2]


def _ack_sweep(rows: List[Row]) -> None:
    root = tempfile.mkdtemp(prefix="agilelog-bench-append-")
    try:
        for backend in DES_BACKENDS:
            seq = _ack_p99(backend, pipelined=False, root=root)
            pipe = _ack_p99(backend, pipelined=True, root=root)
            rows.append((f"append/ack_p99/{backend}/sequential_ms", seq * 1e3,
                         f"PUT then propose, {DES_OPS} appends at "
                         f"{DES_RATE:.0f}/s on the DES clock"))
            rows.append((f"append/ack_p99/{backend}/pipelined_ms", pipe * 1e3,
                         "segment PUT overlapped with the metadata propose "
                         "(ack = both landed)"))
            rows.append((f"append/ack_p99/{backend}/overlap_speedup",
                         seq / pipe,
                         "sequential/pipelined ack p99 — the propose hides "
                         "under the PUT (acceptance > 1.0, CI --key-min)"))
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _run(group_commit: Optional[GroupCommitConfig]
         ) -> Tuple[OpTally, float, List[List[bytes]]]:
    system = BoltSystem(n_brokers=2, group_commit=group_commit,
                        **backend_kwargs())
    logs = [system.create_log(f"log{i}") for i in range(N_LOGS)]
    before = OpTally.capture(system)
    start = time.perf_counter()
    pending = []
    for i in range(N_RECORDS):
        out = logs[i % N_LOGS].append(RECORD)
        if group_commit is not None:
            pending.append(out)
    system.flush()
    for p in pending:
        assert p.positions() is not None
    elapsed = time.perf_counter() - start
    tally = OpTally.capture(system, records=N_RECORDS).delta(before)
    reads = [log.read(0, N_RECORDS // N_LOGS) for log in logs]
    return tally, elapsed, reads


def bench_append() -> List[Row]:
    pc_tally, pc_elapsed, pc_reads = _run(None)
    gc_tally, gc_elapsed, gc_reads = _run(GroupCommitConfig(max_records=BATCH))
    if pc_reads != gc_reads:
        raise RuntimeError("group-commit read-back differs from per-call append")

    rows: List[Row] = []
    for label, tally, elapsed in [("per_call", pc_tally, pc_elapsed),
                                  ("group_commit", gc_tally, gc_elapsed)]:
        krec_s = N_RECORDS / elapsed / 1e3
        rows.append((f"append/{label}/proposals_per_record",
                     tally.proposals_per_record, f"{tally.proposals} proposals"))
        rows.append((f"append/{label}/puts_per_record",
                     tally.puts_per_record, f"{tally.puts} puts"))
        rows.append((f"append/{label}/us_per_record",
                     elapsed / N_RECORDS * 1e6, f"{krec_s:.1f} krec/s"))
    rows.append(("append/amortization/proposals",
                 pc_tally.proposals_per_record / gc_tally.proposals_per_record,
                 f"batch={BATCH}, logs={N_LOGS}"))
    rows.append(("append/amortization/puts",
                 pc_tally.puts_per_record / gc_tally.puts_per_record,
                 f"{gc_tally.bytes_put / max(1, gc_tally.puts):.0f} B/object"))
    rows.append(("append/amortization/throughput",
                 pc_elapsed / gc_elapsed, "wall-clock speedup"))
    _ack_sweep(rows)
    return rows
