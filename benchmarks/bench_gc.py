"""Segment-GC agent-churn benchmark (DESIGN.md §13).

Agentic write patterns are speculative and high-churn: sessions fork, append
a private suffix, and then either commit (one winner) or abort. Before §13
every aborted suffix — and every conflict-rebased one — stranded its segment
objects in shared storage forever. This scenario measures that directly:

* **Churn storage amplification** — N speculation sessions race a hot
  producer; a fixed fraction abort. ``amplification = store_bytes /
  live_bytes`` (live = bytes reachable through the surviving root's view).
  Acceptance: after churn quiesces and GC drains, amplification returns to
  <= 1.2x (CI gates both the ceiling and its reciprocal ``efficiency``
  floor via scripts/bench_compare.py).
* **Group-commit variant** — multi-log segments (§9) mix records of many
  sessions in one object, so a dead session leaves *partially* live
  segments; object-granular GC alone strands those dead bytes (~2.33x,
  reported as ``amplification_post_nocompact``). The §14 compactor rewrites
  the live spans onto fresh objects and retires the sources, bringing the
  gated ``amplification_post`` back under the same 1.2x ceiling as the
  per-call scenario.
* **Tiering probe** — the §14 cold store class, measured through the real
  broker read path under the DES: the cold/hot read-latency ratio and the
  zlib compression ratio cold residency buys.
* **Isolation** — deterministic DES (§8): the reaper books its deletes on
  its own broker, so the latency-critical append path's p99 with background
  GC stays at the no-GC baseline (ratio ~1.0); booking the same reap work
  on the lc broker shows the contention the placement avoids.

``BENCH_QUICK=1`` shrinks the run ~4x for CI smoke.
"""

from __future__ import annotations

import os
from typing import List

from repro.core import BoltSystem, ConflictError, GroupCommitConfig
from repro.core.broker import Broker
from repro.core.objectstore import MemoryObjectStore, TieredObjectStore
from repro.core.raft import MetadataService
from repro.core.sim import (OpTally, Resource, ServiceTimes, Simulator,
                            summarize)

from .common import Row

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")

REC_BYTES = 512
SUFFIX = 8                  # records per speculation session
N_ROUNDS = 8 if QUICK else 32
SESSIONS_PER_ROUND = 3      # concurrent same-fork-point speculations
PRODUCE_EVERY = 2           # producer records per round (forces conflicts)


def _live_bytes(system, log_id: int) -> int:
    state = system.metadata.state
    tail = state.tails.get(log_id)[0]
    return sum(ln for _obj, _off, ln in
               state.read_spans(log_id, 0, tail, _skip_checks=True))


def _run_churn(group_commit: bool, compact: bool = False) -> dict:
    """N rounds of concurrent speculation: each round opens three sessions at
    one fork point (under group commit their staged suffixes share segment
    objects — a dead session then leaves *partially* live segments), the
    producer races them, two abort, one commits through a rebase."""
    system = BoltSystem(
        n_brokers=4,
        group_commit=GroupCommitConfig(max_records=10_000) if group_commit
        else None)
    root = system.create_log("orders")
    root.append_batch([b"p" * REC_BYTES] * 64).wait()
    aborted = committed = conflicts = 0
    for _ in range(N_ROUNDS):
        sessions = [root.speculate(max_rebases=8)
                    for _ in range(SESSIONS_PER_ROUND)]
        for s in sessions:
            s.append_batch([b"s" * REC_BYTES] * SUFFIX)
        for _ in range(PRODUCE_EVERY):
            root.append(b"p" * REC_BYTES)     # withheld: conflicts at commit
        for s in sessions[:-1]:               # losers release their holds
            s.abort()
            aborted += 1
        try:
            res = sessions[-1].commit()       # rebases over the producer delta
            committed += 1
            conflicts += res.attempts - 1
        except ConflictError:
            aborted += 1
    system.flush()
    live = _live_bytes(system, root.log_id)
    before = OpTally.capture(system)
    pre = system.store.total_bytes / max(1, live)
    system.gc()
    tally = OpTally.capture(system).delta(before)
    post = system.store.total_bytes / max(1, live)
    out = {"pre": pre, "post": post, "aborted": aborted,
           "committed": committed, "conflicts": conflicts,
           "reclaimed_objects": tally.deletes,
           "reclaimed_bytes": tally.bytes_reclaimed,
           "pending_after": system.metadata.state.gc_pending()}
    if compact:
        # the §14 epoch: rewrite live spans of partially-live segments onto
        # compacted objects, retire the sources through the reaper, and
        # re-measure residency against the SAME live-byte denominator
        cstats = system.compact()
        system.gc()
        out["post_nocompact"] = post
        out["post"] = system.store.total_bytes / max(1, live)
        out["compacted_objects"] = cstats.compacted_objects
        out["sources_retired"] = cstats.sources_retired
        out["rewrite_bytes"] = cstats.bytes_written
        out["rewrite_fraction"] = cstats.bytes_written / max(1, live)
    return out


# -- DES isolation: does reaping perturb the lc path? -----------------------

LC_RATE = 2000.0
LC_OPS = 1000 if QUICK else 3000
BACKLOG = 1000 if QUICK else 2000   # dead objects drained mid-run


def _run_lc(reap_on: str) -> float:
    """p99 lc append latency while a BACKLOG-object GC drain lands mid-run,
    booked on the lc broker ('shared'), a separate broker ('isolated'), or
    not at all ('none'). Every operation is REAL (store PUTs, metadata
    proposals, a consensus-ordered gc command); only time is modeled (§8).
    The drain is the worst case: one quantum reaping a whole churn backlog,
    i.e. BACKLOG per-object DELETE calls issued from one broker's CPU."""
    sim = Simulator()
    service = ServiceTimes()
    store = MemoryObjectStore()
    store_res = Resource(servers=64)
    metadata = MetadataService(n_replicas=3)
    lc = Broker(0, store, metadata, sim=sim, service=service,
                store_resource=store_res)
    agent = Broker(1, store, metadata, sim=sim, service=service,
                   store_resource=store_res)
    root = metadata.propose(("create_root", "lc"))
    rec = b"x" * 1024
    if reap_on != "none":
        # real churn backlog: a fork accumulates BACKLOG single-record
        # objects, then dies. arrival=None: the churn happened BEFORE the
        # measurement window, so its PUTs must not occupy the window's
        # store pool — only the mid-run drain is under test
        fork = metadata.propose(("cfork", root, False))
        for _ in range(BACKLOG):
            agent.append(fork, [rec], arrival=None)
        metadata.propose(("squash", fork))
    lat: List[float] = []
    t_mid = LC_OPS / LC_RATE / 2
    drained = False
    for i in range(LC_OPS):
        t = i / LC_RATE
        if reap_on != "none" and not drained and t >= t_mid:
            dead = metadata.propose(("gc", None, ()))
            for obj in dead:
                store.delete(obj)
            reaper = lc if reap_on == "shared" else agent
            reaper.book_reclaim(t, len(dead))
            drained = True
        _, done = lc.append(root, [rec], arrival=t)
        lat.append(done - t)
    return summarize(sorted(lat))[2]


def _run_tier_probe() -> dict:
    """Cold vs hot read latency through the REAL broker read path (§14):
    the same object, the same spans, the same page-cache plumbing (pages
    invalidated between reads so every read hits the store class) — only
    the tier placement differs. Also reports the zlib compression ratio
    cold residency buys on record-shaped payloads."""
    sim = Simulator()
    service = ServiceTimes()
    store = TieredObjectStore()
    store_res = Resource(servers=64)
    metadata = MetadataService(n_replicas=3)
    broker = Broker(0, store, metadata, sim=sim, service=service,
                    store_resource=store_res)
    root = metadata.propose(("create_root", "tier"))
    n = 64
    broker.append(root, [(b"tier-%04d|" % i) * 32 for i in range(n)],
                  arrival=None)
    (obj,) = store.list()
    reads = 200 if QUICK else 600
    rate = 500.0

    def probe(offset: float) -> float:
        lat: List[float] = []
        for i in range(reads):
            broker.cache.invalidate_object(obj)
            t = offset + i / rate
            _, done = broker.read(root, 0, n, arrival=t)
            lat.append(done - t)
        return summarize(sorted(lat))[0]

    hot = probe(0.0)
    store.copy_to_cold(obj)
    store.drop_hot(obj)
    cold = probe(reads / rate + 1.0)
    return {"hot_mean": hot, "cold_mean": cold,
            "cold_gets": store.cold_gets,
            "compression": store.cold_logical_bytes / max(1, store.cold_stored_bytes)}


def bench_gc() -> List[Row]:
    rows: List[Row] = []
    churn = _run_churn(group_commit=False)
    rows.append(("gc/churn/amplification_pre", churn["pre"],
                 f"{churn['aborted']} aborted + {churn['committed']} committed "
                 f"sessions ({churn['conflicts']} conflicts rebased): dead "
                 "suffixes stranded before GC"))
    rows.append(("gc/churn/amplification_post", churn["post"],
                 f"after drain: {churn['reclaimed_objects']} objects / "
                 f"{churn['reclaimed_bytes']} B reclaimed, "
                 f"{churn['pending_after']} pending (acceptance <= 1.2x)"))
    rows.append(("gc/churn/efficiency_post", 1.0 / churn["post"],
                 "live_bytes/store_bytes reciprocal floor for the CI "
                 "--key-min gate (>= 0.833 == amplification <= 1.2x)"))
    gcc = _run_churn(group_commit=True, compact=True)
    rows.append(("gc/groupcommit/amplification_pre", gcc["pre"],
                 "multi-log segments (§9): sessions share objects"))
    rows.append(("gc/groupcommit/amplification_post_nocompact",
                 gcc["post_nocompact"],
                 f"{gcc['reclaimed_objects']} whole objects reclaimed; "
                 "object-granular GC cannot touch dead bytes inside "
                 "partially-live shared segments — the §14 motivation"))
    rows.append(("gc/groupcommit/amplification_post", gcc["post"],
                 f"after the §14 compaction epoch: {gcc['sources_retired']} "
                 f"sparse segments rewritten into "
                 f"{gcc['compacted_objects']} compacted objects "
                 f"({gcc['rewrite_bytes']} B, {gcc['rewrite_fraction']:.2f}x "
                 "of live) — gated <= 1.2x like the per-call scenario"))
    tier = _run_tier_probe()
    rows.append(("gc/tiering/cold_read_latency_ratio",
                 tier["cold_mean"] / tier["hot_mean"],
                 f"mean scan latency {tier['cold_mean'] * 1e3:.2f}ms via the "
                 f"cold class vs {tier['hot_mean'] * 1e3:.2f}ms hot "
                 f"({tier['cold_gets']} cold GETs booked at archive rates)"))
    rows.append(("gc/tiering/compression_ratio", tier["compression"],
                 "logical/stored bytes for cold residency (zlib level 1 on "
                 "record-shaped payloads)"))
    p99_none = _run_lc("none")
    p99_iso = _run_lc("isolated")
    p99_shared = _run_lc("shared")
    rows.append(("gc/isolation/lc_p99_ratio", p99_iso / p99_none,
                 f"lc append p99 {p99_iso * 1e6:.0f}us with a {BACKLOG}-object "
                 f"drain on the reaper's own broker vs {p99_none * 1e6:.0f}us "
                 "without GC (~1.0 = GC does not perturb the lc path)"))
    rows.append(("gc/isolation/lc_p99_shared_ratio", p99_shared / p99_none,
                 f"{p99_shared * 1e6:.0f}us when the same drain books on the "
                 "lc broker — the CPU burst §5.7-style placement avoids"))
    return rows
