"""Metadata fast-path benchmarks (DESIGN.md §11): the control plane under
agent load.

Four families:

* ``meta/lookup_hold``   — single-position lookup latency on a depth-7 cFork
                           chain while 0/1/4 promotable holds are active on
                           *sibling* branches (acceptance: within 2x of the
                           no-hold cached latency; the pre-§11 gate fell back
                           to the 12-15x chain walk the moment any hold
                           existed anywhere).
* ``meta/lookup_held``   — lookups on the logs the holds actually constrain:
                           the holder's visible prefix and the promotable
                           child's unbounded view, both served from cache.
* ``meta/promote_reread``— promote latency PLUS re-serving one read on each
                           of N warm views on unrelated logs: scoped
                           invalidation keeps them warm (flat in N), the old
                           wholesale clear rebuilt every one of them.
* ``meta/proposals``     — metadata proposals/sec with pipelined vs
                           synchronous replica apply (3 replicas).

Quick mode for CI smoke runs: ``BENCH_QUICK=1`` shrinks sizes ~8x.
"""

from __future__ import annotations

import gc
import os
import time
from typing import List

from repro.core.metadata import MetadataState
from repro.core.raft import MetadataService

from .common import Row, timeit

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")


def _append(state, log_id, n, tag, batch=512):
    done = 0
    while done < n:
        k = min(batch, n - done)
        state.apply(("append", log_id, f"{tag}-{done}",
                     tuple(range(0, 8 * k, 8)), tuple([8] * k)))
        done += k


def _deep_chain(state, root, levels, per_level, tag):
    """A `levels`-deep cFork chain off `root`; returns the deepest log."""
    log_id = root
    for depth in range(levels):
        _append(state, log_id, per_level, f"{tag}{depth}")
        log_id = state.apply(("cfork", log_id, False))
    return log_id


def bench_meta() -> List[Row]:
    rows: List[Row] = []
    levels = 7
    per_level = 2_500 if QUICK else 20_000
    n_calls = 500 if QUICK else 2_000

    # -- lookup vs sibling-branch holds -------------------------------------
    # One root; the reader is a depth-7 chain off branch R. Holds live on
    # OTHER branches of the root: each hold's holder is the sibling branch
    # log itself, so the reader's lineage never contains a holder.
    state = MetadataState(view_cache=True)
    root = state.apply(("create_root", "r"))
    _append(state, root, per_level, "root")
    reader_branch = state.apply(("cfork", root, False))
    deepest = _deep_chain(state, reader_branch, levels, per_level, "rd")
    siblings = [state.apply(("cfork", root, False)) for _ in range(4)]
    for s in siblings:
        _append(state, s, 64, f"sib{s}")
    pos = per_level * 2 + per_level // 2          # resolves depth >= 5
    tail = state.tail(deepest)
    assert pos < tail

    lookup = {}
    active = []
    gc.collect()   # the big setup states above otherwise leak GC pauses
    for n_holds in (0, 1, 4):   # into the microsecond-scale lookup timings
        while len(active) < n_holds:
            active.append(state.apply(("cfork", siblings[len(active)], True)))
        assert len(state._holders) == n_holds
        gc.collect()
        us = timeit(lambda: state.read_spans(deepest, pos, pos + 1), n=n_calls)
        lookup[n_holds] = us
        rows.append((f"meta/lookup_hold/cached/holds={n_holds}", us,
                     f"depth>=5 lookup, {n_holds} sibling-branch holds"))
    for n_holds in (1, 4):
        ratio = lookup[n_holds] / lookup[0]
        rows.append((f"meta/lookup_hold/penalty/holds={n_holds}", ratio,
                     f"{ratio:.2f}x of no-hold cached (acceptance <=2x)"))
    # reference: what the pre-§11 global gate cost under any hold
    plain = MetadataState(view_cache=False)
    p_root = plain.apply(("create_root", "r"))
    _append(plain, p_root, per_level, "root")
    p_branch = plain.apply(("cfork", p_root, False))
    p_deep = _deep_chain(plain, p_branch, levels, per_level, "rd")
    us = timeit(lambda: plain.read_spans(p_deep, pos, pos + 1), n=n_calls)
    rows.append(("meta/lookup_hold/uncached_chain_walk", us,
                 f"pre-§11 fallback: {us / lookup[0]:.1f}x the cached lookup"))

    # -- lookups on the held lineage itself ---------------------------------
    holder = siblings[0]                           # holds active[0]
    h_tail = state.visible_tail(holder)
    us = timeit(lambda: state.read_spans(holder, h_tail - 1, h_tail), n=n_calls)
    rows.append(("meta/lookup_held/holder_visible_prefix", us,
                 "holder's reads below fp, served from the capped view"))
    _append(state, holder, 64, "withheld")         # beyond the fork point
    child = active[0]
    c_tail = state.tail(child)
    us = timeit(lambda: state.read_spans(child, c_tail - 1, c_tail), n=n_calls)
    rows.append(("meta/lookup_held/promotable_child_beyond_fp", us,
                 "validating child reads past fp, served from its view"))

    # -- promote vs N warm views on unrelated DEEP logs ---------------------
    # The pre-§11 wholesale clear made every promote rebuild every view in
    # the system on its next read. Unrelated views here share a deep,
    # many-run lineage (rebuild is a full chain flatten); the post-promote
    # read is a single deep lookup (cheap iff the view survived).
    n_unrelated = (64 if QUICK else 256)
    reps = 3 if QUICK else 5
    promote_us = {}
    reread_us = {}
    for mode in ("scoped", "wholesale"):
        for n_views in (0, n_unrelated):
            p_total = r_total = 0.0
            for _ in range(reps):
                st = MetadataState(view_cache=True, promote_mode="splice")
                rt = st.apply(("create_root", "r"))
                _append(st, rt, 256, "r")
                other_root = st.apply(("create_root", "other"))
                deep = other_root
                for d in range(6):                 # many small runs per level
                    _append(st, deep, 256, f"d{d}", batch=8)
                    deep = st.apply(("cfork", deep, False))
                d_tail = st.tail(deep)
                others = []
                for _ in range(n_views):
                    f = st.apply(("cfork", deep, False))
                    st.read_spans(f, d_tail - 1, d_tail)   # warm a deep view
                    others.append(f)
                ch = st.apply(("cfork", rt, True))
                st.apply(("append", ch, "c", (0,), (8,)))
                t0 = time.perf_counter()
                st.apply(("promote", ch, "splice"))
                if mode == "wholesale":
                    st._invalidate_views()         # emulate the pre-§11 clear
                t1 = time.perf_counter()
                for f in others:
                    st.read_spans(f, d_tail - 1, d_tail)
                t2 = time.perf_counter()
                p_total += t1 - t0
                r_total += t2 - t1
            promote_us[(mode, n_views)] = p_total / reps * 1e6
            if n_views:
                reread_us[mode] = r_total / (reps * n_views) * 1e6
        rows.append((f"meta/promote_reread/{mode}/promote_us",
                     promote_us[(mode, n_unrelated)],
                     f"promote latency with {n_unrelated} live unrelated views"))
        rows.append((f"meta/promote_reread/{mode}/reread_us", reread_us[mode],
                     f"per deep lookup after the promote "
                     f"({'views survived' if mode == 'scoped' else 'every view rebuilt'})"))
    p_scale = (promote_us[("scoped", n_unrelated)]
               / max(1e-9, promote_us[("scoped", 0)]))
    rows.append(("meta/promote_reread/scoped/promote_scaling", p_scale,
                 f"{p_scale:.2f}x promote cost at {n_unrelated} views vs 0 "
                 "(flat: promote no longer touches unrelated views)"))
    penalty = reread_us["wholesale"] / reread_us["scoped"]
    rows.append(("meta/promote_reread/rebuild_penalty", penalty,
                 f"{penalty:.1f}x slower post-promote lookups under the "
                 "pre-§11 wholesale clear"))

    # -- proposals/sec: pipelined vs synchronous replica apply --------------
    n_props = 2_000 if QUICK else 10_000
    per_mode = {}
    for pipelined, tag in ((True, "pipelined"), (False, "sync")):
        svc = MetadataService(n_replicas=3, pipeline_apply=pipelined)
        lid = svc.propose(("create_root", "r"))
        offs = tuple(range(0, 64, 8))
        lens = tuple([8] * 8)
        t0 = time.perf_counter()
        for i in range(n_props):
            svc.propose(("append", lid, f"o{i}", offs, lens))
        dt = time.perf_counter() - t0
        assert svc.check_convergence()             # drains deferred applies
        per_mode[tag] = dt / n_props * 1e6
        rows.append((f"meta/proposals/{tag}", per_mode[tag],
                     f"{n_props / dt:.0f} proposals/s (3 replicas)"))
    speedup = per_mode["sync"] / per_mode["pipelined"]
    rows.append(("meta/proposals/speedup", speedup,
                 f"{speedup:.2f}x faster propose with deferred follower apply"))
    return rows
